"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One function per paper table/figure (benchmarks/tables.py). For each, we
print ``name,us_per_call,derived`` CSV (derived = the table's headline
metric) and dump all rows to results/tables.json. The roofline table
(deliverable g) is appended from the dry-run artifacts when present.

``python -m benchmarks.run sweep`` instead benchmarks the sweep engine's
execution paths against each other — per-point event engine vs the
batched ``mode="scan"`` fast path vs the device-sharded scan — on the
paper's FB / FLB-NUB grids (Figs. 13/14/18) across workload traces,
writes ``results/BENCH_sweep.json`` (wall-clock, points/sec, per-point
fidelity drift) and, with ``--check-fidelity X``, exits non-zero when
any point's completed-jobs or node-hours drift exceeds the fraction
``X`` — the CI smoke gate. ``--tiny`` shrinks the study to a two-day
trace slice for fast CI runs. ``--devices N`` also times the
shard_map backend over N devices; on a CPU-only host it sets
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` for you (all
imports of jax are deferred until after the flag is in place, so one
plain invocation measures real multi-core scaling).
"""

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))


def _derived(name, rows):
    """One headline number per table (the paper's claim)."""
    try:
        if name == "table_1_2":
            dcs = next(r for r in rows if r["system"].startswith("DCS"))
            fb60 = [r for r in rows if r.get("config_size") and
                    r["system"].startswith("Phoenix")][1]
            return f"fb60_throughput/dcs={fb60['completed_jobs']/dcs['completed_jobs']:.3f}"
        if name == "table_5_6":
            pc = [r for r in rows if "total_vs_ec2" in r]
            return "total_vs_ec2=" + "/".join(
                str(r["total_vs_ec2"]) for r in pc) + ";peak_vs_ec2=" + \
                "/".join(str(r["peak_vs_ec2"]) for r in pc)
        if name == "table_3_4" or name == "table_7_8":
            return "saved_pct=" + "/".join(
                str(r["saved_resources_pct"]) for r in rows)
        if name == "fig_18":
            return "pbj_adjust_events=" + "/".join(
                str(r["pbj_adjust_events"]) for r in rows
                if r["trace"] == "ipsc")
        if name == "fig_8_9":
            return "tokens_per_s=" + "/".join(
                str(r["tokens_per_s"]) for r in rows)
        if name == "ablation_preempt":
            k = [r for r in rows if r["mode"] == "kill"]
            c = [r for r in rows if r["mode"] == "checkpoint"]
            return "turnaround_ckpt/kill=" + "/".join(
                f"{ci['avg_turnaround']/ki['avg_turnaround']:.3f}"
                for ki, ci in zip(k, c))
    except Exception as e:              # pragma: no cover
        return f"derived_error:{type(e).__name__}"
    return f"rows={len(rows)}"


def sweep_benchmark(tiny: bool = False, devices: int = 0) -> dict:
    """Event engine vs batched scan (vs the sharded scan when
    ``devices >= 2``) on the paper's coordinated-policy grids. Returns
    the BENCH_sweep.json payload."""
    import jax
    from repro import compat
    from repro.sim import traces
    from repro.core.profiles import scale_profile
    from repro.sim.sweep import SweepPoint, run_sweep_workloads

    if devices:
        # Fail before the (minutes-long) event baseline, with the single
        # authoritative diagnosis.
        compat.resolve_devices(devices)

    if tiny:
        horizon = 2 * 24 * 3600.0
        jobs = [j for j in traces.nasa_ipsc(seed=0) if j.submit < horizon]
        ws = [(t, d) for t, d in traces.worldcup98(seed=0, peak_vms=64)
              if t < horizon]
        workloads = [(jobs, ws)]
        points = [SweepPoint("fb", capacity=96, label="FB(C=96)"),
                  SweepPoint("fb", capacity=128, label="FB(C=128)"),
                  SweepPoint("flb_nub", lb_pbj=13, lb_ws=12,
                             label="FLB-NUB(B=25)"),
                  SweepPoint("flb_nub", lb_pbj=13, lb_ws=12,
                             lease_seconds=1800.0,
                             label="FLB-NUB(L=30min)")]
    else:
        horizon = traces.TWO_WEEKS
        ws_nasa = traces.worldcup98(seed=0, peak_vms=128)
        # The multi-trace axis: both §6.2 batch logs plus a doubled WS
        # demand variant of the World Cup profile.
        workloads = [
            (traces.nasa_ipsc(seed=0), ws_nasa),
            (traces.sdsc_blue(seed=0), traces.worldcup98(seed=1,
                                                         peak_vms=128)),
            (traces.nasa_ipsc(seed=1), scale_profile(ws_nasa, 2.0)),
        ]
        dcs_size = 256
        points = (
            [SweepPoint("fb", capacity=int(round(dcs_size * f)),
                        label=f"FB(C={int(round(dcs_size * f))})")
             for f in (0.5, 0.6, 0.75, 0.9, 1.0)]            # Fig. 13
            + [SweepPoint("flb_nub", lb_pbj=B - min(12, B - 1),
                          lb_ws=min(12, B - 1), label=f"FLB-NUB(B={B})")
               for B in (13, 25, 51, 102, 154)]              # Fig. 14
            + [SweepPoint("flb_nub", lb_pbj=13, lb_ws=12,
                          lease_seconds=60.0 * m,
                          label=f"FLB-NUB(L={m}min)")
               for m in (15, 30, 60, 120, 240)])             # Fig. 18

    n_evals = len(points) * len(workloads)
    out = {"grid": [p.name() for p in points],
           "workloads": len(workloads), "evals": n_evals, "tiny": tiny}

    t0 = time.time()
    event_rows = run_sweep_workloads(points, workloads, horizon,
                                     mode="event")
    event_wall = time.time() - t0

    t0 = time.time()
    scan_rows = run_sweep_workloads(points, workloads, horizon, mode="scan")
    compile_wall = time.time() - t0
    t0 = time.time()
    scan_rows = run_sweep_workloads(points, workloads, horizon, mode="scan")
    scan_wall = max(time.time() - t0, 1e-6)

    out["event"] = {"wall_s": round(event_wall, 4),
                    "points_per_sec": round(n_evals / max(event_wall, 1e-6),
                                            2)}
    out["scan"] = {"compile_plus_run_s": round(compile_wall, 4),
                   "wall_s": round(scan_wall, 4),
                   "points_per_sec": round(n_evals / scan_wall, 2)}
    out["speedup"] = round(event_wall / scan_wall, 2)

    sharded_rows = None
    if devices and devices >= 2:
        t0 = time.time()
        sharded_rows = run_sweep_workloads(points, workloads, horizon,
                                           mode="scan", devices=devices)
        sharded_compile = time.time() - t0
        t0 = time.time()
        sharded_rows = run_sweep_workloads(points, workloads, horizon,
                                           mode="scan", devices=devices)
        sharded_wall = max(time.time() - t0, 1e-6)
        out["scan_sharded"] = {
            "devices": devices,
            "compile_plus_run_s": round(sharded_compile, 4),
            "wall_s": round(sharded_wall, 4),
            "points_per_sec": round(n_evals / sharded_wall, 2),
            "speedup_vs_event": round(event_wall / sharded_wall, 2),
            "speedup_vs_scan": round(scan_wall / sharded_wall, 2),
            # The sharded backend runs the identical per-lane program —
            # any row mismatch vs the single-device scan is a bug.
            "rows_match_scan": sharded_rows == scan_rows,
        }

    out["backend"] = {"devices": [str(d) for d in jax.devices()],
                      "cpu_count": os.cpu_count()}
    out["note"] = ("scan wall-clock is one jitted XLA program over the "
                   "whole (policy, point, trace) grid; it is compute-bound "
                   "per lane, so the speedup over the per-point Python "
                   "event engine scales with the host's SIMD width / core "
                   "count / accelerator, while the event path is "
                   "single-core Python either way. scan_sharded splits "
                   "the (point x trace) lanes across host devices "
                   "(shard_map) and reports the same rows as scan")

    drift, comparisons = [], []
    for w in range(len(workloads)):
        for i, p in enumerate(points):
            ev, sc = event_rows[w][i], scan_rows[w][i]
            dj = abs(sc["completed_jobs"] - ev["completed_jobs"]) \
                / max(1, ev["completed_jobs"])
            dn = abs(sc["node_hours"] - ev["node_hours"]) \
                / max(1e-9, ev["node_hours"])
            dp = abs(sc["peak_nodes"] - ev["peak_nodes"]) \
                / max(1, ev["peak_nodes"])
            drift.append(max(dj, dn))
            comparisons.append({
                "point": p.name(), "workload": w,
                "event": {m: ev[m] for m in ("completed_jobs", "node_hours",
                                             "peak_nodes", "kills")},
                "scan": {m: sc[m] for m in ("completed_jobs", "node_hours",
                                            "peak_nodes", "kills",
                                            "window_overflow")},
                "drift_completed": round(dj, 4),
                "drift_node_hours": round(dn, 4),
                "drift_peak": round(dp, 4)})
    out["max_drift"] = round(max(drift), 4)
    if sharded_rows is not None and not out["scan_sharded"]["rows_match_scan"]:
        # Surface a sharding bug through the same CI gate as fidelity.
        out["max_drift"] = max(out["max_drift"], 1.0)
    out["comparisons"] = comparisons
    return out


def run_sweep_bench(argv) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.run sweep")
    ap.add_argument("--tiny", action="store_true",
                    help="two-day trace slice, 4-point grid (CI smoke)")
    ap.add_argument("--devices", type=int, default=0, metavar="N",
                    help="also time the sharded scan over N host devices "
                    "(forces N XLA CPU devices when jax is not yet loaded)")
    ap.add_argument("--check-fidelity", type=float, default=None,
                    metavar="FRAC", help="exit 1 if any point's completed-"
                    "jobs or node-hours drift exceeds FRAC")
    ap.add_argument("--out", default="results/BENCH_sweep.json")
    args = ap.parse_args(argv)
    if args.devices >= 2:
        from repro.hostdev import force_host_device_count
        force_host_device_count(args.devices)
    out = sweep_benchmark(tiny=args.tiny, devices=args.devices)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    line = (f"evals={out['evals']} event={out['event']['wall_s']}s "
            f"({out['event']['points_per_sec']} pts/s) "
            f"scan={out['scan']['wall_s']}s "
            f"({out['scan']['points_per_sec']} pts/s) "
            f"speedup={out['speedup']}x max_drift={out['max_drift']}")
    if "scan_sharded" in out:
        sh = out["scan_sharded"]
        line += (f" sharded[{sh['devices']}]={sh['wall_s']}s "
                 f"({sh['points_per_sec']} pts/s, "
                 f"{sh['speedup_vs_event']}x event, "
                 f"{sh['speedup_vs_scan']}x scan, "
                 f"rows_match={sh['rows_match_scan']})")
    print(line)
    print(f"# -> {args.out}")
    if args.check_fidelity is not None and out["max_drift"] > args.check_fidelity:
        print(f"FIDELITY DRIFT {out['max_drift']} exceeds "
              f"{args.check_fidelity}", file=sys.stderr)
        return 1
    return 0


def main() -> None:
    # Deferred so `sweep --devices N` can set XLA_FLAGS first.
    from benchmarks.tables import ALL_TABLES
    from benchmarks import roofline
    os.makedirs("results", exist_ok=True)
    all_rows = {}
    print("name,us_per_call,derived")
    for name, fn in ALL_TABLES.items():
        t0 = time.time()
        rows = fn()
        dt_us = (time.time() - t0) * 1e6
        all_rows[name] = rows
        print(f"{name},{dt_us:.0f},{_derived(name, rows)}", flush=True)
    # Roofline table from the dry-run artifacts.
    t0 = time.time()
    roof = roofline.roofline_rows("singlepod")
    all_rows["roofline"] = roof
    ok = [r for r in roof if r.get("status") == "ok"]
    frac = [r["roofline_fraction"] for r in ok if r.get("roofline_fraction")]
    derived = (f"cells={len(ok)};median_fraction="
               f"{sorted(frac)[len(frac)//2] if frac else 'n/a'}")
    print(f"roofline,{(time.time()-t0)*1e6:.0f},{derived}")
    with open("results/tables.json", "w") as f:
        json.dump(all_rows, f, indent=1)
    print(f"# full tables -> results/tables.json "
          f"({sum(len(v) for v in all_rows.values())} rows)")


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "sweep":
        sys.exit(run_sweep_bench(sys.argv[2:]))
    main()
