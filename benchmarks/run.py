"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One function per paper table/figure (benchmarks/tables.py). For each, we
print ``name,us_per_call,derived`` CSV (derived = the table's headline
metric) and dump all rows to results/tables.json. The roofline table
(deliverable g) is appended from the dry-run artifacts when present.
"""

import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))

from benchmarks.tables import ALL_TABLES            # noqa: E402
from benchmarks import roofline                     # noqa: E402


def _derived(name, rows):
    """One headline number per table (the paper's claim)."""
    try:
        if name == "table_1_2":
            dcs = next(r for r in rows if r["system"].startswith("DCS"))
            fb60 = [r for r in rows if r.get("config_size") and
                    r["system"].startswith("Phoenix")][1]
            return f"fb60_throughput/dcs={fb60['completed_jobs']/dcs['completed_jobs']:.3f}"
        if name == "table_5_6":
            pc = [r for r in rows if "total_vs_ec2" in r]
            return "total_vs_ec2=" + "/".join(
                str(r["total_vs_ec2"]) for r in pc) + ";peak_vs_ec2=" + \
                "/".join(str(r["peak_vs_ec2"]) for r in pc)
        if name == "table_3_4" or name == "table_7_8":
            return "saved_pct=" + "/".join(
                str(r["saved_resources_pct"]) for r in rows)
        if name == "fig_18":
            return "pbj_adjust_events=" + "/".join(
                str(r["pbj_adjust_events"]) for r in rows
                if r["trace"] == "ipsc")
        if name == "fig_8_9":
            return "tokens_per_s=" + "/".join(
                str(r["tokens_per_s"]) for r in rows)
        if name == "ablation_preempt":
            k = [r for r in rows if r["mode"] == "kill"]
            c = [r for r in rows if r["mode"] == "checkpoint"]
            return "turnaround_ckpt/kill=" + "/".join(
                f"{ci['avg_turnaround']/ki['avg_turnaround']:.3f}"
                for ki, ci in zip(k, c))
    except Exception as e:              # pragma: no cover
        return f"derived_error:{type(e).__name__}"
    return f"rows={len(rows)}"


def main() -> None:
    os.makedirs("results", exist_ok=True)
    all_rows = {}
    print("name,us_per_call,derived")
    for name, fn in ALL_TABLES.items():
        t0 = time.time()
        rows = fn()
        dt_us = (time.time() - t0) * 1e6
        all_rows[name] = rows
        print(f"{name},{dt_us:.0f},{_derived(name, rows)}", flush=True)
    # Roofline table from the dry-run artifacts.
    t0 = time.time()
    roof = roofline.roofline_rows("singlepod")
    all_rows["roofline"] = roof
    ok = [r for r in roof if r.get("status") == "ok"]
    frac = [r["roofline_fraction"] for r in ok if r.get("roofline_fraction")]
    derived = (f"cells={len(ok)};median_fraction="
               f"{sorted(frac)[len(frac)//2] if frac else 'n/a'}")
    print(f"roofline,{(time.time()-t0)*1e6:.0f},{derived}")
    with open("results/tables.json", "w") as f:
        json.dump(all_rows, f, indent=1)
    print(f"# full tables -> results/tables.json "
          f"({sum(len(v) for v in all_rows.values())} rows)")


if __name__ == "__main__":
    main()
