"""Benchmark harness entry point: ``PYTHONPATH=src python -m benchmarks.run``.

One function per paper table/figure (benchmarks/tables.py). For each, we
print ``name,us_per_call,derived`` CSV (derived = the table's headline
metric) and dump all rows to results/tables.json. The roofline table
(deliverable g) is appended from the dry-run artifacts when present.

``python -m benchmarks.run sweep`` instead benchmarks the sweep engine's
execution paths against each other — per-point event engine vs the
batched ``mode="scan"`` fast path vs the event-round ``mode="rounds"``
engine vs their device-sharded variants — on the paper's FB / FLB-NUB
grids (Figs. 13/14/18) across workload traces, writes
``results/BENCH_sweep.json`` (wall-clock, points/sec, per-point
fidelity drift for both fast engines) and, with ``--check-fidelity X``,
exits non-zero when any scan point's completed-jobs or node-hours drift
exceeds the fraction ``X`` or any rounds point misses its tighter
contract (completed jobs exact, node-hours/peak within 5 %, sharded
rows bit-identical) — the CI smoke gate. ``--perf-gate R`` additionally
fails when the rounds engine's steady-state points/sec falls below
``R ×`` the scan engine's (the regression gate; both engines share the
per-step machinery, so a rounds-only slowdown is a real regression).
``--tiny`` shrinks the study to a two-day trace slice for fast CI runs.
``--devices N`` also times the shard_map backends over N devices; on a
CPU-only host it sets ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
for you (all imports of jax are deferred until after the flag is in
place, so one plain invocation measures real multi-core scaling). The
run also asserts that no buffer-donation ("aliasing") warnings escaped
the jitted fast paths — donation is platform-gated in ``repro.compat``
and must stay silent on hosts without it. ``--kernel pallas`` adds a
``rounds_pallas`` column: the fused round-step backend
(``repro.kernels.round_step``, interpret mode off-TPU) timed with
separated ``compile_s``/``run_s`` walls and held to the same rounds
contract plus bit-identity to the unfused rows.

``python -m benchmarks.run scenarios`` benchmarks the generated-scenario
path: on-device trace synthesis (``repro.sim.scenarios``) + the batched
(W, P) fold-table build vs the old host loop (numpy generators + the
per-point reference fold), at lane widths ``--widths`` (default 45, 256
and 1024), with ``--sample K`` lanes re-run on the event engine and held
to the rounds contract, and a fold-table cache gate. Writes
``results/BENCH_scenarios.json``; ``--check-contract`` makes contract or
cache failures exit non-zero (the wide-lane CI leg).

``python -m benchmarks.run faults`` is the chaos differential:
throughput-vs-MTBF curves under deterministic fault schedules
(``repro.sim.faults``), each schedule replayed through the event
engine, the rounds engine (time-varying capacity) and a ``LiveCloud``
trace replay. ``--check-contract`` gates on ``CONTRACTS['faults']``,
the no-lost-jobs invariant, and event-vs-live ledger identity; writes
``results/BENCH_faults.json``.

``python -m benchmarks.run roundstep`` is the kernel microbenchmark:
one fused vs one unfused outer step across vmapped lane widths
(``--lanes``), bit-equality asserted at every width, written to
``results/BENCH_roundstep.json``.
"""

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__))), "src"))


def _derived(name, rows):
    """One headline number per table (the paper's claim)."""
    try:
        if name == "table_1_2":
            dcs = next(r for r in rows if r["system"].startswith("DCS"))
            fb60 = [r for r in rows if r.get("config_size") and
                    r["system"].startswith("Phoenix")][1]
            return f"fb60_throughput/dcs={fb60['completed_jobs']/dcs['completed_jobs']:.3f}"
        if name == "table_5_6":
            pc = [r for r in rows if "total_vs_ec2" in r]
            return "total_vs_ec2=" + "/".join(
                str(r["total_vs_ec2"]) for r in pc) + ";peak_vs_ec2=" + \
                "/".join(str(r["peak_vs_ec2"]) for r in pc)
        if name == "table_3_4" or name == "table_7_8":
            return "saved_pct=" + "/".join(
                str(r["saved_resources_pct"]) for r in rows)
        if name == "fig_18":
            return "pbj_adjust_events=" + "/".join(
                str(r["pbj_adjust_events"]) for r in rows
                if r["trace"] == "ipsc")
        if name == "fig_8_9":
            return "tokens_per_s=" + "/".join(
                str(r["tokens_per_s"]) for r in rows)
        if name == "ablation_preempt":
            k = [r for r in rows if r["mode"] == "kill"]
            c = [r for r in rows if r["mode"] == "checkpoint"]
            return "turnaround_ckpt/kill=" + "/".join(
                f"{ci['avg_turnaround']/ki['avg_turnaround']:.3f}"
                for ki, ci in zip(k, c))
    except Exception as e:              # pragma: no cover
        return f"derived_error:{type(e).__name__}"
    return f"rows={len(rows)}"


def rounds_contract_ok(rounds_fidelity: dict, donation_warnings,
                       sharded_match: bool) -> bool:
    """The rounds engine's CI gate, thresholds imported from
    ``repro.sim.contracts.ROUNDS_CONTRACT`` — the same table the test
    suite asserts, so the gate and the tests cannot drift apart
    (tests/test_engine_differential.py pins this coupling)."""
    from repro.sim.contracts import ROUNDS_CONTRACT as RC
    rf = rounds_fidelity
    return bool(
        rf["completed_jobs_exact"]
        and rf["max_drift_node_hours"] <= RC.node_hours_rel
        and rf["max_drift_peak"] <= RC.peak_rel
        and rf["truncated_lanes"] == 0
        and not donation_warnings
        and sharded_match)


def _timed(fn, reps: int = 3):
    """Best-of-``reps`` wall time for an already-warm callable — the
    2-core CI boxes are noisy co-tenants, and a single timed run has
    bounced by +/-30% between invocations of the same program."""
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.time()
        out = fn()
        best = min(best, time.time() - t0)
    return max(best, 1e-6), out


def sweep_benchmark(tiny: bool = False, devices: int = 0,
                    kernel: str = "xla") -> dict:
    """Event engine vs batched scan vs event-round engine (plain and
    coalesced, vs their sharded variants when ``devices >= 2``) on the
    paper's coordinated-policy grids. ``kernel="pallas"`` ADDS a
    ``rounds_pallas`` column — the fused round-step backend timed and
    fidelity-gated alongside the regular engines (its rows must be
    bit-identical to the unfused rounds rows). Returns the
    BENCH_sweep.json payload."""
    import warnings

    import jax
    from repro import compat
    from repro.sim import traces
    from repro.core.profiles import scale_profile
    from repro.sim.sweep import (ScanOptions, SweepPoint,
                                 run_sweep_workloads, warmup_sweep)

    if devices:
        # Fail before the (minutes-long) event baseline, with the single
        # authoritative diagnosis.
        compat.resolve_devices(devices)

    if tiny:
        horizon = 2 * 24 * 3600.0

        def build_workloads():
            jobs = [j for j in traces.nasa_ipsc(seed=0)
                    if j.submit < horizon]
            ws = [(t, d) for t, d in traces.worldcup98(seed=0, peak_vms=64)
                  if t < horizon]
            return [(jobs, ws)]

        points = [SweepPoint("fb", capacity=96, label="FB(C=96)"),
                  SweepPoint("fb", capacity=128, label="FB(C=128)"),
                  SweepPoint("flb_nub", lb_pbj=13, lb_ws=12,
                             label="FLB-NUB(B=25)"),
                  SweepPoint("flb_nub", lb_pbj=13, lb_ws=12,
                             lease_seconds=1800.0,
                             label="FLB-NUB(L=30min)")]
    else:
        horizon = traces.TWO_WEEKS

        def build_workloads():
            ws_nasa = traces.worldcup98(seed=0, peak_vms=128)
            # The multi-trace axis: both §6.2 batch logs plus a doubled
            # WS demand variant of the World Cup profile.
            return [
                (traces.nasa_ipsc(seed=0), ws_nasa),
                (traces.sdsc_blue(seed=0), traces.worldcup98(seed=1,
                                                             peak_vms=128)),
                (traces.nasa_ipsc(seed=1), scale_profile(ws_nasa, 2.0)),
            ]

        dcs_size = 256
        points = (
            [SweepPoint("fb", capacity=int(round(dcs_size * f)),
                        label=f"FB(C={int(round(dcs_size * f))})")
             for f in (0.5, 0.6, 0.75, 0.9, 1.0)]            # Fig. 13
            + [SweepPoint("flb_nub", lb_pbj=B - min(12, B - 1),
                          lb_ws=min(12, B - 1), label=f"FLB-NUB(B={B})")
               for B in (13, 25, 51, 102, 154)]              # Fig. 14
            + [SweepPoint("flb_nub", lb_pbj=13, lb_ws=12,
                          lease_seconds=60.0 * m,
                          label=f"FLB-NUB(L={m}min)")
               for m in (15, 30, 60, 120, 240)])             # Fig. 18

    # Setup stage, timed honestly per engine family (the setup_s column
    # the compile_s/run_s walls silently excluded): numpy trace
    # synthesis, plus each family's host-side pack — job tables + WS
    # profiles for the scan, job tables + WS fold tables for the rounds
    # engines (cold fold-table cache per rep; the coalesced/pallas
    # variants share the rounds pack — identical windows, identical
    # arrays).
    from repro.sim.rounds import fold_table_cache_clear
    from repro.sim.sweep import _pack_rounds, _pack_scan
    tracegen_s, workloads = _timed(build_workloads, reps=2)
    scan_pack_s, _ = _timed(
        lambda: _pack_scan(points, workloads, horizon, ScanOptions()),
        reps=2)

    def _rounds_setup():
        fold_table_cache_clear()
        return _pack_rounds(points, workloads, horizon, ScanOptions())

    rounds_pack_s, _ = _timed(_rounds_setup, reps=2)

    n_evals = len(points) * len(workloads)
    out = {"grid": [p.name() for p in points],
           "workloads": len(workloads), "evals": n_evals, "tiny": tiny,
           "tracegen_s": round(tracegen_s, 4)}

    # The event engine has no compile step, so both runs are timed —
    # best-of-2 keeps the speedup_vs_event ratios symmetric with the
    # best-of-N fast-path walls instead of dividing by one noisy draw.
    event_wall, event_rows = _timed(lambda: run_sweep_workloads(
        points, workloads, horizon, mode="event"), reps=2)

    # The coalesced-rounds variant: contended stretches fold up to
    # COALESCE_BATCH completions (plus riding arrivals) per event round
    # via the bulk top-k/prefix-feasibility section of repro.sim.rounds.
    from repro.sim.rounds import COALESCE_BATCH
    coalesce_opts = ScanOptions(coalesce=COALESCE_BATCH)

    # Any donation ("aliasing") warning from the jitted fast paths means
    # the compat platform gate failed — record them and gate below.
    pallas_opts = (ScanOptions(kernel="pallas") if kernel == "pallas"
                   else None)

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")

        scan_compile = warmup_sweep(points, workloads, horizon,
                                    mode="scan")
        scan_wall, scan_rows = _timed(lambda: run_sweep_workloads(
            points, workloads, horizon, mode="scan"))

        rounds_compile = warmup_sweep(points, workloads, horizon,
                                      mode="rounds")
        rounds_wall, rounds_rows = _timed(lambda: run_sweep_workloads(
            points, workloads, horizon, mode="rounds"))

        coal_compile = warmup_sweep(points, workloads, horizon,
                                    mode="rounds",
                                    scan_options=coalesce_opts)
        coal_wall, coal_rows = _timed(lambda: run_sweep_workloads(
            points, workloads, horizon, mode="rounds",
            scan_options=coalesce_opts))

        if pallas_opts is not None:
            pallas_compile = warmup_sweep(points, workloads, horizon,
                                          mode="rounds",
                                          scan_options=pallas_opts)
            pallas_wall, pallas_rows = _timed(lambda: run_sweep_workloads(
                points, workloads, horizon, mode="rounds",
                scan_options=pallas_opts))
    donation_warnings = [str(w.message) for w in caught
                         if "donat" in str(w.message).lower()
                         or "alias" in str(w.message).lower()]

    def _walls(compile_plus_run, wall):
        # compile_s is the warm-up wall minus one steady run — the jit
        # trace + XLA (and Pallas) compile cost in isolation; the old
        # compile_plus_run_s column stays for ledger continuity.
        return {"compile_plus_run_s": round(compile_plus_run, 4),
                "compile_s": round(max(compile_plus_run - wall, 0.0), 4),
                "run_s": round(wall, 4)}

    out["event"] = {"wall_s": round(event_wall, 4),
                    "points_per_sec": round(n_evals / event_wall, 2)}
    out["scan"] = {**_walls(scan_compile, scan_wall),
                   "wall_s": round(scan_wall, 4),
                   "points_per_sec": round(n_evals / scan_wall, 2)}
    out["rounds"] = {**_walls(rounds_compile, rounds_wall),
                     "wall_s": round(rounds_wall, 4),
                     "points_per_sec": round(n_evals / rounds_wall, 2),
                     "speedup_vs_event": round(event_wall / rounds_wall, 2),
                     "speedup_vs_scan": round(scan_wall / rounds_wall, 2)}
    out["rounds_coalesced"] = {
        "coalesce_batch": COALESCE_BATCH,
        **_walls(coal_compile, coal_wall),
        "wall_s": round(coal_wall, 4),
        "points_per_sec": round(n_evals / coal_wall, 2),
        "speedup_vs_event": round(event_wall / coal_wall, 2),
        "speedup_vs_scan": round(scan_wall / coal_wall, 2),
        "speedup_vs_rounds": round(rounds_wall / coal_wall, 2),
        "max_rounds": max(r.get("rounds", 0)
                          for rows_w in coal_rows for r in rows_w),
        "max_rounds_uncoalesced": max(r.get("rounds", 0)
                                      for rows_w in rounds_rows
                                      for r in rows_w),
        "coalesced_events": sum(r.get("coalesced", 0)
                                for rows_w in coal_rows
                                for r in rows_w),
    }
    if pallas_opts is not None:
        from repro.kernels.ops import _default_interpret
        out["rounds_pallas"] = {
            **_walls(pallas_compile, pallas_wall),
            "wall_s": round(pallas_wall, 4),
            "points_per_sec": round(n_evals / pallas_wall, 2),
            "speedup_vs_event": round(event_wall / pallas_wall, 2),
            "speedup_vs_rounds": round(rounds_wall / pallas_wall, 2),
            # Interpret mode (CPU) validates semantics, not speed — the
            # compiled-kernel regime is GPU/TPU. Recorded so the ledger
            # never passes an interpret wall off as a kernel wall.
            "interpret": _default_interpret(),
            # Both backends run the same _chunk_core math on the same
            # inputs — any row difference is a packing bug.
            "rows_match_rounds": pallas_rows == rounds_rows,
        }
    out["speedup"] = round(event_wall / scan_wall, 2)
    out["donation_warnings"] = donation_warnings

    sharded_rows = rounds_sharded_rows = None
    pallas_sharded_match = None
    if devices and devices >= 2:
        t0 = time.time()
        run_sweep_workloads(points, workloads, horizon, mode="scan",
                            devices=devices)
        sharded_compile = time.time() - t0
        sharded_wall, sharded_rows = _timed(lambda: run_sweep_workloads(
            points, workloads, horizon, mode="scan", devices=devices),
            reps=2)
        out["scan_sharded"] = {
            "devices": devices,
            "compile_plus_run_s": round(sharded_compile, 4),
            "compile_s": round(max(sharded_compile - sharded_wall, 0.0), 4),
            "run_s": round(sharded_wall, 4),
            "wall_s": round(sharded_wall, 4),
            "points_per_sec": round(n_evals / sharded_wall, 2),
            "speedup_vs_event": round(event_wall / sharded_wall, 2),
            "speedup_vs_scan": round(scan_wall / sharded_wall, 2),
            # The sharded backend runs the identical per-lane program —
            # any row mismatch vs the single-device scan is a bug.
            "rows_match_scan": sharded_rows == scan_rows,
        }
        t0 = time.time()
        run_sweep_workloads(points, workloads, horizon, mode="rounds",
                            devices=devices)
        rsh_compile = time.time() - t0
        rsh_wall, rounds_sharded_rows = _timed(
            lambda: run_sweep_workloads(points, workloads, horizon,
                                        mode="rounds", devices=devices),
            reps=2)
        out["rounds_sharded"] = {
            "devices": devices,
            "compile_plus_run_s": round(rsh_compile, 4),
            "compile_s": round(max(rsh_compile - rsh_wall, 0.0), 4),
            "run_s": round(rsh_wall, 4),
            "wall_s": round(rsh_wall, 4),
            "points_per_sec": round(n_evals / rsh_wall, 2),
            "speedup_vs_event": round(event_wall / rsh_wall, 2),
            "speedup_vs_rounds": round(rounds_wall / rsh_wall, 2),
            "rows_match_rounds": rounds_sharded_rows == rounds_rows,
        }
        if pallas_opts is not None:
            # The fused kernel's sharded leg: lanes split across host
            # devices via the same sharded_grid_map (the vmapped
            # pallas_call is just the per-lane program) — rows must stay
            # bit-identical to the single-device fused run.
            psh_compile = warmup_sweep(points, workloads, horizon,
                                       mode="rounds",
                                       scan_options=pallas_opts,
                                       devices=devices)
            psh_wall, psh_rows = _timed(
                lambda: run_sweep_workloads(points, workloads, horizon,
                                            mode="rounds",
                                            scan_options=pallas_opts,
                                            devices=devices), reps=2)
            pallas_sharded_match = psh_rows == pallas_rows
            out["rounds_pallas_sharded"] = {
                "devices": devices,
                "compile_plus_run_s": round(psh_compile, 4),
                "compile_s": round(max(psh_compile - psh_wall, 0.0), 4),
                "run_s": round(psh_wall, 4),
                "wall_s": round(psh_wall, 4),
                "points_per_sec": round(n_evals / psh_wall, 2),
                "rows_match_pallas": pallas_sharded_match,
            }

    # Every engine row reports its setup cost: trace synthesis for the
    # event engine, plus the family's pack stage for the fast paths
    # (sharded variants share their family's pack — the pack is
    # device-count independent).
    for key, engine in list(out.items()):
        if isinstance(engine, dict) and "points_per_sec" in engine:
            if key.startswith("scan"):
                engine["setup_s"] = round(tracegen_s + scan_pack_s, 4)
            elif key.startswith("rounds"):
                engine["setup_s"] = round(tracegen_s + rounds_pack_s, 4)
            else:                                  # the event engine
                engine["setup_s"] = round(tracegen_s, 4)

    out["backend"] = {"devices": [str(d) for d in jax.devices()],
                      "cpu_count": os.cpu_count()}
    out["note"] = ("all fast paths are jitted XLA programs batched over "
                   "the (policy, point) grid — compute-bound per lane, so "
                   "their speedup over the per-point Python event engine "
                   "scales with the host's cores/SIMD/accelerator. scan "
                   "advances every lane on a fixed dt; rounds jumps "
                   "lane-by-lane to the next event (exact completions and "
                   "allocation integrals — see its tighter drift columns). "
                   "On the paper traces the event density matches the "
                   "scan's substep density, so the engines run at similar "
                   "wall-clock; the rounds engine pulls ahead on demand "
                   "traces finer than the scan's FLB_MIN_DT floor, and "
                   "its fidelity contract (completed exact, <=5% "
                   "node-hours/peak) holds everywhere. *_sharded split "
                   "the lanes across host devices (shard_map) and must "
                   "report bit-identical rows")

    def _drift(rows):
        worst, comparisons = [], []
        for w in range(len(workloads)):
            for i, p in enumerate(points):
                ev, fast = event_rows[w][i], rows[w][i]
                dj = abs(fast["completed_jobs"] - ev["completed_jobs"]) \
                    / max(1, ev["completed_jobs"])
                dn = abs(fast["node_hours"] - ev["node_hours"]) \
                    / max(1e-9, ev["node_hours"])
                dp = abs(fast["peak_nodes"] - ev["peak_nodes"]) \
                    / max(1, ev["peak_nodes"])
                worst.append(max(dj, dn))
                comparisons.append({
                    "point": p.name(), "workload": w,
                    "event": {m: ev[m] for m in
                              ("completed_jobs", "node_hours",
                               "peak_nodes", "kills")},
                    "fast": {m: fast[m] for m in
                             ("completed_jobs", "node_hours", "peak_nodes",
                              "kills", "window_overflow")},
                    "jobs_exact": fast["completed_jobs"]
                    == ev["completed_jobs"],
                    "drift_completed": round(dj, 4),
                    "drift_node_hours": round(dn, 4),
                    "drift_peak": round(dp, 4)})
        return worst, comparisons

    def _fidelity(rows, cmp_rows):
        return {
            "completed_jobs_exact": all(c["jobs_exact"] for c in cmp_rows),
            "max_drift_node_hours": round(max(c["drift_node_hours"]
                                              for c in cmp_rows), 4),
            "max_drift_peak": round(max(c["drift_peak"]
                                        for c in cmp_rows), 4),
            "truncated_lanes": sum(r.get("truncated", 0)
                                   for rows_w in rows for r in rows_w),
        }

    scan_drift, scan_cmp = _drift(scan_rows)
    rounds_drift, rounds_cmp = _drift(rounds_rows)
    _, coal_cmp = _drift(coal_rows)
    out["max_drift"] = round(max(scan_drift), 4)
    out["rounds_fidelity"] = _fidelity(rounds_rows, rounds_cmp)
    out["rounds_coalesced_fidelity"] = _fidelity(coal_rows, coal_cmp)
    if sharded_rows is not None and not out["scan_sharded"]["rows_match_scan"]:
        # Surface a sharding bug through the same CI gate as fidelity.
        out["max_drift"] = max(out["max_drift"], 1.0)
    out["comparisons"] = scan_cmp
    out["rounds_comparisons"] = rounds_cmp
    # The rounds contract (thresholds imported from
    # repro.sim.contracts — the table the tests assert), folded into
    # one gate flag per engine variant: completed jobs exact,
    # node-hours and peak within the contract band, sharded rows
    # bit-identical, no lane truncation, no donation warnings. The
    # coalesced variant must satisfy the SAME contract — the coalescer
    # may never buy speed with fidelity.
    out["rounds_contract_ok"] = rounds_contract_ok(
        out["rounds_fidelity"], donation_warnings,
        rounds_sharded_rows is None
        or out["rounds_sharded"]["rows_match_rounds"])
    # The coalesced sharded-identity leg is pinned by
    # tests/test_sweep_sharded.py (subprocess, 2 forced devices), not
    # re-timed here — True stands for "covered elsewhere".
    out["rounds_coalesced_contract_ok"] = rounds_contract_ok(
        out["rounds_coalesced_fidelity"], donation_warnings, True)
    if pallas_opts is not None:
        # The fused kernel answers to the SAME contract as the engine it
        # fuses, plus bit-identity to the unfused rows (and to its own
        # sharded run when a sharded leg was timed).
        _, pallas_cmp = _drift(pallas_rows)
        out["rounds_pallas_fidelity"] = _fidelity(pallas_rows, pallas_cmp)
        out["rounds_pallas_contract_ok"] = bool(rounds_contract_ok(
            out["rounds_pallas_fidelity"], donation_warnings,
            pallas_sharded_match is None or pallas_sharded_match)
            and out["rounds_pallas"]["rows_match_rounds"])
    return out


def run_sweep_bench(argv) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.run sweep")
    ap.add_argument("--tiny", action="store_true",
                    help="two-day trace slice, 4-point grid (CI smoke)")
    ap.add_argument("--devices", type=int, default=0, metavar="N",
                    help="also time the sharded fast paths over N host "
                    "devices (forces N XLA CPU devices when jax is not "
                    "yet loaded)")
    ap.add_argument("--check-fidelity", type=float, default=None,
                    metavar="FRAC", help="exit 1 if any scan point's "
                    "completed-jobs or node-hours drift exceeds FRAC, or "
                    "the rounds contract (jobs exact, node-hours/peak "
                    "within 5%%, sharded rows identical) fails — with "
                    "--kernel pallas the fused column answers to the "
                    "same contract plus bit-identity to unfused rows")
    ap.add_argument("--perf-gate", type=float, default=None, metavar="R",
                    help="exit 1 if the (unfused) rounds engine's "
                    "steady-state points/sec drops below R x the scan "
                    "engine's")
    ap.add_argument("--kernel", choices=("xla", "pallas"), default="xla",
                    help="'pallas' additionally times the fused "
                    "round-step kernel as a rounds_pallas column "
                    "(interpret mode off-TPU)")
    ap.add_argument("--out", default="results/BENCH_sweep.json")
    args = ap.parse_args(argv)
    if args.devices >= 2:
        from repro.hostdev import force_host_device_count
        force_host_device_count(args.devices)
    out = sweep_benchmark(tiny=args.tiny, devices=args.devices,
                          kernel=args.kernel)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    rd = out["rounds"]
    rco = out["rounds_coalesced"]
    line = (f"evals={out['evals']} event={out['event']['wall_s']}s "
            f"({out['event']['points_per_sec']} pts/s) "
            f"scan={out['scan']['wall_s']}s "
            f"({out['scan']['points_per_sec']} pts/s) "
            f"rounds={rd['wall_s']}s ({rd['points_per_sec']} pts/s, "
            f"{rd['speedup_vs_event']}x event) "
            f"rounds_coalesced[{rco['coalesce_batch']}]={rco['wall_s']}s "
            f"({rco['points_per_sec']} pts/s, max_rounds "
            f"{rco['max_rounds_uncoalesced']}->{rco['max_rounds']}) "
            f"max_drift(scan)={out['max_drift']} "
            f"rounds_contract_ok={out['rounds_contract_ok']} "
            f"coalesced_contract_ok={out['rounds_coalesced_contract_ok']}")
    if "rounds_pallas" in out:
        rp = out["rounds_pallas"]
        line += (f" rounds_pallas={rp['run_s']}s "
                 f"(compile {rp['compile_s']}s, interpret "
                 f"{rp['interpret']}, rows_match="
                 f"{rp['rows_match_rounds']}, contract_ok="
                 f"{out['rounds_pallas_contract_ok']})")
    for key, base in (("scan_sharded", "scan"),
                      ("rounds_sharded", "rounds"),
                      ("rounds_pallas_sharded", "rounds_pallas")):
        if key in out:
            sh = out[key]
            match = sh.get("rows_match_scan",
                           sh.get("rows_match_rounds",
                                  sh.get("rows_match_pallas")))
            line += (f" {key}[{sh['devices']}]={sh['wall_s']}s "
                     f"({sh['points_per_sec']} pts/s, rows_match={match})")
    print(line)
    print(f"# -> {args.out}")
    rc = 0
    if args.check_fidelity is not None:
        if out["max_drift"] > args.check_fidelity:
            print(f"FIDELITY DRIFT {out['max_drift']} exceeds "
                  f"{args.check_fidelity}", file=sys.stderr)
            rc = 1
        if not out["rounds_contract_ok"]:
            print(f"ROUNDS CONTRACT FAILED: {out['rounds_fidelity']} "
                  f"donation_warnings={out['donation_warnings']}",
                  file=sys.stderr)
            rc = 1
        if not out["rounds_coalesced_contract_ok"]:
            print(f"COALESCED ROUNDS CONTRACT FAILED: "
                  f"{out['rounds_coalesced_fidelity']}", file=sys.stderr)
            rc = 1
        if "rounds_pallas" in out and not out["rounds_pallas_contract_ok"]:
            print(f"PALLAS ROUNDS CONTRACT FAILED: "
                  f"{out['rounds_pallas_fidelity']} rows_match="
                  f"{out['rounds_pallas']['rows_match_rounds']}",
                  file=sys.stderr)
            rc = 1
    if args.perf_gate is not None:
        ratio = rd["points_per_sec"] / max(out["scan"]["points_per_sec"],
                                           1e-9)
        if ratio < args.perf_gate:
            print(f"PERF GATE: rounds at {ratio:.2f}x scan points/sec, "
                  f"below the {args.perf_gate}x gate", file=sys.stderr)
            rc = 1
    return rc


def scenarios_benchmark(widths=(45, 256, 1024), tiny: bool = False,
                        devices: int = 0, sample_n: int = 3,
                        reps: int = 3) -> dict:
    """Generated-scenario sweeps at growing lane widths: on-device
    tracegen (``repro.sim.scenarios``) + batched fold tables vs the
    host-loop baseline (numpy generators + the per-point reference
    fold build), with the full sweep timed end-to-end through
    ``run_sweep_workloads`` on the rounds engine and the PR 5
    differential harness sampling lanes against the event engine.
    Returns the BENCH_scenarios.json payload.

    Per width the ledger separates ``gen_s`` (vmapped synthesis +
    device transfer, steady state), ``pack_s`` (job-table padding +
    rise compression + ONE batched (W, P) fold-table build),
    ``compile_s`` and ``run_s``. ``run_s`` is a full
    ``run_sweep_workloads`` call and therefore INCLUDES a fresh
    synthesize + pack each rep — the end-to-end cost a sweep actually
    pays. The host baseline is measured on ``host_lanes_measured``
    lanes and extrapolated linearly (it is embarrassingly per-lane).
    """
    import numpy as np

    import jax
    from repro import compat
    from repro.core.profiles import step_points
    from repro.sim import traces
    from repro.sim.contracts import CONTRACTS
    from repro.sim.rounds import (_ws_fold_tables_ref,
                                  fold_table_cache_clear,
                                  fold_table_cache_info)
    from repro.sim.scenarios import (PBJParams, ScenarioGrid, WSParams,
                                     sample_workloads, synthesize)
    from repro.sim.sweep import (ScanOptions, SweepPoint,
                                 _pack_scenarios_grids,
                                 run_sweep_workloads)

    if devices:
        compat.resolve_devices(devices)

    duration = 2 * 24 * 3600.0 if tiny else traces.TWO_WEEKS
    max_jobs = 400 if tiny else 3000
    points = [SweepPoint("fb", capacity=96, label="FB(C=96)"),
              SweepPoint("fb", capacity=128, label="FB(C=128)"),
              SweepPoint("fb", capacity=160, label="FB(C=160)"),
              SweepPoint("flb_nub", lb_pbj=13, lb_ws=12,
                         label="FLB-NUB(B=25)"),
              SweepPoint("flb_nub", lb_pbj=13, lb_ws=12,
                         lease_seconds=1800.0, label="FLB-NUB(L=30min)")]
    fb_leases = np.array([3600.0, 3600.0, 3600.0])
    fb_levels = np.array([96.0, 128.0, 160.0])
    flb_leases = np.array([3600.0, 1800.0])
    flb_levels = np.array([12.0, 12.0])
    opts = ScanOptions(devices=devices if devices >= 2 else None)
    P = len(points)

    out = {"tiny": tiny, "duration_s": duration, "max_jobs": max_jobs,
           "grid": [p.name() for p in points], "devices": devices,
           "backend": {"devices": [str(d) for d in jax.devices()],
                       "cpu_count": os.cpu_count()},
           "note": ("setup = gen (vmapped on-device synthesis, steady "
                    "state after one compile) + pack (batched fold "
                    "tables); host baseline = numpy tracegen + the "
                    "reference per-point fold loop per lane, measured "
                    "on a few lanes and scaled linearly. run_s re-runs "
                    "the FULL pipeline (synthesize + pack + engine) "
                    "per rep"),
           "widths": []}

    for width in widths:
        W = max(1, int(round(width / P)))
        lo, hi = (250.0, 380.0) if tiny else (1800.0, 2900.0)
        pbj = PBJParams(
            nodes=128.0,
            utilization=np.linspace(0.35, 0.8, W),
            n_jobs=np.round(np.linspace(lo, hi, W)),
            alpha=np.linspace(0.15, 0.7, W),
            burst_frac=np.linspace(0.06, 0.25, W),
            diurnal_depth=np.linspace(0.5, 0.95, W))
        ws = WSParams(peak=np.round(np.linspace(32.0, 128.0, W)),
                      base_mean=np.linspace(8.0, 14.0, W),
                      surge_ratio=np.linspace(2.0, 6.0, W))
        grid = ScenarioGrid(seeds=tuple(range(W)), pbj=pbj, ws=ws,
                            duration=duration, max_jobs=max_jobs)

        synth = synthesize(grid)                  # compile + warm
        gen_s, synth = _timed(lambda: synthesize(grid), reps=reps)
        pack_s, _ = _timed(
            lambda: _pack_scenarios_grids(points, grid, synth, opts),
            reps=reps)
        setup_s = gen_s + pack_s

        # Host-loop baseline: per-lane numpy synthesis + the reference
        # per-point fold build, exactly what pack_event_workloads did
        # before the batched rewrite.
        nb = min(W, 8)

        def host_setup():
            for w in range(nb):
                [j for j in traces.nasa_ipsc(seed=w)
                 if j.submit < duration]
                wtrace = [(t, d) for t, d in traces.worldcup98(seed=w)
                          if t < duration]
                times, values = step_points(wtrace, duration)
                _ws_fold_tables_ref(times, values, duration, "fb",
                                    fb_leases, fb_levels)
                _ws_fold_tables_ref(times, values, duration, "flb_nub",
                                    flb_leases, flb_levels)

        host_nb_s, _ = _timed(host_setup, reps=1)
        host_setup_s = host_nb_s * (W / nb)

        t0 = time.time()
        rows = run_sweep_workloads(points, grid, mode="rounds",
                                   scan_options=opts)
        compile_plus_run = time.time() - t0
        run_s, rows = _timed(
            lambda: run_sweep_workloads(points, grid, mode="rounds",
                                        scan_options=opts),
            reps=max(2, reps - 1))

        # Sampled-lane differential: a few lanes re-run on the event
        # engine, the generated rows held to the rounds contract.
        sample = sorted({0, W // 2, W - 1})[:max(1, sample_n)]
        host_lanes = sample_workloads(synth, sample)
        ev_rows = run_sweep_workloads(points, host_lanes, duration,
                                      mode="event")
        violations = []
        for j, w in enumerate(sample):
            for i in range(P):
                violations += [
                    f"lane {w} {v}" for v in
                    CONTRACTS["rounds"].check_row(rows[w][i],
                                                  ev_rows[j][i])]

        # Fold-table cache: re-packing the same sampled lanes (as the
        # differential harness and the multi-engine benchmark do per
        # engine column) must hit, not recompute.
        fold_table_cache_clear()
        run_sweep_workloads(points, host_lanes, duration, mode="rounds")
        run_sweep_workloads(points, host_lanes, duration, mode="rounds")
        ci = fold_table_cache_info()
        cache = {"hits": ci.hits, "misses": ci.misses}

        out["widths"].append({
            "width": width, "lanes": W * P, "traces": W,
            "gen_s": round(gen_s, 4), "pack_s": round(pack_s, 4),
            "setup_s": round(setup_s, 4),
            "setup_per_point_ms": round(1e3 * setup_s / (W * P), 4),
            "host_setup_s": round(host_setup_s, 4),
            "host_lanes_measured": nb,
            "setup_speedup_vs_host": round(
                host_setup_s / max(setup_s, 1e-9), 2),
            "compile_plus_run_s": round(compile_plus_run, 4),
            "compile_s": round(max(compile_plus_run - run_s, 0.0), 4),
            "run_s": round(run_s, 4),
            "points_per_sec": round(W * P / run_s, 2),
            "sampled_lanes": [int(s) for s in sample],
            "contract_violations": violations,
            "contract_ok": not violations,
            "fold_cache": cache,
            "fold_cache_ok": cache["hits"] >= 1,
        })
    return out


def run_scenarios_bench(argv) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.run scenarios")
    ap.add_argument("--widths", type=int, nargs="+",
                    default=[45, 256, 1024], metavar="N",
                    help="(point x trace) lane widths to sweep")
    ap.add_argument("--tiny", action="store_true",
                    help="two-day horizon, ~350-job lanes (CI smoke)")
    ap.add_argument("--devices", type=int, default=0, metavar="N",
                    help="shard the generated sweep over N host devices "
                    "(forces N XLA CPU devices when jax is not yet "
                    "loaded)")
    ap.add_argument("--sample", type=int, default=3, metavar="K",
                    help="lanes per width re-run on the event engine "
                    "for the differential contract")
    ap.add_argument("--check-contract", action="store_true",
                    help="exit 1 unless every width's sampled-lane "
                    "rounds contract is green and the fold-table cache "
                    "registered hits")
    ap.add_argument("--out", default="results/BENCH_scenarios.json")
    args = ap.parse_args(argv)
    if args.devices >= 2:
        from repro.hostdev import force_host_device_count
        force_host_device_count(args.devices)
    out = scenarios_benchmark(widths=tuple(args.widths), tiny=args.tiny,
                              devices=args.devices, sample_n=args.sample)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    rc = 0
    for row in out["widths"]:
        print(f"width={row['width']} lanes={row['lanes']} "
              f"setup={row['setup_s']}s (gen {row['gen_s']}s + pack "
              f"{row['pack_s']}s, {row['setup_per_point_ms']}ms/pt, "
              f"{row['setup_speedup_vs_host']}x host) "
              f"compile={row['compile_s']}s run={row['run_s']}s "
              f"({row['points_per_sec']} pts/s) "
              f"contract_ok={row['contract_ok']} "
              f"cache_hits={row['fold_cache']['hits']}")
        if args.check_contract and not (row["contract_ok"]
                                        and row["fold_cache_ok"]):
            print(f"SCENARIOS GATE FAILED at width {row['width']}: "
                  f"violations={row['contract_violations']} "
                  f"fold_cache={row['fold_cache']}", file=sys.stderr)
            rc = 1
    print(f"# -> {args.out}")
    return rc


def roundstep_benchmark(lane_widths=(1, 4, 16, 64), reps: int = 3) -> dict:
    """Microbenchmark of the fused Pallas round-step kernel vs the
    unfused traced body: ONE outer step (compaction + admission + the
    ``compact_every`` unrolled rounds) on a real packed trace lane,
    vmapped across ``lane_widths`` lane counts — the per-op dispatch
    floor the fusion attacks, isolated from the while_loop. Also
    asserts the two backends' packed outputs are bit-identical at every
    width. Returns the BENCH_roundstep.json payload."""
    import numpy as np

    import jax
    import jax.numpy as jnp
    from repro.kernels import round_step as rsk
    from repro.kernels.ops import _default_interpret
    from repro.sim import rounds as roundslib
    from repro.sim import traces

    horizon = 2 * 24 * 3600.0
    jobs = [j for j in traces.nasa_ipsc(seed=0) if j.submit < horizon]
    ws = [(t, d) for t, d in traces.worldcup98(seed=0, peak_vms=64)
          if t < horizon]
    K = roundslib.FB_ROUNDS_WINDOW
    spec = roundslib.RoundsSpec(
        duration=horizon,
        max_rounds=roundslib.round_budget(len(jobs), len(ws), horizon,
                                          3600.0),
        window=K, kernel="pallas")
    pk = jax.tree_util.tree_map(
        lambda a: a[0], roundslib.pack_event_workloads(
            [(jobs, ws)], horizon, K, "fb", leases=[3600.0], levels=[96]))
    prm = {"lease": jnp.asarray(3600.0, pk.submit.dtype),
           "capacity": jnp.asarray(96.0, pk.submit.dtype),
           "p_idx": jnp.asarray(0, jnp.int32)}
    ctx = roundslib._lane_ctx("fb", prm, pk)
    inputs = rsk.lane_inputs("fb", ctx)
    f = pk.submit.dtype
    zero = jnp.zeros((), f)
    acc = {k: zero for k in roundslib.ACC_KEYS}
    core0 = (zero, jnp.asarray(64.0, f), zero, zero,
             jnp.asarray(False), pk.ws0, jnp.asarray(64.0, f),
             jnp.asarray(0, jnp.int32), jnp.asarray(K, jnp.int32),
             pk.submit[:K], pk.size[:K], pk.runtime[:K],
             jnp.zeros(K, bool), jnp.zeros(K, bool), jnp.zeros(K, f),
             jnp.zeros(K, f), acc)
    sc1, win1 = rsk.pack_carry(core0)

    def step(fn):
        return jax.jit(jax.vmap(
            lambda sc, win: fn(*inputs, sc, win, policy="fb", spec=spec),
            in_axes=(0, 0)))

    fused, ref = step(rsk.chunk_step), step(rsk.chunk_step_ref)
    out = {"window": K, "compact_every": spec.compact_every,
           "interpret": _default_interpret(), "policy": "fb",
           "trace_jobs": len(jobs), "widths": []}
    for n in lane_widths:
        sc = jnp.broadcast_to(sc1, (n,) + sc1.shape)
        win = jnp.broadcast_to(win1, (n,) + win1.shape)
        row = {"lanes": int(n)}
        results = {}
        for name, fn in (("fused", fused), ("ref", ref)):
            t0 = time.time()
            r = jax.block_until_ready(fn(sc, win))
            row[f"{name}_compile_plus_run_s"] = round(time.time() - t0, 4)
            wall, r = _timed(lambda: jax.block_until_ready(fn(sc, win)),
                             reps=reps)
            row[f"{name}_run_s"] = round(wall, 5)
            results[name] = r
        row["bit_equal"] = all(
            bool(jnp.array_equal(a, b)) for a, b in
            zip(jax.tree_util.tree_leaves(results["fused"]),
                jax.tree_util.tree_leaves(results["ref"])))
        row["fused_vs_ref"] = round(
            row["ref_run_s"] / max(row["fused_run_s"], 1e-9), 2)
        out["widths"].append(row)
    return out


def run_roundstep_bench(argv) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.run roundstep")
    ap.add_argument("--lanes", type=int, nargs="+",
                    default=[1, 4, 16, 64], metavar="N",
                    help="vmapped lane counts to time")
    ap.add_argument("--out", default="results/BENCH_roundstep.json")
    args = ap.parse_args(argv)
    out = roundstep_benchmark(lane_widths=tuple(args.lanes))
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as fjson:
        json.dump(out, fjson, indent=1)
    for row in out["widths"]:
        print(f"lanes={row['lanes']} fused={row['fused_run_s']}s "
              f"ref={row['ref_run_s']}s ({row['fused_vs_ref']}x, "
              f"bit_equal={row['bit_equal']})")
    print(f"# interpret={out['interpret']} -> {args.out}")
    return 0 if all(r["bit_equal"] for r in out["widths"]) else 1


def live_benchmark(tiny: bool = False, serve_dt: float = 30.0) -> dict:
    """Live-vs-sim differential: replay WS traces as request traffic
    through the serving stack (``repro.serving.replay`` — autoscaler +
    VirtualReplica on the shared event pump) and diff the resulting
    decision ledger against the event simulator on the same workload,
    under ``CONTRACTS['live']``. Lanes: one paper-trace pair (NASA iPSC
    jobs + World Cup demand) and one synthesized ``synth_ws`` scenario
    lane. Returns the BENCH_live.json payload."""
    from repro.core.jobs import Job
    from repro.core.pbj_manager import PBJPolicyParams
    from repro.serving.replay import replay
    from repro.sim import scenarios as sc
    from repro.sim import traces
    from repro.sim.contracts import CONTRACTS, demand_drift
    from repro.sim.engine import build_fb, clone_jobs, run_sim
    from repro.sim.pump import DecisionLedger

    day = 24 * 3600.0
    horizon = day if tiny else 2 * day
    peak = 8 if tiny else 16
    capacity = 16 if tiny else 32
    ckpt = PBJPolicyParams(checkpoint_preempt=True)
    contract = CONTRACTS["live"]

    nasa = [Job(jid=i, submit=j.submit, size=min(j.size, capacity // 2),
                runtime=j.runtime)
            for i, j in enumerate(j for j in traces.nasa_ipsc(seed=0)
                                  if j.submit < horizon * 0.6)]
    nasa = nasa[:40 if tiny else 120]
    wc = traces.worldcup98(seed=0, peak_vms=peak, duration=horizon)
    grid = sc.ScenarioGrid(
        seeds=(5,),
        pbj=sc.PBJParams(nodes=float(capacity), utilization=0.45,
                         n_jobs=30.0 if tiny else 90.0),
        ws=sc.WSParams(peak=float(peak), base_mean=3.0),
        duration=horizon, max_jobs=200, ws_step=900.0)
    (sjobs, sws), = sc.sample_workloads(sc.synthesize(grid), [0])

    out = {"tiny": tiny, "horizon_s": horizon, "capacity": capacity,
           "serve_dt_s": serve_dt,
           "contract": {"node_hours_rel": contract.node_hours_rel,
                        "peak_rel": contract.peak_rel,
                        "completed_exact": contract.completed_exact,
                        "demand_mae_rel": contract.demand_mae_rel,
                        "demand_peak_rel": contract.demand_peak_rel},
           "lanes": []}
    for name, jobs, ws in (("nasa+worldcup", nasa, wc),
                           ("synth_ws", sjobs, sws)):
        led = DecisionLedger()
        wall_ref, ref = _timed(lambda: run_sim(
            build_fb(capacity, params=ckpt), clone_jobs(jobs), ws,
            duration=horizon, name="event", ledger=led), reps=1)
        wall_live, res = _timed(lambda: replay(
            clone_jobs(jobs), ws, capacity, duration=horizon,
            serve_dt=serve_dt), reps=1)
        violations = contract.check_live(
            res.row.row(), ref.row(), res.derived_demand,
            res.trace_demand, horizon)
        mae, dpeak = demand_drift(res.derived_demand, res.trace_demand,
                                  horizon)
        out["lanes"].append({
            "lane": name, "jobs": len(jobs), "ws_steps": len(ws),
            "event_wall_s": round(wall_ref, 3),
            "live_wall_s": round(wall_live, 3),
            "event": ref.row(), "live": res.row.row(),
            "requests_completed": res.requests_completed,
            "peak_instances": res.peak_instances,
            "ledger_events": len(res.ledger.entries),
            "demand_mae_rel": round(mae, 4),
            "demand_peak_rel": round(dpeak, 4),
            "contract_ok": not violations,
            "contract_violations": violations,
        })
    return out


def run_live_bench(argv) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.run live")
    ap.add_argument("--tiny", action="store_true",
                    help="one-day horizon, peak-8 traces (CI smoke)")
    ap.add_argument("--serve-dt", type=float, default=30.0, metavar="S",
                    help="serving tick of the replay layer (seconds)")
    ap.add_argument("--check-contract", action="store_true",
                    help="exit 1 unless every lane is inside "
                    "CONTRACTS['live']")
    ap.add_argument("--out", default="results/BENCH_live.json")
    args = ap.parse_args(argv)
    out = live_benchmark(tiny=args.tiny, serve_dt=args.serve_dt)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    rc = 0
    for lane in out["lanes"]:
        ev, lv = lane["event"], lane["live"]
        print(f"lane={lane['lane']} jobs={lane['jobs']} "
              f"completed={lv['completed_jobs']}/{ev['completed_jobs']} "
              f"node_hours={lv['node_hours']:.1f}/{ev['node_hours']:.1f} "
              f"demand_mae={lane['demand_mae_rel']} "
              f"requests={lane['requests_completed']} "
              f"walls: live={lane['live_wall_s']}s "
              f"event={lane['event_wall_s']}s "
              f"contract_ok={lane['contract_ok']}")
        if args.check_contract and not lane["contract_ok"]:
            print(f"LIVE GATE FAILED at lane {lane['lane']}: "
                  f"{lane['contract_violations']}", file=sys.stderr)
            rc = 1
    print(f"# -> {args.out}")
    return rc


def faults_benchmark(tiny: bool = False, serve_dt: float = 30.0) -> dict:
    """Chaos differential: throughput-vs-MTBF curves under deterministic
    fault schedules (``repro.sim.faults``), each schedule replayed
    through the repo's three execution paths and cross-checked:

      * event engine (plain kill mode) vs the rounds engine's
        time-varying-capacity fold (``fb_rounds_row``), under
        ``CONTRACTS['faults']`` (node-hours/peak 2 %, completions
        ±2-jobs-or-2 %);
      * event engine (checkpoint-preempt mode) vs a ``LiveCloud`` trace
        replay with ``inject_faults`` — both run the shared pump, so
        the decision ledgers must match entry for entry and completed
        jobs exactly;
      * the no-lost-jobs invariant on every event run — a failure may
        delay a job, never drop it.

    One serving-layer lane (autoscaler + ``GrantBackoff`` +
    admission-throttle shedding) runs at the shortest MTBF and reports
    its shed/retry counters (observability, not gated: the
    autoscaler-derived demand legitimately shifts kill victims)."""
    from repro.core.jobs import Job
    from repro.core.pbj_manager import PBJPolicyParams
    from repro.core.runtime_bridge import LiveCloud
    from repro.serving.replay import replay
    from repro.sim import traces
    from repro.sim.contracts import CONTRACTS, no_lost_jobs
    from repro.sim.engine import build_fb, clone_jobs, run_sim
    from repro.sim.faults import (burst_schedule, exponential_schedule,
                                  merge_schedules)
    from repro.sim.pump import DecisionLedger
    from repro.sim.rounds import fb_rounds_row

    day = 24 * 3600.0
    horizon = day if tiny else 2 * day
    capacity = 16 if tiny else 32
    lease = 3600.0
    mttr = 1800.0
    mtbf_hours = (4.0, 24.0) if tiny else (2.0, 6.0, 24.0, 96.0)
    ckpt = PBJPolicyParams(checkpoint_preempt=True)
    contract = CONTRACTS["faults"]

    jobs = [Job(jid=i, submit=j.submit, size=min(j.size, capacity // 2),
                runtime=j.runtime)
            for i, j in enumerate(j for j in traces.nasa_ipsc(seed=0)
                                  if j.submit < horizon * 0.6)]
    jobs = jobs[:40 if tiny else 120]
    ws = traces.worldcup98(seed=0, peak_vms=8 if tiny else 16,
                           duration=horizon)
    d0 = max((int(d) for t, d in ws if t <= 0), default=0)

    base_sys = build_fb(capacity, lease)
    base = run_sim(base_sys, clone_jobs(jobs), ws, duration=horizon,
                   name="event")
    out = {"tiny": tiny, "horizon_s": horizon, "capacity": capacity,
           "mttr_s": mttr, "jobs": len(jobs),
           "contract": {"completed_abs": contract.completed_abs,
                        "completed_rel": contract.completed_rel,
                        "node_hours_rel": contract.node_hours_rel,
                        "peak_rel": contract.peak_rel},
           "baseline_no_faults": base.row(), "lanes": []}

    for mh in mtbf_hours:
        sched = merge_schedules(
            exponential_schedule(seed=7, n_nodes=capacity // 2,
                                 mtbf=mh * 3600.0, mttr=mttr,
                                 duration=horizon),
            burst_schedule(seed=11, k=max(1, capacity // 4),
                           mtbf=4 * mh * 3600.0, mttr=2 * mttr,
                           duration=horizon))
        # Event reference (plain §5.1 kill mode) + kill/shed ledger.
        ev_sys = build_fb(capacity, lease)
        ev_jobs = clone_jobs(jobs)
        led = DecisionLedger()
        wall_ev, ev = _timed(lambda: run_sim(
            ev_sys, ev_jobs, ws, duration=horizon, name="event",
            ledger=led, faults=sched), reps=1)
        lost = no_lost_jobs(ev_jobs, ev_sys)
        # Rounds engine: fault instants folded into the horizon min,
        # capacity time-varying.
        wall_rr, rr = _timed(lambda: fb_rounds_row(
            jobs, ws, capacity, lease, horizon, faults=sched), reps=1)
        violations = contract.check_row(rr, ev.row())
        # Checkpoint-restart recovery: event(ckpt) vs LiveCloud trace
        # replay of the same schedule — one pump, exact ledgers.
        ck_led = DecisionLedger()
        ck_sys = build_fb(capacity, lease, params=ckpt)
        ck_jobs = clone_jobs(jobs)
        ck = run_sim(ck_sys, ck_jobs, ws, duration=horizon,
                     name="event_ckpt", ledger=ck_led, faults=sched)
        cloud = LiveCloud(capacity, lease_seconds=lease,
                          duration=horizon, ws_initial=d0)
        cloud.load_trace(clone_jobs(jobs), ws_trace=ws, lease_ticks=True)
        cloud.inject_faults(sched)
        cloud.run_until(horizon)
        from repro.sim.engine import summarize
        live = summarize(cloud.service, [], horizon, "live")
        live_exact = (cloud.ledger.entries == ck_led.entries
                      and live.node_hours == ck.node_hours)
        counts = led.counts()
        out["lanes"].append({
            "mtbf_h": mh, "schedule_events": len(sched),
            "max_concurrent_failed": sched.max_concurrent(),
            "event": ev.row(), "rounds": rr,
            "event_ckpt": ck.row(),
            "event_wall_s": round(wall_ev, 3),
            "rounds_wall_s": round(wall_rr, 3),
            "policy_kills": counts["kills"] - counts["failure_kills"],
            "failure_kills": counts["failure_kills"],
            "sheds": counts["sheds"],
            "throughput_vs_baseline": round(
                ev.completed_jobs / max(1, base.completed_jobs), 4),
            "no_lost_jobs": not lost, "lost": lost,
            "live_ledger_exact": live_exact,
            "contract_ok": not violations,
            "contract_violations": violations,
        })

    # Serving-layer chaos lane: autoscaler-driven replay with admission
    # shedding and bounded grant-retry backoff (observability only).
    sched = merge_schedules(
        exponential_schedule(seed=7, n_nodes=capacity // 2,
                             mtbf=mtbf_hours[0] * 3600.0, mttr=mttr,
                             duration=horizon),
        burst_schedule(seed=11, k=max(1, capacity // 4),
                       mtbf=4 * mtbf_hours[0] * 3600.0, mttr=2 * mttr,
                       duration=horizon))
    res = replay(clone_jobs(jobs), ws, capacity, duration=horizon,
                 serve_dt=serve_dt, faults=sched, max_queue=64)
    out["serving"] = {
        "mtbf_h": mtbf_hours[0],
        "live": res.row.row(),
        "requests_completed": res.requests_completed,
        "shed_requests": res.shed_requests,
        "grant_retries": res.grant_retries,
        "failure_kills": res.ledger.kills("fail"),
        "sheds": res.ledger.sheds(),
    }
    return out


def run_faults_bench(argv) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.run faults")
    ap.add_argument("--tiny", action="store_true",
                    help="one-day horizon, capacity 16, 2 MTBF points "
                    "(CI smoke)")
    ap.add_argument("--serve-dt", type=float, default=30.0, metavar="S",
                    help="serving tick of the chaos serving lane")
    ap.add_argument("--check-contract", action="store_true",
                    help="exit 1 on any CONTRACTS['faults'] violation, "
                    "lost job, or live-vs-event ledger mismatch")
    ap.add_argument("--out", default="results/BENCH_faults.json")
    args = ap.parse_args(argv)
    out = faults_benchmark(tiny=args.tiny, serve_dt=args.serve_dt)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    rc = 0
    base = out["baseline_no_faults"]["completed_jobs"]
    print(f"baseline (no faults): completed={base}")
    for lane in out["lanes"]:
        ev, rr = lane["event"], lane["rounds"]
        print(f"mtbf={lane['mtbf_h']}h events={lane['schedule_events']} "
              f"completed ev/rounds={ev['completed_jobs']}/"
              f"{rr['completed_jobs']} "
              f"throughput_vs_base={lane['throughput_vs_baseline']} "
              f"kills={lane['policy_kills']}+{lane['failure_kills']}f "
              f"sheds={lane['sheds']} "
              f"live_exact={lane['live_ledger_exact']} "
              f"no_lost={lane['no_lost_jobs']} "
              f"contract_ok={lane['contract_ok']}")
        if args.check_contract and not (
                lane["contract_ok"] and lane["no_lost_jobs"]
                and lane["live_ledger_exact"]):
            print(f"FAULTS GATE FAILED at mtbf={lane['mtbf_h']}h: "
                  f"{lane['contract_violations'] or lane['lost'] or 'live ledger mismatch'}",
                  file=sys.stderr)
            rc = 1
    sv = out["serving"]
    print(f"serving lane: requests={sv['requests_completed']} "
          f"shed_requests={sv['shed_requests']} "
          f"grant_retries={sv['grant_retries']} "
          f"failure_kills={sv['failure_kills']}")
    print(f"# -> {args.out}")
    return rc


def capacity_benchmark(tiny: bool = False, devices: int = 0) -> dict:
    """The capacity query layer (``repro.sim.capacity``) measured
    against brute force: batched min-C bisection vs a full grid scan
    (same answer, far fewer sweep rows), a Pareto frontier over a
    (C, B, L) policy grid with its invariants re-checked by a direct
    O(n²) pass, the multi-cloud cost lens over that frontier, and the
    §6 headline queries. Returns the BENCH_capacity.json payload."""
    from repro import compat
    from repro.sim import traces
    from repro.sim.capacity import (CapacitySLO, CostModel, _with_capacity,
                                    min_capacity, pareto_front,
                                    headline_queries)
    from repro.sim.sweep import SweepPoint, run_sweep_workloads

    if devices:
        compat.resolve_devices(devices)
    dev = devices if devices >= 2 else None

    if tiny:
        horizon = 2 * 24 * 3600.0
        jobs = [j for j in traces.nasa_ipsc(seed=0) if j.submit < horizon]
        ws = [(t, d) for t, d in traces.worldcup98(seed=0, peak_vms=64)
              if t < horizon]
        workloads = [(jobs, ws)]
        lo, hi = 1, 128
        slo = CapacitySLO(min_completed_frac=0.9)
        pareto_caps, pareto_Bs = (32, 64, 96, 128), (13, 25)
    else:
        horizon = traces.TWO_WEEKS
        workloads = [
            (traces.nasa_ipsc(seed=0),
             traces.worldcup98(seed=0, peak_vms=128)),
            (traces.sdsc_blue(seed=0),
             traces.worldcup98(seed=1, peak_vms=128)),
        ]
        lo, hi = 1, 256
        slo = CapacitySLO(min_completed_frac=0.95)
        pareto_caps, pareto_Bs = (128, 154, 192, 230, 256), (13, 25, 51)
    # Two policy lanes per workload: the paper's hourly lease and a
    # 30-minute variant — bisected jointly, one batch per iteration.
    templates = [SweepPoint("fb"),
                 SweepPoint("fb", lease_seconds=1800.0)]
    n_jobs = [len(j) for j, _ in workloads]

    out = {"tiny": tiny, "devices": devices,
           "slo": {"min_completed_frac": slo.min_completed_frac},
           "grid": {"lo": lo, "hi": hi,
                    "templates": len(templates),
                    "workloads": len(workloads)}}

    # --- min_capacity vs brute force -------------------------------
    def bisect():
        import warnings as _w
        with _w.catch_warnings():
            # Bisection legitimately probes degenerate capacities
            # (C=1 overflows any window); the diagnostics are not
            # news here.
            _w.simplefilter("ignore", RuntimeWarning)
            return min_capacity(templates, workloads, slo, lo=lo, hi=hi,
                                duration=horizon, mode="rounds",
                                devices=dev)

    report = bisect()                   # warm the jit caches
    query_wall, report = _timed(bisect, reps=2)

    grid_points = [_with_capacity(t, c)
                   for t in templates for c in range(lo, hi + 1)]

    def brute():
        import warnings as _w
        with _w.catch_warnings():
            _w.simplefilter("ignore", RuntimeWarning)
            return run_sweep_workloads(grid_points, workloads, horizon,
                                       mode="rounds", devices=dev)
    brute_wall, brute_rows = _timed(brute, reps=2)

    span = hi - lo + 1
    lanes = []
    all_match = all_props = True
    for r in report.results:
        base = r.template_index * span
        rows_w = brute_rows[r.workload]
        feas = [c for k, c in enumerate(range(lo, hi + 1))
                if slo.satisfied(rows_w[base + k], n_jobs[r.workload])]
        brute_argmin = feas[0] if feas else None
        match = brute_argmin == r.capacity
        prop = (slo.satisfied(rows_w[base + (r.capacity - lo)],
                              n_jobs[r.workload])
                and (r.capacity == lo
                     or not slo.satisfied(
                         rows_w[base + (r.capacity - lo - 1)],
                         n_jobs[r.workload])))
        all_match &= match
        all_props &= prop
        lanes.append({
            "template": f"{r.point.name()}@L="
                        f"{r.template.lease_seconds:g}s",
            "workload": r.workload,
            "capacity": r.capacity,
            "completed": int(r.row["completed_jobs"]),
            "target": slo.target_completed(n_jobs[r.workload]),
            "at_grid_edge": r.at_grid_edge,
            "brute_argmin": brute_argmin, "match": match,
            "property_ok": prop})
    out["min_capacity"] = {
        "wall_s": round(query_wall, 4),
        "brute_wall_s": round(brute_wall, 4),
        "iterations": report.iterations,
        "rows_evaluated": report.rows_evaluated,
        "brute_force_rows": report.brute_force_rows,
        "eval_savings_x": round(report.brute_force_rows
                                / max(1, report.rows_evaluated), 2),
        "lanes": lanes,
        "matches_bruteforce": all_match,
        "property_ok": all_props,
    }

    # --- Pareto frontier over a (C, B, L) policy grid --------------
    ppoints = (
        [SweepPoint("fb", capacity=c, label=f"FB(C={c})")
         for c in pareto_caps]
        + [SweepPoint("flb_nub", lb_pbj=B - min(12, B - 1),
                      lb_ws=min(12, B - 1), label=f"FLB-NUB(B={B})")
           for B in pareto_Bs]
        + [SweepPoint("flb_nub", lb_pbj=13, lb_ws=12,
                      lease_seconds=1800.0, label="FLB-NUB(L=30min)")])
    jobs0, ws0 = workloads[0]

    def front_fn():
        return pareto_front(ppoints, jobs0, ws0, duration=horizon,
                            mode="rounds", devices=dev)
    front = front_fn()
    pareto_wall, front = _timed(front_fn, reps=2)

    # Direct O(n²) re-check of the frontier invariants.
    sense = {"node_hours": 1, "peak_nodes": 1, "completed_jobs": -1}

    def dominates(a, b):
        vals = [(sense[m] * a.row[m], sense[m] * b.row[m])
                for m in front.objectives]
        return (all(x <= y for x, y in vals)
                and any(x < y for x, y in vals))
    nondominated_ok = not any(
        dominates(q, p) for p in front.frontier_points()
        for q in front.points)
    complete_ok = all(
        (p.index in front.frontier)
        or (p.dominated_by is not None
            and dominates(front.points[p.dominated_by], p))
        for p in front.points)
    out["pareto"] = {
        "wall_s": round(pareto_wall, 4),
        "grid_points": len(ppoints),
        "objectives": list(front.objectives),
        "frontier": [{
            "point": front.points[i].point.label or
            front.points[i].point.name(),
            "node_hours": round(float(front.points[i].row["node_hours"]),
                                1),
            "peak_nodes": int(front.points[i].row["peak_nodes"]),
            "completed_jobs": int(front.points[i].row["completed_jobs"]),
        } for i in front.frontier],
        "nondominated_ok": nondominated_ok,
        "complete_ok": complete_ok,
    }

    # --- cost lens over the frontier -------------------------------
    cm = CostModel()
    mix = front.frontier_rows()
    comp = cm.compare(mix)
    out["cost"] = {
        "providers": [{"name": p.name,
                       "node_hour_usd": p.node_hour_usd,
                       "request_usd": p.request_usd}
                      for p in cm.providers],
        "frontier_mix": [{
            "provider": e.provider,
            "node_cost_usd": round(e.node_cost_usd, 2),
            "request_cost_usd": round(e.request_cost_usd, 2),
            "total_usd": round(e.total_usd, 2)} for e in comp],
        "cheapest_provider": comp[0].provider,
    }

    # --- the paper's §6 numbers as query outputs -------------------
    t0 = time.time()
    out["headline"] = headline_queries(tiny=tiny, mode="rounds",
                                       devices=dev)
    out["headline_wall_s"] = round(time.time() - t0, 4)
    return out


def run_capacity_bench(argv) -> int:
    ap = argparse.ArgumentParser(prog="benchmarks.run capacity")
    ap.add_argument("--tiny", action="store_true",
                    help="two-day trace slice, 128-wide capacity "
                    "interval (CI smoke)")
    ap.add_argument("--devices", type=int, default=0, metavar="N",
                    help="shard the batched bisection/grid lanes over "
                    "N host devices (forces N XLA CPU devices when jax "
                    "is not yet loaded)")
    ap.add_argument("--check-contract", action="store_true",
                    help="exit 1 unless the bisection matches the "
                    "brute-force argmin on every lane, the feasible/"
                    "predecessor-infeasible property holds, and the "
                    "Pareto frontier passes the direct non-domination/"
                    "completeness re-check; implies --check-fidelity")
    ap.add_argument("--check-fidelity", action="store_true",
                    help="exit 1 if the §6 headline numbers fall "
                    "outside CONTRACTS['queries'] bands (full-size "
                    "runs; tiny runs only assert the queries executed)")
    ap.add_argument("--out", default="results/BENCH_capacity.json")
    args = ap.parse_args(argv)
    if args.devices >= 2:
        from repro.hostdev import force_host_device_count
        force_host_device_count(args.devices)
    out = capacity_benchmark(tiny=args.tiny, devices=args.devices)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)

    mc, pa, hl = out["min_capacity"], out["pareto"], out["headline"]
    print(f"min_capacity: wall={mc['wall_s']}s over "
          f"{mc['rows_evaluated']} rows in {mc['iterations']} batches "
          f"(brute force: {mc['brute_wall_s']}s over "
          f"{mc['brute_force_rows']} rows — {mc['eval_savings_x']}x "
          f"fewer evals) matches_bruteforce={mc['matches_bruteforce']} "
          f"property_ok={mc['property_ok']}")
    for lane in mc["lanes"]:
        print(f"  {lane['template']} x wl{lane['workload']}: minC="
              f"{lane['capacity']} (brute {lane['brute_argmin']}) "
              f"completed={lane['completed']}>={lane['target']}")
    print(f"pareto: wall={pa['wall_s']}s grid={pa['grid_points']} "
          f"frontier={len(pa['frontier'])} "
          f"nondominated_ok={pa['nondominated_ok']} "
          f"complete_ok={pa['complete_ok']}")
    print(f"cost: cheapest={out['cost']['cheapest_provider']} for the "
          f"frontier mix")
    priv, pub, gate = hl["private"], hl["public"], hl["gate"]
    print(f"headline: config_reduction={priv['config_reduction']} "
          f"(minC={priv['min_fb_capacity']} of DCS {priv['dcs_size']}) "
          f"peak_reduction={pub['peak_reduction']} "
          f"(FLB {pub['flb_peak']} vs EC2 {pub['ec2_peak']}) "
          f"gate_checked={gate['checked']} ok={gate['ok']}")
    print(f"# -> {args.out}")

    rc = 0
    if args.check_contract:
        if not (mc["matches_bruteforce"] and mc["property_ok"]):
            print("CAPACITY GATE FAILED: bisection disagrees with "
                  "brute force", file=sys.stderr)
            rc = 1
        if not (pa["nondominated_ok"] and pa["complete_ok"]):
            print("CAPACITY GATE FAILED: Pareto invariants",
                  file=sys.stderr)
            rc = 1
    if args.check_fidelity or args.check_contract:
        if gate["checked"] and not gate["ok"]:
            print(f"HEADLINE GATE FAILED: {gate['violations']}",
                  file=sys.stderr)
            rc = 1
        if not gate["checked"] and not args.tiny:
            print("HEADLINE GATE FAILED: gate did not run",
                  file=sys.stderr)
            rc = 1
    return rc


def main() -> int:
    """The full paper-table run: every ``ALL_TABLES`` entry plus the
    roofline table, dumped to ``results/tables.json``.

    One table crashing must not cost the artifact (the old behavior: an
    exception anywhere killed the run before the single write at the
    end, which is why no ``tables.json`` ever landed) — failures are
    caught per table, recorded under ``_errors`` in the artifact, and
    turn the exit code nonzero; the artifact itself is written
    atomically (tmp + rename) and a write failure is also nonzero.
    """
    # Deferred so `sweep --devices N` can set XLA_FLAGS first.
    from benchmarks.tables import ALL_TABLES
    from benchmarks import roofline
    os.makedirs("results", exist_ok=True)
    all_rows = {}
    errors = {}
    print("name,us_per_call,derived")
    for name, fn in ALL_TABLES.items():
        t0 = time.time()
        try:
            rows = fn()
        except Exception as e:
            errors[name] = f"{type(e).__name__}: {e}"
            print(f"{name},failed,{errors[name]}", flush=True)
            continue
        dt_us = (time.time() - t0) * 1e6
        all_rows[name] = rows
        print(f"{name},{dt_us:.0f},{_derived(name, rows)}", flush=True)
    # Roofline table from the dry-run artifacts.
    t0 = time.time()
    try:
        roof = roofline.roofline_rows("singlepod")
        all_rows["roofline"] = roof
        ok = [r for r in roof if r.get("status") == "ok"]
        frac = [r["roofline_fraction"] for r in ok
                if r.get("roofline_fraction")]
        derived = (f"cells={len(ok)};median_fraction="
                   f"{sorted(frac)[len(frac)//2] if frac else 'n/a'}")
        print(f"roofline,{(time.time()-t0)*1e6:.0f},{derived}")
    except Exception as e:
        errors["roofline"] = f"{type(e).__name__}: {e}"
        print(f"roofline,failed,{errors['roofline']}", flush=True)
    if errors:
        all_rows["_errors"] = errors
    out_path = "results/tables.json"
    try:
        tmp = out_path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(all_rows, f, indent=1)
        os.replace(tmp, out_path)
    except OSError as e:
        print(f"FAILED to write {out_path}: {e}", file=sys.stderr)
        return 1
    n_rows = sum(len(v) for k, v in all_rows.items() if k != "_errors")
    print(f"# full tables -> {out_path} ({n_rows} rows)")
    if errors:
        print(f"TABLES FAILED: {sorted(errors)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    if len(sys.argv) > 1 and sys.argv[1] == "sweep":
        sys.exit(run_sweep_bench(sys.argv[2:]))
    if len(sys.argv) > 1 and sys.argv[1] == "roundstep":
        sys.exit(run_roundstep_bench(sys.argv[2:]))
    if len(sys.argv) > 1 and sys.argv[1] == "scenarios":
        sys.exit(run_scenarios_bench(sys.argv[2:]))
    if len(sys.argv) > 1 and sys.argv[1] == "live":
        sys.exit(run_live_bench(sys.argv[2:]))
    if len(sys.argv) > 1 and sys.argv[1] == "faults":
        sys.exit(run_faults_bench(sys.argv[2:]))
    if len(sys.argv) > 1 and sys.argv[1] == "capacity":
        sys.exit(run_capacity_bench(sys.argv[2:]))
    sys.exit(main())
