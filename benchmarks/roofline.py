"""Roofline table (deliverable g) — reads the dry-run artifacts.

Prints per (arch × shape) the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS, and the roofline fraction, from
``results/dryrun_singlepod.json`` (the single-pod mesh, per assignment).
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "results")


def load(tag: str = "singlepod") -> List[Dict]:
    path = os.path.join(RESULTS, f"dryrun_{tag}.json")
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def roofline_rows(tag: str = "singlepod") -> List[Dict]:
    rows = []
    for r in sorted(load(tag), key=lambda r: (r["arch"], r["shape"])):
        if r["status"] != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": r["status"],
                         "reason": r.get("reason", "")[:60]})
            continue
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "t_compute_ms": round(1e3 * r["t_compute_s"], 3),
            "t_memory_ms": round(1e3 * r["t_memory_s"], 3),
            "t_collective_ms": round(1e3 * r["t_collective_s"], 3),
            "bottleneck": r["bottleneck"],
            "useful_ratio": round(r["useful_ratio"], 3)
            if r.get("useful_ratio") else None,
            "roofline_fraction": round(r["roofline_fraction"], 4),
            "mem_gb_per_dev": round(r["mem_per_device_gb"], 2)
            if r.get("mem_per_device_gb") else None,
        })
    return rows


def print_table(tag: str = "singlepod") -> List[Dict]:
    rows = roofline_rows(tag)
    if not rows:
        print(f"(no dry-run results for {tag}; run "
              f"`python -m repro.launch.dryrun` first)")
        return rows
    hdr = ("arch", "shape", "t_compute_ms", "t_memory_ms",
           "t_collective_ms", "bottleneck", "useful_ratio",
           "roofline_fraction", "mem_gb_per_dev")
    print(",".join(hdr))
    for r in rows:
        if r["status"] != "ok":
            print(f'{r["arch"]},{r["shape"]},SKIP/{r["status"]}')
            continue
        print(",".join(str(r.get(k, "")) for k in hdr))
    return rows


if __name__ == "__main__":
    import sys
    print_table(sys.argv[1] if len(sys.argv) > 1 else "singlepod")
