"""Benchmark functions — one per paper table/figure (§6).

Every function regenerates its artifact with the synthetic moment-matched
traces and returns a list of row dicts; ``run.py`` times each and prints
the ``name,us_per_call,derived`` CSV plus the full tables to
results/tables.json.
"""

from __future__ import annotations

import time
from typing import Dict, List

from repro.core.pbj_manager import PBJPolicyParams
from repro.sim import traces
from repro.sim.engine import (build_dcs, build_ec2_rightscale, build_fb,
                              build_flb_nub, clone_jobs, run_sim)

T = traces.TWO_WEEKS
SEED = 0


def _workload(name: str, prc_pbj: int, prc0: int):
    jobs = traces.nasa_ipsc(SEED) if name == "ipsc" else traces.sdsc_blue(SEED)
    if prc_pbj != prc0:
        jobs = traces.scale_jobs(jobs, prc_pbj, prc0)
    return jobs


def _ws(prc_ws: int):
    return traces.worldcup98(SEED, peak_vms=prc_ws)


_PRC0 = {"ipsc": 128, "blue": 144}


def _row(r, **extra) -> Dict:
    d = r.row()
    d.update(extra)
    return d


# ---------------------------------------------------------------- Tables 1–2

def table_1_2() -> List[Dict]:
    """DCS vs PhoenixCloud-FB at shrinking configuration sizes (§6.5.3)."""
    out = []
    for trace in ("ipsc", "blue"):
        prc0 = _PRC0[trace]
        jobs, ws = _workload(trace, prc0, prc0), _ws(128)
        dcs_size = prc0 + 128
        out.append(_row(run_sim(build_dcs(prc0, 128), clone_jobs(jobs), ws,
                                T, name=f"DCS({dcs_size})"), trace=trace,
                        config_size=dcs_size))
        for frac in (prc0 / dcs_size, 0.6, 0.75, 1.0):
            c = int(round(dcs_size * frac))
            out.append(_row(run_sim(build_fb(c), clone_jobs(jobs), ws, T,
                                    name=f"PhoenixCloud({c})"),
                            trace=trace, config_size=c))
    return out


# ---------------------------------------------------------------- Tables 3–4

def table_3_4() -> List[Dict]:
    """FB with varying PRC_WS/PRC_PBJ ratios (§6.5.3): saved resources
    peak when the two peak demands are close."""
    out = []
    for trace in ("ipsc", "blue"):
        prc0 = _PRC0[trace]
        for prc_ws in (64, 128, 256):
            jobs, ws = _workload(trace, prc0, prc0), _ws(prc_ws)
            c = max(prc0, prc_ws)       # smallest valid configuration
            r = run_sim(build_fb(c), clone_jobs(jobs), ws, T,
                        name=f"FB({prc0},{prc_ws})->{c}")
            saving = 1 - c / (prc0 + prc_ws)
            out.append(_row(r, trace=trace, prc_ws=prc_ws, config_size=c,
                            saved_resources_pct=round(100 * saving, 1)))
    return out


# ---------------------------------------------------------------- Tables 5–6

def _baseline_params():
    return PBJPolicyParams(request_threshold=1.2, release_threshold=0.2,
                           elastic_factor=0.5)


def table_5_6() -> List[Dict]:
    """EC2+RightScale vs PhoenixCloud FLB-NUB (§6.6.3), baseline params
    [B25/U1.2/V0.2/G0.5/L60] (iPSC) and [B27/...] (BLUE)."""
    out = []
    for trace, B in (("ipsc", 25), ("blue", 27)):
        prc0 = _PRC0[trace]
        jobs, ws = _workload(trace, prc0, prc0), _ws(128)
        ec2 = run_sim(build_ec2_rightscale(), clone_jobs(jobs), ws, T,
                      name="EC2+RightScale")
        pc = run_sim(build_flb_nub(B - 12, 12, params=_baseline_params()),
                     clone_jobs(jobs), ws, T, name=f"PhoenixCloud(B{B})")
        out.append(_row(ec2, trace=trace))
        out.append(_row(pc, trace=trace,
                        total_vs_ec2=round(pc.node_hours / ec2.node_hours, 3),
                        peak_vs_ec2=round(pc.peak_nodes / ec2.peak_nodes, 3)))
    return out


# ---------------------------------------------------------------- Tables 7–8

def table_7_8() -> List[Dict]:
    """FLB-NUB with varying PRC_WS (§6.6.3), BR0.1 rule for B."""
    out = []
    for trace in ("ipsc", "blue"):
        prc0 = _PRC0[trace]
        for prc_ws in (64, 128, 256):
            jobs, ws = _workload(trace, prc0, prc0), _ws(prc_ws)
            B = max(2, int(0.1 * (prc0 + prc_ws)))
            lb_ws = min(12, B - 1)
            r = run_sim(build_flb_nub(B - lb_ws, lb_ws,
                                      params=_baseline_params()),
                        clone_jobs(jobs), ws, T,
                        name=f"FLB-NUB({prc0},{prc_ws})")
            ideal = (prc0 + prc_ws) * T / 3600
            out.append(_row(r, trace=trace, prc_ws=prc_ws, B=B,
                            saved_resources_pct=round(
                                100 * (1 - r.node_hours / ideal), 1)))
    return out


# ------------------------------------------------------------- Figs 14–15: B

def fig_14_15() -> List[Dict]:
    """Effect of the coordinated-pool size B (§6.6.4, J1/J2)."""
    out = []
    for trace in ("ipsc", "blue"):
        prc0 = _PRC0[trace]
        jobs, ws = _workload(trace, prc0, prc0), _ws(128)
        for B in (13, 25, 51, 102, 154):
            lb_ws = min(12, B - 1)
            r = run_sim(build_flb_nub(B - lb_ws, lb_ws,
                                      params=_baseline_params()),
                        clone_jobs(jobs), ws, T, name=f"B={B}")
            out.append(_row(r, trace=trace, B=B))
    return out


# --------------------------------------------------------- Figs 16–17: U,V,G

def fig_16_17() -> List[Dict]:
    """Effect of U (request), V (release), G (elastic factor) (§6.6.4)."""
    out = []
    for trace, B in (("ipsc", 25), ("blue", 27)):
        prc0 = _PRC0[trace]
        jobs, ws = _workload(trace, prc0, prc0), _ws(128)
        base = dict(request_threshold=1.2, release_threshold=0.2,
                    elastic_factor=0.5)
        sweeps = [("U", "request_threshold", (1.0, 1.2, 1.5, 2.0)),
                  ("V", "release_threshold", (0.1, 0.2, 0.5)),
                  ("G", "elastic_factor", (0.25, 0.5, 0.99))]
        for label, field, values in sweeps:
            for v in values:
                params = PBJPolicyParams(**{**base, field: v})
                r = run_sim(build_flb_nub(B - 12, 12, params=params),
                            clone_jobs(jobs), ws, T,
                            name=f"{label}={v}")
                out.append(_row(r, trace=trace, param=label, value=v))
    return out


# ---------------------------------------------------------------- Fig 18: L

def fig_18() -> List[Dict]:
    """Management overhead vs the lease time unit L (§6.6.4)."""
    out = []
    for trace, B in (("ipsc", 25), ("blue", 27)):
        prc0 = _PRC0[trace]
        jobs, ws = _workload(trace, prc0, prc0), _ws(128)
        for minutes in (15, 30, 60, 120, 240):
            r = run_sim(build_flb_nub(B - 12, 12, lease_seconds=60 * minutes,
                                      params=_baseline_params()),
                        clone_jobs(jobs), ws, T, name=f"L={minutes}min")
            out.append(_row(r, trace=trace, lease_minutes=minutes))
    return out


# ------------------------------------------- Figs 8–9: serving calibration

def fig_8_9() -> List[Dict]:
    """The §6.4 live experiment, miniaturized: throughput and utilization
    vs replica count on the real serving engine (reduced smollm)."""
    import numpy as np
    from repro.configs.base import get_config, reduced_config
    from repro.launch.mesh import make_local_mesh
    from repro.serving.engine import Replica, Request

    cfg = reduced_config(get_config("smollm_135m"))
    mesh = make_local_mesh()
    out = []
    params = None
    for n_replicas in (1, 2, 4):
        reps = []
        for _ in range(n_replicas):
            r = Replica(cfg, mesh, slots=4, max_len=48, params=params)
            params = r.params
            reps.append(r)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i, prompt=rng.integers(
            0, cfg.vocab, 8).astype(np.int32), max_new_tokens=8)
            for i in range(4 * n_replicas * 2)]
        t0 = time.time()
        done = 0
        utils = []
        while reqs or any(r.n_active for r in reps):
            for r in reps:
                while reqs and r.free_slot() is not None:
                    r.admit(reqs.pop(0))
            utils.append(sum(r.n_active for r in reps)
                         / sum(r.slots for r in reps))
            for r in reps:
                done += len(r.step())
        dt = time.time() - t0
        out.append({"replicas": n_replicas, "completed": done,
                    "tokens_per_s": round(done * 8 / dt, 1),
                    "avg_utilization": round(float(np.mean(utils)), 3)})
    return out


# -------------------------------------------- beyond-paper: preempt ablation

def ablation_preempt() -> List[Dict]:
    """Kill-restart (paper-faithful) vs checkpoint-preempt (ours)."""
    out = []
    for trace in ("ipsc", "blue"):
        prc0 = _PRC0[trace]
        jobs, ws = _workload(trace, prc0, prc0), _ws(128)
        for mode, params in (("kill", PBJPolicyParams()),
                             ("checkpoint",
                              PBJPolicyParams(checkpoint_preempt=True))):
            r = run_sim(build_fb(int((prc0 + 128) * 0.6), params=params),
                        clone_jobs(jobs), ws, T, name=f"FB-{mode}")
            out.append(_row(r, trace=trace, mode=mode))
    return out


ALL_TABLES = {
    "table_1_2": table_1_2,
    "table_3_4": table_3_4,
    "table_5_6": table_5_6,
    "table_7_8": table_7_8,
    "fig_14_15": fig_14_15,
    "fig_16_17": fig_16_17,
    "fig_18": fig_18,
    "fig_8_9": fig_8_9,
    "ablation_preempt": ablation_preempt,
}


# ------------------------------ Figs 13/14/18: the unified sweep engine

def sweep_fig_13_14_18() -> List[Dict]:
    """The paper's three headline sweeps — capacity C (Fig. 13), pool
    size B (Fig. 14), lease unit L vs EC2+RightScale (Fig. 18) — as ONE
    ``run_sweep`` call per trace (21 points each): DCS and EC2 points go
    through the vectorized jnp fast path, the two stateful PhoenixCloud
    policies through the event engine."""
    from repro.sim.sweep import paper_grid, run_sweep
    out = []
    for trace in ("ipsc", "blue"):
        prc0 = _PRC0[trace]
        jobs, ws = _workload(trace, prc0, prc0), _ws(128)
        for row in run_sweep(paper_grid(prc0, 128,
                                        params=_baseline_params()),
                             jobs, ws, T):
            row["trace"] = trace
            out.append(row)
    return out


ALL_TABLES["sweep_fig_13_14_18"] = sweep_fig_13_14_18


# ------------------------------------- beyond-paper: vmapped param sweep

def jaxsim_sweep() -> List[Dict]:
    """§6.6.4 (B/U/V/G study) as ONE vmapped jax.lax.scan program
    (core/jaxsim.py) — 12 two-week FLB-NUB configurations batched."""
    from repro.core import jaxsim
    jobs = traces.nasa_ipsc(SEED)
    ws = traces.worldcup98(SEED, peak_vms=128)
    grid = ([{"B": b, "U": 1.2, "V": 0.2, "G": 0.5}
             for b in (13, 25, 51, 102, 154)]
            + [{"B": 25, "U": u, "V": 0.2, "G": 0.5} for u in (1.0, 1.5, 2.0)]
            + [{"B": 25, "U": 1.2, "V": v, "G": 0.5} for v in (0.1, 0.5)]
            + [{"B": 25, "U": 1.2, "V": 0.2, "G": g} for g in (0.25, 0.99)])
    return jaxsim.sweep(grid, jobs, ws, T)


ALL_TABLES["jaxsim_sweep"] = jaxsim_sweep
