"""PhoenixCloud on TPU — coordinated provisioning for heterogeneous ML
workloads (Zhan et al., 2010), as a multi-pod JAX framework.

Public surface:
  repro.core     — the paper (RE specs, CSF, FB/FLB-NUB, TRE managers)
  repro.sim      — trace-driven evaluation (paper §6)
  repro.configs  — the 10 assigned architectures (get_config / ARCH_IDS)
  repro.models   — composable model assembly (Model)
  repro.kernels  — Pallas TPU kernels (flash attention/decode, SSD)
  repro.train    — optimizer/data/checkpoint/compression/trainer
  repro.serving  — continuous-batching engine + autoscaler
  repro.launch   — production mesh, dry-run, CLIs
"""

__version__ = "1.0.0"
