"""smollm-135m [dense] — llama-arch small, GQA 9q/3kv.
[hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm_135m", family="dense", source="hf:HuggingFaceTB/SmolLM-135M",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536,
    vocab=49152, head_dim=64,
    rope_theta=10000.0,
    microbatch=64, train_chips=1, serve_chips_per_replica=1,
)
