"""granite-moe-3b-a800m [moe] — 40 experts top-8, per-expert d_ff=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite_moe_3b", family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab=49155, head_dim=64,
    n_experts=40, experts_per_token=8,
    microbatch=32, train_chips=8, serve_chips_per_replica=1,
)
