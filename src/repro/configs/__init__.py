from repro.configs.base import (ARCH_IDS, ArchConfig, LayerSpec, ShapeSpec,
                                all_configs, get_config, reduced_config)

__all__ = ["ARCH_IDS", "ArchConfig", "LayerSpec", "ShapeSpec",
           "all_configs", "get_config", "reduced_config"]
