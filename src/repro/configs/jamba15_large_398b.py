"""jamba-1.5-large-398b [hybrid] — Mamba+attention 1:7 interleave, MoE 16e
top-2 on every other layer. Mamba layers use Mamba2/SSD blocks (the
assignment pairs this arch with the SSD formulation; deviation from
Jamba's Mamba1 documented in DESIGN.md). [arXiv:2403.19887; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba15_large_398b", family="hybrid",
    source="arXiv:2403.19887; hf",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab=65536, head_dim=128,
    n_experts=16, experts_per_token=2, moe_period=2, moe_offset=1,
    attn_period=8, attn_offset=3,          # 1 attention layer per 8 (1:7)
    ssm_state=128, ssm_expand=2, ssm_head_dim=128,
    optimizer="adafactor", microbatch=8,
    train_chips=256, serve_chips_per_replica=64,
)
