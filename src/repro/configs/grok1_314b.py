"""grok-1-314b [moe] — 8 experts top-2. [hf:xai-org/grok-1; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok1_314b", family="moe", source="hf:xai-org/grok-1; unverified",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
    vocab=131072, head_dim=128,
    n_experts=8, experts_per_token=2,
    optimizer="adafactor", microbatch=32,
    train_chips=256, serve_chips_per_replica=64,
)
