"""qwen2.5-14b [dense] — GQA 40q/8kv, QKV bias. [hf:Qwen/Qwen2.5; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_5_14b", family="dense", source="hf:Qwen/Qwen2.5-0.5B; hf",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=13824,
    vocab=152064, head_dim=128, qkv_bias=True,
    rope_theta=1000000.0,
    microbatch=16, train_chips=64, serve_chips_per_replica=4,
)
