"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th layer;
patch-embedding frontend is a stub (input_specs provides patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama32_vision_90b", family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision; unverified",
    n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab=128256, head_dim=128,
    cross_attn_period=5, frontend_len=1601,   # (560/14)^2 + 1 patches
    rope_theta=500000.0,
    optimizer="adafactor", microbatch=8,
    train_chips=256, serve_chips_per_replica=32,
)
