"""Architecture configuration system.

Every assigned architecture is an ``ArchConfig`` registered under its id
and selectable via ``--arch`` in the launchers. A config fully determines:

  * the model structure (``layer_pattern()`` — the period block that the
    scan-over-layers iterates),
  * the shape grid (``shapes()`` — train/prefill/decode/long cells with
    the assignment's documented skips),
  * the provisioning demand model used by the PhoenixCloud layer
    (``train_chips`` / ``serve_chips_per_replica``),
  * dry-run knobs (microbatch, remat, optimizer choice for giant models).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

# ----------------------------------------------------------------- layer IR

# Layer kinds inside a period block.
ATTN = "attn"              # global self-attention
ATTN_LOCAL = "attn_local"  # sliding-window self-attention
ATTN_CROSS = "attn_cross"  # cross-attention to frontend embeddings (vlm/audio)
MAMBA = "mamba"            # Mamba2 SSD block
# MLP kinds.
DENSE = "dense"
MOE = "moe"
NONE = "none"


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer inside the period block: (mixer kind, mlp kind).

    ``cross=True`` adds a cross-attention sublayer after the mixer
    (whisper-style decoder layers); ``mixer=ATTN_CROSS`` *replaces* the
    self-attention with cross-attention (llama-3.2-vision image layers).
    """

    mixer: str
    mlp: str = DENSE
    cross: bool = False


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"
    skip: Optional[str] = None  # reason string when the cell is skipped


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | vlm | audio
    source: str               # provenance tag from the assignment
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    # Attention flavour.
    qkv_bias: bool = False
    attn_softcap: Optional[float] = None     # gemma2: 50.0
    final_softcap: Optional[float] = None    # gemma2: 30.0
    sliding_window: Optional[int] = None     # gemma2 local layers: 4096
    local_global: bool = False               # alternate local/global layers
    rope_theta: float = 10000.0
    # MoE.
    n_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1        # a layer uses MoE iff (idx % moe_period == moe_offset)
    moe_offset: int = 0
    # SSM / hybrid.
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    attn_period: int = 0       # hybrid: 1 attention layer per `attn_period`
    attn_offset: int = 0
    # Cross-attention (vlm) / encoder-decoder (audio).
    cross_attn_period: int = 0  # 1 cross-attn layer per period
    encoder_layers: int = 0     # enc-dec: encoder depth (decoder = n_layers)
    frontend_len: int = 1500    # stub frontend sequence length (frames/patches)
    frontend_batch_scale: float = 1.0
    # Training knobs for the dry-run (memory fitting).
    optimizer: str = "adamw"   # "adamw" | "adafactor"
    microbatch: Optional[int] = None   # per-step microbatch for grad accum
    remat: bool = True
    # Provisioning demand model (PhoenixCloud layer).
    train_chips: int = 256
    serve_chips_per_replica: int = 1

    # ------------------------------------------------------------ structure

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // max(1, self.n_heads)

    def layer_pattern(self) -> List[LayerSpec]:
        """The period block replicated ``n_layers / len(pattern)`` times."""
        if self.family == "ssm":
            return [LayerSpec(MAMBA, NONE)]
        if self.family == "hybrid":
            period = self.attn_period
            specs = []
            for i in range(period):
                mixer = ATTN if i % period == self.attn_offset else MAMBA
                mlp = MOE if (self.n_experts and i % self.moe_period
                              == self.moe_offset) else DENSE
                specs.append(LayerSpec(mixer, mlp))
            return specs
        if self.family == "vlm":
            period = self.cross_attn_period
            return [LayerSpec(ATTN_CROSS if i == period - 1 else ATTN, DENSE)
                    for i in range(period)]
        if self.local_global:
            return [LayerSpec(ATTN_LOCAL, self._mlp_kind(0)),
                    LayerSpec(ATTN, self._mlp_kind(1))]
        if self.family == "audio":
            # Enc-dec decoder layer: self-attn + cross-attn + MLP.
            return [LayerSpec(ATTN, DENSE, cross=True)]
        return [LayerSpec(ATTN, self._mlp_kind(0))]

    def _mlp_kind(self, idx: int) -> str:
        if self.n_experts and idx % self.moe_period == self.moe_offset:
            return MOE
        return DENSE

    @property
    def n_periods(self) -> int:
        pattern = self.layer_pattern()
        assert self.n_layers % len(pattern) == 0, \
            f"{self.name}: {self.n_layers} layers not divisible by " \
            f"period {len(pattern)}"
        return self.n_layers // len(pattern)

    # ----------------------------------------------------------- shape grid

    def sub_quadratic(self) -> bool:
        """Eligibility for long_500k (SSM/hybrid only, per assignment)."""
        return self.family in ("ssm", "hybrid")

    def shapes(self) -> Dict[str, ShapeSpec]:
        long_skip = None if self.sub_quadratic() else (
            "long_500k needs sub-quadratic attention; "
            f"{self.name} is full-attention (family={self.family}) — "
            "skip per assignment note in DESIGN.md")
        return {
            "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
            "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
            "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
            "long_500k": ShapeSpec("long_500k", 524288, 1, "decode",
                                   skip=long_skip),
        }

    # ------------------------------------------------------- size accounting

    def param_count(self) -> int:
        """Approximate parameter count (embedding + blocks + head)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim_
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
            + self.n_heads * hd * d
        dense_mlp = 3 * d * f
        moe_mlp = self.n_experts * 3 * d * f + d * self.n_experts
        d_inner = self.ssm_expand * d
        n_ssm_heads = max(1, d_inner // self.ssm_head_dim)
        mamba = (d * (2 * d_inner + 2 * self.ssm_state + n_ssm_heads)
                 + d_inner * d + self.ssm_conv
                 * (d_inner + 2 * self.ssm_state) + 3 * n_ssm_heads)
        total = v * d                     # embedding (tied head)
        for spec in self.layer_pattern():
            n = self.n_periods
            if spec.mixer in (ATTN, ATTN_LOCAL):
                total += n * attn
            elif spec.mixer == ATTN_CROSS:
                total += n * attn
            elif spec.mixer == MAMBA:
                total += n * mamba
            if spec.mlp == DENSE:
                total += n * dense_mlp
            elif spec.mlp == MOE:
                total += n * moe_mlp
        total += self.encoder_layers * (attn + dense_mlp)
        total += self.n_layers * 2 * d    # norms
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        inactive = (self.n_experts - self.experts_per_token) * 3 * d * f
        n_moe_layers = sum(1 for s in self.layer_pattern() if s.mlp == MOE) \
            * self.n_periods
        return self.param_count() - n_moe_layers * inactive


# ------------------------------------------------------------------ registry

ARCH_IDS = [
    "gemma2_2b", "smollm_135m", "qwen2_5_14b", "qwen1_5_0_5b",
    "llama32_vision_90b", "jamba15_large_398b", "whisper_base",
    "granite_moe_3b", "grok1_314b", "mamba2_130m",
]

_ALIASES = {
    "gemma2-2b": "gemma2_2b",
    "smollm-135m": "smollm_135m",
    "qwen2.5-14b": "qwen2_5_14b",
    "qwen1.5-0.5b": "qwen1_5_0_5b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "jamba-1.5-large-398b": "jamba15_large_398b",
    "whisper-base": "whisper_base",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "grok-1-314b": "grok1_314b",
    "mamba2-130m": "mamba2_130m",
}


def get_config(arch: str) -> ArchConfig:
    arch = _ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduced_config(cfg: ArchConfig, **overrides) -> ArchConfig:
    """A smoke-test-sized config of the same family (small dims, same
    layer pattern structure)."""
    pattern = len(cfg.layer_pattern())
    base = dict(
        n_layers=2 * pattern if cfg.family != "hybrid" else pattern,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_ff=128,
        vocab=512,
        head_dim=16,
        sliding_window=64 if cfg.sliding_window else None,
        n_experts=min(cfg.n_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=16,
        encoder_layers=2 if cfg.encoder_layers else 0,
        frontend_len=32,
        microbatch=None,
        train_chips=1,
    )
    base.update(overrides)
    return dataclasses.replace(cfg, **base)
