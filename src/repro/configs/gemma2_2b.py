"""gemma2-2b [dense] — local+global alternating attention, logit softcaps,
GQA 8q/4kv. [arXiv:2408.00118; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2_2b", family="dense", source="arXiv:2408.00118; hf",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, d_ff=9216,
    vocab=256000, head_dim=256,
    attn_softcap=50.0, final_softcap=30.0,
    sliding_window=4096, local_global=True,
    rope_theta=10000.0,
    microbatch=32, train_chips=16, serve_chips_per_replica=1,
)
