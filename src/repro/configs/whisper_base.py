"""whisper-base [audio] — encoder-decoder, conv frontend stubbed: the
encoder consumes precomputed 1500-frame embeddings from input_specs().
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper_base", family="audio", source="arXiv:2212.04356; unverified",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab=51865, head_dim=64,
    encoder_layers=6, frontend_len=1500,
    microbatch=64, train_chips=1, serve_chips_per_replica=1,
)
