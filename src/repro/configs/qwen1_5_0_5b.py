"""qwen1.5-0.5b [dense] — QKV bias, kv=16 (MHA). [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1_5_0_5b", family="dense", source="hf:Qwen/Qwen1.5-0.5B; hf",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=2816,
    vocab=151936, head_dim=64, qkv_bias=True,
    rope_theta=10000.0,
    microbatch=64, train_chips=2, serve_chips_per_replica=1,
)
