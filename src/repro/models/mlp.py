"""Gated MLP and Mixture-of-Experts layers.

The MoE uses capacity-based top-k routing with an explicit
``shard_map`` dispatch (version-guarded via ``repro.compat``): tokens
are routed *locally per data shard*
(scatter into an (E, C, d) buffer), expert FFNs run with d_ff
tensor-parallel over the 'model' axis, and the partial outputs are
``psum``-combined. This keeps compiled FLOPs proportional to *active*
parameters (honest MoE roofline) while avoiding the (N, E, C) one-hot
dispatch einsum whose memory explodes at 32k sequence lengths.

Expert-parallel sharding rule (divisibility-aware, see DESIGN.md):
d_ff is sharded over 'model' whenever divisible (all three assigned MoE
archs: grok 32768/16, granite 512/16, jamba 24576/16); otherwise the
expert weights are replicated and the psum is skipped.
"""

from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import ArchConfig
from repro.models.common import AxisSizes, KeyGen, normal_init, shard

CAPACITY_FACTOR = 1.25


def init_dense_mlp(kg: KeyGen, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w1": normal_init(kg(), (d, f), d ** -0.5, dtype),
        "w3": normal_init(kg(), (d, f), d ** -0.5, dtype),
        "w2": normal_init(kg(), (f, d), f ** -0.5, dtype),
    }


def dense_mlp_specs(cfg: ArchConfig, ax: AxisSizes) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w1": ax.spec(("data", "model"), (d, f)),
        "w3": ax.spec(("data", "model"), (d, f)),
        "w2": ax.spec(("model", "data"), (f, d)),
    }


def dense_mlp(p: Dict, x: jax.Array, ax: AxisSizes) -> jax.Array:
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    h = shard(h, ax, (ax.batch_axes, None, "model"))
    return h @ p["w2"]


# ----------------------------------------------------------------------- MoE

def init_moe(kg: KeyGen, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": normal_init(kg(), (d, e), d ** -0.5, jnp.float32),
        "w1": normal_init(kg(), (e, d, f), d ** -0.5, dtype),
        "w3": normal_init(kg(), (e, d, f), d ** -0.5, dtype),
        "w2": normal_init(kg(), (e, f, d), f ** -0.5, dtype),
    }


def moe_specs(cfg: ArchConfig, ax: AxisSizes) -> Dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": P(None, None),
        "w1": ax.spec((None, "data", "model"), (e, d, f)),
        "w3": ax.spec((None, "data", "model"), (e, d, f)),
        "w2": ax.spec((None, "model", "data"), (e, f, d)),
    }


def _capacity(n_local: int, cfg: ArchConfig) -> int:
    c = int(cfg.experts_per_token * n_local * CAPACITY_FACTOR
            / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)   # round up to a multiple of 8


def _moe_local(xl: jax.Array, router: jax.Array, w1: jax.Array,
               w3: jax.Array, w2: jax.Array, cfg: ArchConfig,
               model_sharded: bool) -> jax.Array:
    """Per-data-shard MoE: local dispatch, TP expert FFN, psum combine."""
    nl, d = xl.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    cap = _capacity(nl, cfg)
    logits = xl.astype(jnp.float32) @ router                 # (nl, e)
    probs = jax.nn.softmax(logits, axis=-1)
    pk, ik = jax.lax.top_k(probs, k)                         # (nl, k)
    pk = (pk / jnp.sum(pk, -1, keepdims=True)).astype(xl.dtype)
    # Slot assignment: position of each (token, choice) within its expert.
    onehot = jax.nn.one_hot(ik.reshape(-1), e, dtype=jnp.int32)  # (nl*k, e)
    slot = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1     # 0-based
    slot = slot.reshape(nl, k)
    keep = slot < cap                                         # capacity drop
    # Dispatch: scatter tokens into the (e, cap, d) expert buffer.
    buf = jnp.zeros((e, cap, d), xl.dtype)
    buf = buf.at[ik, slot].add(
        jnp.where(keep[..., None], xl[:, None, :], 0), mode="drop")
    # Expert FFN (d_ff tensor-parallel over 'model' when sharded).
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w1)) \
        * jnp.einsum("ecd,edf->ecf", buf, w3)
    out_e = jnp.einsum("ecf,efd->ecd", h, w2)
    if model_sharded:
        # Combine in the compute dtype (bf16 on TPU): halves the TP psum
        # wire bytes vs fp32 at no accuracy cost (expert FFN ran in bf16
        # anyway; the router weights are applied after the psum).
        out_e = jax.lax.psum(out_e.astype(xl.dtype), "model")
    # Combine: gather back and weight by (renormalized) router probs.
    gathered = out_e.at[ik, slot].get(mode="fill", fill_value=0)  # (nl,k,d)
    gathered = jnp.where(keep[..., None], gathered, 0)
    return jnp.sum(gathered * pk[..., None], axis=1)


def moe_mlp(p: Dict, x: jax.Array, cfg: ArchConfig, ax: AxisSizes,
            mesh) -> jax.Array:
    """x: (B, S, d) → (B, S, d)."""
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    f_sharded = cfg.d_ff % ax.size("model") == 0 and ax.size("model") > 1
    # Tokens shard over the batch axes when divisible (train/prefill);
    # small decode batches replicate (the FFN is tiny at N=1 anyway).
    batch = ax.batch_axes if (b * s) % ax.size(ax.batch_axes) == 0 else None
    in_specs = (
        P(batch, None),                                    # tokens
        P(None, None),                                     # router
        P(None, None, "model") if f_sharded else P(None, None, None),
        P(None, None, "model") if f_sharded else P(None, None, None),
        P(None, "model", None) if f_sharded else P(None, None, None),
    )
    fn = functools.partial(_moe_local, cfg=cfg, model_sharded=f_sharded)
    out = shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=P(batch, None),
        check_vma=False,
    )(xf, p["router"], p["w1"], p["w3"], p["w2"])
    return out.reshape(b, s, d)
