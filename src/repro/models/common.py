"""Shared model utilities: norms, RoPE, init, and sharding rules.

Sharding philosophy: every parameter/activation gets a *requested*
PartitionSpec; a dimension is only sharded on a mesh axis when its size is
divisible by that axis (small models simply replicate on 'model'). This is
what lets one model definition serve smollm-135m (9 heads — replicated
attention, sharded MLP) and grok-1 (TP over 48 heads / 32768 d_ff) on the
same 16×16 production mesh.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class AxisSizes:
    """Mesh axis sizes used for divisibility-aware spec construction."""

    sizes: Tuple[Tuple[str, int], ...]   # e.g. (("pod",2),("data",16),("model",16))
    mesh: Optional[object] = None        # jax.sharding.Mesh (for constraints)

    @staticmethod
    def from_mesh(mesh) -> "AxisSizes":
        return AxisSizes(tuple(zip(mesh.axis_names,
                                   (mesh.devices.shape[i]
                                    for i in range(len(mesh.axis_names))))),
                         mesh)

    @staticmethod
    def single() -> "AxisSizes":
        return AxisSizes((("data", 1), ("model", 1)))

    def size(self, name) -> int:
        if isinstance(name, (tuple, list)):
            out = 1
            for n in name:
                out *= self.size(n)
            return out
        for n, s in self.sizes:
            if n == name:
                return s
        return 1

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.sizes)

    def has(self, name: str) -> bool:
        return name in self.names

    @property
    def batch_axes(self):
        """Axes that shard the batch dimension (pod+data when multi-pod)."""
        return ("pod", "data") if self.has("pod") else ("data",)

    def spec(self, dims: Sequence[Optional[object]],
             shape: Sequence[int]) -> P:
        """Build a PartitionSpec, dropping axes that don't divide."""
        assert len(dims) == len(shape), (dims, shape)
        out = []
        for want, size in zip(dims, shape):
            if want is None:
                out.append(None)
            elif size % self.size(want) == 0:
                out.append(want)
            else:
                out.append(None)
        return P(*out)


def shard(x: jax.Array, ax: AxisSizes, dims: Sequence[Optional[object]]):
    """with_sharding_constraint with divisibility fallback. No-op when the
    mesh is absent or trivial (single-device smoke tests)."""
    if ax.mesh is None or ax.mesh.size == 1:
        return x
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ax.mesh, ax.spec(dims, x.shape)))


# --------------------------------------------------------------------- norms

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dtype)


def rms_norm_gated(x: jax.Array, z: jax.Array, w: jax.Array,
                   eps: float = 1e-6) -> jax.Array:
    """Mamba2's gated RMSNorm: norm(x * silu(z))."""
    return rms_norm(x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), w, eps)


# ---------------------------------------------------------------------- RoPE

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embeddings. x: (..., seq, heads, head_dim); positions: (seq,)
    or (batch, seq) broadcastable to x's seq dim."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (..., seq, half)
    angles = angles[..., None, :]                                # head axis
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------- init

def normal_init(key, shape, stddev, dtype=jnp.float32):
    return (stddev * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


class KeyGen:
    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy; logits (..., V), labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
