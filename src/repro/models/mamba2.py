"""Mamba2 (SSD — state-space duality) block, arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm (quadratic within chunks,
linear across chunks); decode is the O(1) recurrent state update — this is
what makes the ``long_500k`` cell tractable for the SSM/hybrid archs.

The heavy intra-chunk einsums can route through the Pallas SSD kernel
(``repro.kernels``); the pure-jnp path here doubles as its oracle.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import (AxisSizes, KeyGen, normal_init,
                                 rms_norm_gated, shard)

CHUNK = 128
N_GROUPS = 1    # B/C projection groups (mamba2 default)


def dims(cfg: ArchConfig) -> Tuple[int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    assert n_heads * cfg.ssm_head_dim == d_inner, (cfg.name, d_inner)
    return d_inner, n_heads, cfg.ssm_state


def init_mamba(kg: KeyGen, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d = cfg.d_model
    d_inner, nh, n = dims(cfg)
    gbc = 2 * N_GROUPS * n
    std = d ** -0.5
    return {
        "wz": normal_init(kg(), (d, d_inner), std, dtype),
        "wx": normal_init(kg(), (d, d_inner), std, dtype),
        "wbc": normal_init(kg(), (d, gbc), std, dtype),
        "wdt": normal_init(kg(), (d, nh), std, dtype),
        "conv_x": normal_init(kg(), (cfg.ssm_conv, d_inner), 0.3, dtype),
        "conv_bc": normal_init(kg(), (cfg.ssm_conv, gbc), 0.3, dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.zeros((d_inner,), jnp.float32),
        "wo": normal_init(kg(), (d_inner, d), d_inner ** -0.5, dtype),
    }


def mamba_specs(cfg: ArchConfig, ax: AxisSizes) -> Dict:
    d = cfg.d_model
    d_inner, nh, n = dims(cfg)
    gbc = 2 * N_GROUPS * n
    return {
        "wz": ax.spec(("data", "model"), (d, d_inner)),
        "wx": ax.spec(("data", "model"), (d, d_inner)),
        "wbc": ax.spec(("data", None), (d, gbc)),
        "wdt": ax.spec(("data", "model"), (d, nh)),
        "conv_x": ax.spec((None, "model"), (cfg.ssm_conv, d_inner)),
        "conv_bc": ax.spec((None, None), (cfg.ssm_conv, gbc)),
        "A_log": ax.spec(("model",), (nh,)),
        "D": ax.spec(("model",), (nh,)),
        "dt_bias": ax.spec(("model",), (nh,)),
        "norm_w": ax.spec(("model",), (d_inner,)),
        "wo": ax.spec(("model", "data"), (d_inner, d)),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (b, l, c); w: (k, c)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., q) → (..., q, q) with out[i,j] = sum_{j<m<=i} a_m (i>=j)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array,
                init_state: Optional[jax.Array] = None,
                chunk: int = CHUNK, impl: str = "xla"
                ) -> Tuple[jax.Array, jax.Array]:
    """SSD scan. x: (b, l, h, p); a: (b, l, h) log-decay (≤ 0);
    B, C: (b, l, g, n). Returns (y: (b, l, h, p), final state (b, h, p, n)).
    """
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.ssd(x, a, B, C, init_state=init_state, chunk=chunk)
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    chunk = min(chunk, l)
    nc = l // chunk
    assert nc * chunk == l, (l, chunk)
    xc = x.reshape(b, nc, chunk, h, p)
    ac = a.reshape(b, nc, chunk, h).transpose(0, 3, 1, 2)     # (b,h,nc,q)
    Bc = B.reshape(b, nc, chunk, g, n)
    Cc = C.reshape(b, nc, chunk, g, n)
    Bh = jnp.repeat(Bc, rep, axis=3)                          # (b,nc,q,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)
    a_cum = jnp.cumsum(ac, axis=-1)                           # (b,h,nc,q)
    # 1. Intra-chunk (quadratic, attention-like).
    L = jnp.exp(_segsum(ac))                                  # (b,h,nc,q,q)
    y_diag = jnp.einsum("bclhn,bcshn,bhcls,bcshp->bclhp",
                        Ch, Bh, L, xc)
    # 2. Chunk states (fp32 — the recurrence is precision-sensitive and
    # must be dtype-stable for the scan carry).
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)           # (b,h,nc,q)
    states = jnp.einsum("bcshn,bhcs,bcshp->bchpn", Bh, decay_states,
                        xc).astype(jnp.float32)
    # 3. Inter-chunk recurrence.
    chunk_decay = jnp.exp(a_cum[..., -1])                     # (b,h,nc)
    s0 = jnp.zeros((b, h, p, n), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)

    def step(carry, inp):
        st, dec = inp
        new = carry * dec[:, :, None, None] + st
        return new, carry                                     # emit previous

    final, prev_states = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(2, 0, 1).astype(jnp.float32)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # (b,nc,h,p,n)
    # 4. Off-diagonal (state → output).
    state_decay_out = jnp.exp(a_cum)                          # (b,h,nc,q)
    y_off = jnp.einsum("bclhn,bchpn,bhcl->bclhp",
                       Ch, prev_states, state_decay_out)
    y = (y_diag + y_off).reshape(b, l, h, p).astype(x.dtype)
    return y, final


def _split_bc(bc: jax.Array, n: int):
    Bp, Cp = bc[..., :N_GROUPS * n], bc[..., N_GROUPS * n:]
    return (Bp.reshape(*bc.shape[:-1], N_GROUPS, n),
            Cp.reshape(*bc.shape[:-1], N_GROUPS, n))


def mamba_full(p: Dict, u: jax.Array, cfg: ArchConfig, ax: AxisSizes,
               impl: str = "xla") -> jax.Array:
    """Training/prefill pass (no state emitted). u: (b, l, d)."""
    out, _, _ = _mamba_forward(p, u, cfg, ax, impl)
    return out


def _mamba_forward(p: Dict, u: jax.Array, cfg: ArchConfig, ax: AxisSizes,
                   impl: str):
    b, l, d = u.shape
    d_inner, nh, n = dims(cfg)
    z = u @ p["wz"]
    x = _causal_conv(u @ p["wx"], p["conv_x"])
    bc = _causal_conv(u @ p["wbc"], p["conv_bc"])
    x = jax.nn.silu(x)
    bc = jax.nn.silu(bc)
    x = shard(x, ax, (ax.batch_axes, None, "model"))
    dt = jax.nn.softplus((u @ p["wdt"]).astype(jnp.float32)
                         + p["dt_bias"])                       # (b,l,nh)
    A = -jnp.exp(p["A_log"])                                   # (nh,) < 0
    B_, C_ = _split_bc(bc, n)
    xh = x.reshape(b, l, nh, cfg.ssm_head_dim)
    a = (dt * A).astype(jnp.float32)
    y, state = ssd_chunked((xh * dt[..., None].astype(xh.dtype)), a,
                           B_, C_, impl=impl)
    y = y + p["D"].astype(y.dtype)[:, None] * xh
    y = y.reshape(b, l, d_inner)
    y = rms_norm_gated(y, z, p["norm_w"]).astype(u.dtype)
    return y @ p["wo"], state, (x, bc)


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Dict:
    d_inner, nh, n = dims(cfg)
    gbc = 2 * N_GROUPS * n
    return {
        "state": jnp.zeros((batch, nh, cfg.ssm_head_dim, n), dtype),
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, d_inner), dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, gbc), dtype),
    }


def mamba_cache_specs(cfg: ArchConfig, ax: AxisSizes, cache: Dict) -> Dict:
    return {
        "state": ax.spec((ax.batch_axes, "model", None, None),
                         cache["state"].shape),
        "conv_x": ax.spec((ax.batch_axes, None, "model"),
                          cache["conv_x"].shape),
        "conv_bc": ax.spec((ax.batch_axes, None, None),
                           cache["conv_bc"].shape),
    }


def mamba_prefill(p: Dict, u: jax.Array, cfg: ArchConfig, ax: AxisSizes,
                  cache: Dict, impl: str = "xla") -> Tuple[jax.Array, Dict]:
    out, state, (x_conv_in, bc_conv_in) = _mamba_forward(p, u, cfg, ax, impl)
    w = cfg.ssm_conv
    cache = dict(cache)
    cache["state"] = state.astype(cache["state"].dtype)
    # Keep the last (w-1) *pre-conv* inputs. We saved post-silu conv outputs
    # above; recompute the tail of the raw projections instead.
    tail_u = u[:, -(w - 1):, :]
    cache["conv_x"] = (tail_u @ p["wx"]).astype(cache["conv_x"].dtype)
    cache["conv_bc"] = (tail_u @ p["wbc"]).astype(cache["conv_bc"].dtype)
    return out, cache


def mamba_decode(p: Dict, u: jax.Array, cfg: ArchConfig, ax: AxisSizes,
                 cache: Dict) -> Tuple[jax.Array, Dict]:
    """One-token recurrent step. u: (b, 1, d)."""
    b = u.shape[0]
    d_inner, nh, n = dims(cfg)
    ut = u[:, 0, :]
    z = ut @ p["wz"]
    x_new = ut @ p["wx"]
    bc_new = ut @ p["wbc"]
    # Depthwise conv over the cached window.
    cx = jnp.concatenate([cache["conv_x"], x_new[:, None, :]], axis=1)
    cbc = jnp.concatenate([cache["conv_bc"], bc_new[:, None, :]], axis=1)
    x = jax.nn.silu(jnp.einsum("bkc,kc->bc", cx, p["conv_x"]))
    bc = jax.nn.silu(jnp.einsum("bkc,kc->bc", cbc, p["conv_bc"]))
    dt = jax.nn.softplus((ut @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    B_, C_ = _split_bc(bc, n)                                 # (b,g,n)
    rep = nh // N_GROUPS
    Bh = jnp.repeat(B_, rep, axis=1)                          # (b,nh,n)
    Ch = jnp.repeat(C_, rep, axis=1)
    xh = x.reshape(b, nh, cfg.ssm_head_dim)
    dA = jnp.exp(dt * A)                                      # (b,nh)
    state = cache["state"].astype(jnp.float32)
    state = state * dA[..., None, None] \
        + jnp.einsum("bh,bhp,bhn->bhpn", dt, xh.astype(jnp.float32),
                     Bh.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch.astype(jnp.float32))
    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(b, d_inner).astype(u.dtype)
    y = rms_norm_gated(y, z, p["norm_w"])
    out = (y @ p["wo"])[:, None, :]
    cache = dict(cache)
    cache["state"] = state.astype(cache["state"].dtype)
    cache["conv_x"] = cx[:, 1:, :].astype(cache["conv_x"].dtype)
    cache["conv_bc"] = cbc[:, 1:, :].astype(cache["conv_bc"].dtype)
    return out, cache
