"""GQA attention with the flavours the assigned archs need.

Covers: grouped-query attention (einsum-grouped, no KV duplication), QKV
bias (qwen), logit softcap (gemma2), sliding-window local attention
(gemma2), cross-attention to frontend/encoder embeddings (vlm/audio), and
KV-cache prefill/decode.

``impl='xla'`` is the jnp path used for training and for the dry-run
lowering (the roofline reads XLA HLO); ``impl='pallas'`` routes prefill
through the flash-attention Pallas kernel (TPU target; validated in
interpret mode on CPU).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.common import AxisSizes, KeyGen, normal_init, rope, shard
from repro.models.common import softcap as _softcap

NEG_INF = -2.0e38


def init_attn(kg: KeyGen, cfg: ArchConfig, dtype=jnp.float32) -> Dict:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    std = d ** -0.5
    p = {
        "wq": normal_init(kg(), (d, h, hd), std, dtype),
        "wk": normal_init(kg(), (d, k, hd), std, dtype),
        "wv": normal_init(kg(), (d, k, hd), std, dtype),
        "wo": normal_init(kg(), (h, hd, d), (h * hd) ** -0.5, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((k, hd), dtype)
        p["bv"] = jnp.zeros((k, hd), dtype)
    return p


def attn_specs(cfg: ArchConfig, ax: AxisSizes) -> Dict:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    s = {
        "wq": ax.spec(("data", "model", None), (d, h, hd)),
        "wk": ax.spec(("data", "model", None), (d, k, hd)),
        "wv": ax.spec(("data", "model", None), (d, k, hd)),
        "wo": ax.spec(("model", None, "data"), (h, hd, d)),
    }
    if cfg.qkv_bias:
        s["bq"] = ax.spec(("model", None), (h, hd))
        s["bk"] = ax.spec(("model", None), (k, hd))
        s["bv"] = ax.spec(("model", None), (k, hd))
    return s


# NOTE (§Perf, refuted iteration): an FSDP "gather-at-use" constraint on
# the weights (forcing weight all-gather instead of activation partial-sum
# over 'data') was tried here and REVERTED: it fixed one pathology
# (qwen2.5-14b multipod activation all-reduce) but regressed others
# (llama-90b singlepod 639->1171 ms t_coll; qwen multipod 199->289 ms) —
# the 3-axis resharding takes XLA's "involuntary full rematerialization"
# path. GSPMD's own operand choice is better on net; see EXPERIMENTS.md.


def _project_qkv(p: Dict, xq: jax.Array, xkv: jax.Array, cfg: ArchConfig,
                 ax: AxisSizes, q_pos, kv_pos, use_rope: bool):
    q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"])
    k = jnp.einsum("btd,dmk->btmk", xkv, p["wk"])
    v = jnp.einsum("btd,dmk->btmk", xkv, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if use_rope:
        q = rope(q, q_pos, cfg.rope_theta)
        k = rope(k, kv_pos, cfg.rope_theta)
    return q, k, v


def _out_proj(out: jax.Array, p: Dict, ax: AxisSizes) -> jax.Array:
    return jnp.einsum("bshd,hdk->bsk", out, p["wo"])


def _sdpa(q: jax.Array, k: jax.Array, v: jax.Array, cfg: ArchConfig,
          mask: Optional[jax.Array]) -> jax.Array:
    """Grouped-query scaled-dot-product attention.

    q: (b, s, h, hd); k/v: (b, t, kv, hd); mask: broadcastable to
    (b, kv, g, s, t) or None.
    """
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k) / np.sqrt(hd)
    scores = _softcap(scores, cfg.attn_softcap)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, hd)


def _causal_mask(s: int, t: int, q_offset, window: Optional[int]):
    """(s, t) boolean mask; q row i sits at absolute position q_offset+i."""
    rows = jnp.arange(s)[:, None] + q_offset
    cols = jnp.arange(t)[None, :]
    m = cols <= rows
    if window is not None:
        m &= cols > rows - window
    return m


# Q-chunked attention: above this sequence length the full (S, S) score
# tensor would dominate HBM (32k: ~TB-scale globally), so the XLA path
# scans over query chunks — peak temp drops to (b, h, CHUNK_Q, S) while
# total score traffic is unchanged. The Pallas flash kernel removes the
# score traffic entirely (see EXPERIMENTS.md §Perf).
CHUNK_Q = 2048
CHUNK_THRESHOLD = 8192


def _sdpa_qchunked(q: jax.Array, k: jax.Array, v: jax.Array,
                   cfg: ArchConfig, window: Optional[int],
                   causal: bool) -> jax.Array:
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    nc = s // CHUNK_Q
    assert nc * CHUNK_Q == s, (s, CHUNK_Q)
    qc = q.reshape(b, nc, CHUNK_Q, h, hd).transpose(1, 0, 2, 3, 4)
    idx = jnp.arange(nc)

    # Sliding-window layers only ever see a (window + CHUNK_Q) band of
    # keys per query chunk — slice it instead of scoring all s columns
    # (gemma2 local layers at 32k: 6144-wide band vs 32768 → ~5.3× less
    # score traffic/FLOPs; §Perf cell C).
    band = min(s, (window + CHUNK_Q)) if (window and causal) else None

    def body(_, xs):
        qi, ci = xs
        if band is not None and band < s:
            start = jnp.clip(ci * CHUNK_Q + CHUNK_Q - band, 0, s - band)
            kb = jax.lax.dynamic_slice(k, (0, start, 0, 0),
                                       (b, band, kv, hd))
            vb = jax.lax.dynamic_slice(v, (0, start, 0, 0),
                                       (b, band, kv, hd))
            rows = ci * CHUNK_Q + jnp.arange(CHUNK_Q)[:, None]
            cols = start + jnp.arange(band)[None, :]
            mask = (cols <= rows) & (cols > rows - window)
            out = _sdpa(qi, kb, vb, cfg, mask)
        else:
            mask = _causal_mask(CHUNK_Q, s, ci * CHUNK_Q, window) \
                if causal else None
            out = _sdpa(qi, k, v, cfg, mask)
        return None, out

    _, outs = jax.lax.scan(body, None, (qc, idx))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


_FORCE_DENSE = False    # analytic cost path: no inner scans (XLA counts
#                         while bodies once — see launch.cells.analytic_cost)


class force_dense:
    def __enter__(self):
        global _FORCE_DENSE
        self._old = _FORCE_DENSE
        _FORCE_DENSE = True

    def __exit__(self, *a):
        global _FORCE_DENSE
        _FORCE_DENSE = self._old


def _sdpa_banded_unrolled(q, k, v, cfg, window):
    """Python-unrolled banded attention — same math as the banded
    q-chunked scan, with every chunk visible to HLO cost analysis (the
    analytic roofline path counts while bodies once, so it must not
    loop)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    nc = s // CHUNK_Q
    band = min(s, window + CHUNK_Q)
    outs = []
    for ci in range(nc):
        start = min(max(ci * CHUNK_Q + CHUNK_Q - band, 0), s - band)
        qi = q[:, ci * CHUNK_Q:(ci + 1) * CHUNK_Q]
        kb = k[:, start:start + band]
        vb = v[:, start:start + band]
        rows = ci * CHUNK_Q + jnp.arange(CHUNK_Q)[:, None]
        cols = start + jnp.arange(band)[None, :]
        mask = (cols <= rows) & (cols > rows - window)
        outs.append(_sdpa(qi, kb, vb, cfg, mask))
    return jnp.concatenate(outs, axis=1)


def _sdpa_auto(q, k, v, cfg, window, causal):
    s = q.shape[1]
    long = s > CHUNK_THRESHOLD and s % CHUNK_Q == 0
    if long and _FORCE_DENSE and causal and window and \
            window + CHUNK_Q < s:
        return _sdpa_banded_unrolled(q, k, v, cfg, window)
    if long and not _FORCE_DENSE:
        return _sdpa_qchunked(q, k, v, cfg, window, causal)
    mask = _causal_mask(s, k.shape[1], 0, window) if causal else None
    return _sdpa(q, k, v, cfg, mask)


def attend_full(p: Dict, x: jax.Array, cfg: ArchConfig, ax: AxisSizes,
                local: bool, impl: str = "xla",
                causal: bool = True) -> jax.Array:
    """Training/prefill self-attention over the whole sequence.
    ``causal=False`` gives the bidirectional encoder variant (whisper)."""
    b, s, _ = x.shape
    pos = jnp.arange(s)
    q, k, v = _project_qkv(p, x, x, cfg, ax, pos, pos, use_rope=True)
    q = shard(q, ax, (ax.batch_axes, None, "model", None))
    k = shard(k, ax, (ax.batch_axes, None, "model", None))
    v = shard(v, ax, (ax.batch_axes, None, "model", None))
    window = cfg.sliding_window if local else None
    if impl == "pallas" and causal:
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, window=window,
                                   softcap=cfg.attn_softcap)
    else:
        out = _sdpa_auto(q, k, v, cfg, window, causal)
    return _out_proj(out, p, ax)


def attend_cross(p: Dict, x: jax.Array, src: jax.Array, cfg: ArchConfig,
                 ax: AxisSizes) -> jax.Array:
    """Cross-attention to frontend/encoder embeddings (no mask, no rope)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, x, src, cfg, ax, None, None, use_rope=False)
    out = _sdpa(q, k, v, cfg, mask=None)
    return _out_proj(out, p, ax)


# ------------------------------------------------------------------ caching
#
# Cache layout is (batch, kv_heads, seq, head_dim) — decode-native: the
# per-token attention consumes K/V directly as dot_general batch dims
# (b, kv) × contraction over head_dim with NO transpose copies. The
# baseline (b, seq, kv, hd) layout cost 2 full-cache transpose copies per
# layer per token (§Perf cell A: 156 GB/layer → ~52 GB/layer). Prefill
# pays one transpose when filling — amortized over thousands of decodes.

def init_cache(cfg: ArchConfig, batch: int, max_len: int, cross_len: int = 0,
               dtype=jnp.bfloat16) -> Dict:
    """Per-attention-layer cache template (used stacked over periods)."""
    kv, hd = cfg.n_kv_heads, cfg.head_dim_
    c = {"k": jnp.zeros((batch, kv, max_len, hd), dtype),
         "v": jnp.zeros((batch, kv, max_len, hd), dtype)}
    if cross_len:
        c["ck"] = jnp.zeros((batch, kv, cross_len, hd), dtype)
        c["cv"] = jnp.zeros((batch, kv, cross_len, hd), dtype)
    return c


def _sdpa_cached(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 cfg: ArchConfig, mask: Optional[jax.Array]) -> jax.Array:
    """Decode attention against the (b, kv, t, hd) cache layout.

    q: (b, s, H, hd) with tiny s (1 for decode); mask broadcastable to
    (b, kv, g, s, t) or None. No transposition of the cache occurs.
    """
    b, s, h, hd = q.shape
    kv = k_cache.shape[1]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd).transpose(0, 2, 3, 1, 4)   # tiny
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg,
                        k_cache.astype(q.dtype)) / np.sqrt(hd)
    scores = _softcap(scores, cfg.attn_softcap)
    if mask is not None:
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,bktd->bkgsd", probs, v_cache.astype(q.dtype))
    return out.transpose(0, 3, 1, 2, 4).reshape(b, s, h, hd)


def cache_specs(cfg: ArchConfig, ax: AxisSizes, cache: Dict) -> Dict:
    return {name: ax.spec((ax.batch_axes, None, "model", None), arr.shape)
            for name, arr in cache.items()}


def prefill_attn(p: Dict, x: jax.Array, cfg: ArchConfig, ax: AxisSizes,
                 cache: Dict, local: bool, impl: str = "xla"
                 ) -> Tuple[jax.Array, Dict]:
    """Full-sequence attention that also fills the KV cache."""
    b, s, _ = x.shape
    pos = jnp.arange(s)
    q, k, v = _project_qkv(p, x, x, cfg, ax, pos, pos, use_rope=True)
    window = cfg.sliding_window if local else None
    if impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=True, window=window,
                                   softcap=cfg.attn_softcap)
    else:
        out = _sdpa_auto(q, k, v, cfg, window, causal=True)
    cache = dict(cache)
    # One transpose into the decode-native (b, kv, t, hd) layout.
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.transpose(0, 2, 1, 3).astype(cache["k"].dtype),
        (0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.transpose(0, 2, 1, 3).astype(cache["v"].dtype),
        (0, 0, 0, 0))
    return _out_proj(out, p, ax), cache


def decode_attn(p: Dict, x: jax.Array, cfg: ArchConfig, ax: AxisSizes,
                cache: Dict, pos: jax.Array, local: bool,
                impl: str = "xla") -> Tuple[jax.Array, Dict]:
    """One-token decode against the (b, kv, t, hd) cache. x: (b, 1, d).

    ``pos`` is either a scalar — one write position shared by every
    batch row — or per-row ``(b,)`` for continuous batching, where the
    rows sit at heterogeneous sequence positions (the serving engine's
    slots). Per-row positions rotate, write and mask each row at its own
    position; they take the masked XLA path (``flash_decode``'s fused
    kernel contracts on a scalar position).
    """
    b = x.shape[0]
    pos = jnp.asarray(pos)
    cache = dict(cache)
    max_len = cache["k"].shape[2]
    window = cfg.sliding_window if local else None
    at = jnp.minimum(pos, max_len - 1)
    if pos.ndim == 0:
        q, k_new, v_new = _project_qkv(p, x, x, cfg, ax, pos[None],
                                       pos[None], use_rope=True)
        k_new = k_new.transpose(0, 2, 1, 3).astype(cache["k"].dtype)
        v_new = v_new.transpose(0, 2, 1, 3).astype(cache["v"].dtype)
        cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k_new,
                                                  (0, 0, at, 0))
        cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v_new,
                                                  (0, 0, at, 0))
        if impl == "pallas":
            from repro.kernels import ops as kops
            out = kops.flash_decode(q, cache["k"], cache["v"], at,
                                    window=window, softcap=cfg.attn_softcap)
            return _out_proj(out, p, ax), cache
        valid = jnp.arange(max_len) <= at
        if window is not None:
            valid &= jnp.arange(max_len) > at - window
        mask = valid[None, None, None, None, :]      # (b,kv,g,1,t)
    else:
        q, k_new, v_new = _project_qkv(p, x, x, cfg, ax, pos[:, None],
                                       pos[:, None], use_rope=True)
        k_new = k_new.transpose(0, 2, 1, 3).astype(cache["k"].dtype)
        v_new = v_new.transpose(0, 2, 1, 3).astype(cache["v"].dtype)
        rows = jnp.arange(b)
        cache["k"] = cache["k"].at[rows, :, at, :].set(k_new[:, :, 0, :])
        cache["v"] = cache["v"].at[rows, :, at, :].set(v_new[:, :, 0, :])
        cols = jnp.arange(max_len)[None, :]
        valid = cols <= at[:, None]
        if window is not None:
            valid &= cols > at[:, None] - window
        mask = valid[:, None, None, None, :]         # (b,kv,g,1,t)
    out = _sdpa_cached(q, cache["k"], cache["v"], cfg, mask)
    return _out_proj(out, p, ax), cache


def decode_cross_attn(p: Dict, x: jax.Array, cfg: ArchConfig, ax: AxisSizes,
                      cache: Dict) -> jax.Array:
    """Cross-attention during decode: K/V precomputed at prefill time."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    out = _sdpa_cached(q, cache["ck"], cache["cv"], cfg, mask=None)
    return _out_proj(out, p, ax)


def fill_cross_cache(p: Dict, src: jax.Array, cfg: ArchConfig,
                     cache: Dict) -> Dict:
    k = jnp.einsum("btd,dmk->btmk", src, p["wk"])
    v = jnp.einsum("btd,dmk->btmk", src, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    cache = dict(cache)
    cache["ck"] = k.transpose(0, 2, 1, 3).astype(cache["ck"].dtype)
    cache["cv"] = v.transpose(0, 2, 1, 3).astype(cache["cv"].dtype)
    return cache
