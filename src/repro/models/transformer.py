"""Composable decoder-LM assembly for all assigned architecture families.

One definition covers dense / MoE / SSM / hybrid / VLM / enc-dec audio:
the architecture's ``layer_pattern()`` (a *period block* of LayerSpecs) is
replicated ``n_periods`` times by a ``lax.scan`` over stacked parameters —
compile time stays flat in depth, which matters when lowering 40
(arch × shape) cells for 512 devices.

Entry points per model:
  * ``loss(params, batch)``        — training loss (causal LM / enc-dec)
  * ``prefill(params, batch)``     — fills the KV/SSM caches, returns logits
  * ``decode(params, tokens, cache, pos)`` — one-token serve step
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ATTN, ATTN_CROSS, ATTN_LOCAL, DENSE, MAMBA,
                                MOE, NONE, ArchConfig, LayerSpec)
from repro.models import attention as A
from repro.models import mamba2 as M
from repro.models import mlp as F
from repro.models.common import (AxisSizes, KeyGen, cross_entropy_loss,
                                 normal_init, rms_norm, shard, softcap)


def _prepend(spec, dim=None):
    return jax.tree.map(lambda s: P(dim, *tuple(s)), spec,
                        is_leaf=lambda s: isinstance(s, P))


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    mesh: object                   # jax.sharding.Mesh
    impl: str = "xla"              # 'xla' | 'pallas'
    compute_dtype: object = jnp.bfloat16
    param_dtype: object = jnp.float32
    unroll: bool = False           # python-loop layers (FLOP accounting —
    #                                XLA counts while bodies once, so the
    #                                analytic roofline path unrolls)

    def __post_init__(self):
        self.ax = AxisSizes.from_mesh(self.mesh)
        self.pattern = self.cfg.layer_pattern()

    # ------------------------------------------------------------- params

    def _init_layer(self, kg: KeyGen, spec: LayerSpec) -> Dict:
        cfg, dt = self.cfg, self.param_dtype
        d = cfg.d_model
        p: Dict = {"norm1": jnp.zeros((d,), jnp.float32)}
        if spec.mixer == MAMBA:
            p["mix"] = M.init_mamba(kg, cfg, dt)
        else:
            p["mix"] = A.init_attn(kg, cfg, dt)
        if spec.cross:
            p["norm_cross"] = jnp.zeros((d,), jnp.float32)
            p["cross"] = A.init_attn(kg, cfg, dt)
        if spec.mlp != NONE:
            p["norm2"] = jnp.zeros((d,), jnp.float32)
            p["mlp"] = (F.init_dense_mlp(kg, cfg, dt) if spec.mlp == DENSE
                        else F.init_moe(kg, cfg, dt))
        return p

    def _layer_specs(self, spec: LayerSpec) -> Dict:
        cfg, ax = self.cfg, self.ax
        s: Dict = {"norm1": P(None)}
        if spec.mixer == MAMBA:
            s["mix"] = M.mamba_specs(cfg, ax)
        else:
            s["mix"] = A.attn_specs(cfg, ax)
        if spec.cross:
            s["norm_cross"] = P(None)
            s["cross"] = A.attn_specs(cfg, ax)
        if spec.mlp != NONE:
            s["norm2"] = P(None)
            s["mlp"] = (F.dense_mlp_specs(cfg, ax) if spec.mlp == DENSE
                        else F.moe_specs(cfg, ax))
        return s

    def init(self, seed: int = 0):
        cfg = self.cfg
        key = jax.random.PRNGKey(seed)
        kg = KeyGen(key)

        def stack(init_fn, n):
            return jax.vmap(lambda k: init_fn(KeyGen(k)))(
                jax.random.split(kg(), n))

        params: Dict = {
            "embed": normal_init(kg(), (cfg.vocab, cfg.d_model),
                                 cfg.d_model ** -0.5, self.param_dtype),
            "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
            "blocks": {
                f"l{i}": stack(functools.partial(self._init_layer, spec=sp),
                               cfg.n_periods)
                for i, sp in enumerate(self.pattern)
            },
        }
        if cfg.encoder_layers:
            enc_spec = LayerSpec(ATTN, DENSE)
            params["encoder"] = {
                "blocks": stack(
                    functools.partial(self._init_layer, spec=enc_spec),
                    cfg.encoder_layers),
                "norm": jnp.zeros((cfg.d_model,), jnp.float32),
            }
        if cfg.family == "vlm":
            params["front_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        return params

    def param_specs(self):
        cfg, ax = self.cfg, self.ax
        specs: Dict = {
            "embed": ax.spec(("model", "data"), (cfg.vocab, cfg.d_model)),
            "final_norm": P(None),
            "blocks": {
                f"l{i}": _prepend(self._layer_specs(sp))
                for i, sp in enumerate(self.pattern)
            },
        }
        if cfg.encoder_layers:
            specs["encoder"] = {
                "blocks": _prepend(self._layer_specs(LayerSpec(ATTN, DENSE))),
                "norm": P(None),
            }
        if cfg.family == "vlm":
            specs["front_norm"] = P(None)
        return specs

    # ------------------------------------------------------------- caches

    def _layer_cache(self, spec: LayerSpec, batch: int, max_len: int,
                     dtype) -> Dict:
        cfg = self.cfg
        c: Dict = {}
        if spec.mixer in (ATTN, ATTN_LOCAL):
            c.update(A.init_cache(cfg, batch, max_len, dtype=dtype))
        elif spec.mixer == ATTN_CROSS:
            full = A.init_cache(cfg, batch, 1, cross_len=cfg.frontend_len,
                                dtype=dtype)
            c.update({"ck": full["ck"], "cv": full["cv"]})
        elif spec.mixer == MAMBA:
            c.update(M.init_mamba_cache(cfg, batch, dtype=jnp.float32))
        if spec.cross:
            full = A.init_cache(cfg, batch, 1, cross_len=cfg.frontend_len,
                                dtype=dtype)
            c.update({"ck": full["ck"], "cv": full["cv"]})
        return c

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        out = {}
        for i, sp in enumerate(self.pattern):
            layer = self._layer_cache(sp, batch, max_len, dtype)
            out[f"l{i}"] = jax.tree.map(
                lambda a: jnp.zeros((self.cfg.n_periods,) + a.shape, a.dtype),
                layer)
        return out

    def cache_pspecs(self, cache) -> Dict:
        """Key-aware cache sharding with fallbacks.

        KV caches (period, batch, seq, kv_heads, hd): batch over the batch
        axes when divisible; otherwise (batch=1 long-context cells) the
        *sequence* dim is sharded — over 'data', and additionally over
        'model' when the kv-head count doesn't divide the model axis.
        SSM states shard heads over 'model'; conv tails shard channels.
        """
        ax = self.ax

        def kv_spec(a):
            per, b, kv, s, hd = a.shape   # decode-native layout
            batch_ok = b % ax.size(ax.batch_axes) == 0 and \
                ax.size(ax.batch_axes) > 1
            heads_ok = kv % ax.size("model") == 0 and ax.size("model") > 1
            if batch_ok:
                return ax.spec((None, ax.batch_axes,
                                "model" if heads_ok else None, None, None),
                               a.shape)
            seq_axes = ("data",) if heads_ok else ("data", "model")
            if ax.has("pod"):
                seq_axes = ("pod",) + seq_axes
            return ax.spec((None, None, "model" if heads_ok else None,
                            seq_axes, None), a.shape)

        def spec_of(path, a):
            key = str(path[-1].key) if hasattr(path[-1], "key") else ""
            if key in ("k", "v", "ck", "cv"):
                return kv_spec(a)
            if key == "state":       # (period, b, nh, p, n)
                return ax.spec((None, ax.batch_axes, "model", None, None),
                               a.shape)
            if key in ("conv_x", "conv_bc"):   # (period, b, w-1, ch)
                return ax.spec((None, ax.batch_axes, None, "model"),
                               a.shape)
            return ax.spec((None, ax.batch_axes) + (None,) * (a.ndim - 2),
                           a.shape)

        return jax.tree_util.tree_map_with_path(spec_of, cache)

    # ------------------------------------------------------------ forward

    def _embed(self, params, tokens):
        x = params["embed"][tokens].astype(self.compute_dtype)
        return x * jnp.asarray(self.cfg.d_model ** 0.5, self.compute_dtype)

    def _logits(self, params, x):
        x = rms_norm(x, params["final_norm"])
        logits = jnp.einsum("bsd,vd->bsv", x,
                            params["embed"].astype(self.compute_dtype))
        logits = shard(logits, self.ax, (self.ax.batch_axes, None, "model"))
        return softcap(logits, self.cfg.final_softcap)

    def _mixer(self, lp, spec: LayerSpec, h, mode, cache, pos, src):
        cfg, ax = self.cfg, self.ax
        local = spec.mixer == ATTN_LOCAL
        if spec.mixer == MAMBA:
            if mode == "full":
                return M.mamba_full(lp["mix"], h, cfg, ax, self.impl), cache
            if mode == "prefill":
                return M.mamba_prefill(lp["mix"], h, cfg, ax, cache,
                                       self.impl)
            return M.mamba_decode(lp["mix"], h, cfg, ax, cache)
        if spec.mixer == ATTN_CROSS:
            if mode in ("full", "prefill"):
                out = A.attend_cross(lp["mix"], h, src, cfg, ax)
                if mode == "prefill":
                    cache = A.fill_cross_cache(lp["mix"], src, cfg, cache)
                return out, cache
            return A.decode_cross_attn(lp["mix"], h, cfg, ax, cache), cache
        # Self-attention.
        if mode == "full":
            return A.attend_full(lp["mix"], h, cfg, ax, local,
                                 self.impl), cache
        if mode == "prefill":
            return A.prefill_attn(lp["mix"], h, cfg, ax, cache, local,
                                  self.impl)
        return A.decode_attn(lp["mix"], h, cfg, ax, cache, pos, local,
                             self.impl)

    def _block(self, x, blk, spec_cache, mode, pos, src):
        """One period block. blk/spec_cache: per-period slices."""
        new_cache = {}
        for i, sp in enumerate(self.pattern):
            lp = blk[f"l{i}"]
            lc = spec_cache.get(f"l{i}", {}) if spec_cache else {}
            h = rms_norm(x, lp["norm1"])
            # Split the layer cache between mixer entries and cross entries.
            if sp.cross:
                mix_c = {k: v for k, v in lc.items() if k in ("k", "v")}
                cross_c = {k: v for k, v in lc.items() if k in ("ck", "cv")}
            else:
                mix_c, cross_c = lc, None
            out, mix_c = self._mixer(lp, sp, h, mode, mix_c, pos, src)
            x = x + out
            if sp.cross:
                hc = rms_norm(x, lp["norm_cross"])
                if mode in ("full", "prefill"):
                    x = x + A.attend_cross(lp["cross"], hc, src, self.cfg,
                                           self.ax)
                    if mode == "prefill":
                        cross_c = A.fill_cross_cache(lp["cross"], src,
                                                     self.cfg, cross_c)
                else:
                    x = x + A.decode_cross_attn(lp["cross"], hc, self.cfg,
                                                self.ax, cross_c)
            if sp.mlp != NONE:
                h2 = rms_norm(x, lp["norm2"])
                if sp.mlp == DENSE:
                    x = x + F.dense_mlp(lp["mlp"], h2, self.ax)
                else:
                    x = x + F.moe_mlp(lp["mlp"], h2, self.cfg, self.ax,
                                      self.mesh)
            if spec_cache is not None:
                nc = dict(mix_c or {})
                if sp.cross and cross_c:
                    nc.update(cross_c)
                new_cache[f"l{i}"] = nc
        return x, new_cache

    def _run_blocks(self, params, x, mode, cache=None, pos=None, src=None):
        remat = self.cfg.remat and mode == "full"

        if self.unroll:
            return self._run_blocks_unrolled(params, x, mode, cache, pos,
                                             src, remat)

        if cache is None:
            def body(carry, blk):
                y, _ = self._block(carry, blk, None, mode, pos, src)
                return y, None
            if remat:
                body = jax.checkpoint(body)
            x, _ = jax.lax.scan(body, x, params["blocks"])
            return x, None

        def body(carry, xs):
            blk, cb = xs
            y, nc = self._block(carry, blk, cb, mode, pos, src)
            return y, nc

        x, new_cache = jax.lax.scan(body, x, (params["blocks"], cache))
        return x, new_cache

    def _run_blocks_unrolled(self, params, x, mode, cache, pos, src, remat):
        """Python loop over periods — identical math to the scan path."""
        new_caches = []
        for i in range(self.cfg.n_periods):
            blk = jax.tree.map(lambda a: a[i], params["blocks"])
            cb = jax.tree.map(lambda a: a[i], cache) if cache is not None \
                else None

            def body(carry, blk=blk, cb=cb):
                return self._block(carry, blk, cb, mode, pos, src)

            if remat and cache is None:
                body = jax.checkpoint(body)
            x, nc = body(x)
            if cache is not None:
                new_caches.append(nc)
        if cache is None:
            return x, None
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_caches)
        return x, stacked

    def encode(self, params, frames):
        """Whisper encoder over stub frame embeddings (b, F, d)."""
        x = frames.astype(self.compute_dtype)
        enc = params["encoder"]

        def body(carry, blk):
            h = rms_norm(carry, blk["norm1"])
            out = A.attend_full(blk["mix"], h, self.cfg, self.ax,
                                local=False, impl="xla", causal=False)
            carry = carry + out
            h2 = rms_norm(carry, blk["norm2"])
            carry = carry + F.dense_mlp(blk["mlp"], h2, self.ax)
            return carry, None

        if self.unroll:
            for i in range(self.cfg.encoder_layers):
                x, _ = body(x, jax.tree.map(lambda a: a[i], enc["blocks"]))
        else:
            x, _ = jax.lax.scan(body, x, enc["blocks"])
        return rms_norm(x, enc["norm"])

    def _frontend(self, params, batch):
        cfg = self.cfg
        if cfg.family == "audio":
            return self.encode(params, batch["frontend"])
        if cfg.family == "vlm":
            return rms_norm(batch["frontend"].astype(self.compute_dtype),
                            params["front_norm"])
        return None

    # -------------------------------------------------------- entry points

    def loss(self, params, batch) -> jax.Array:
        params = jax.tree.map(
            lambda a: a.astype(self.compute_dtype)
            if a.dtype == jnp.float32 and a.ndim > 1 else a, params)
        src = self._frontend(params, batch)
        x = self._embed(params, batch["tokens"])
        x = shard(x, self.ax, (self.ax.batch_axes, None, None))
        x, _ = self._run_blocks(params, x, "full", src=src)
        logits = self._logits(params, x)
        return cross_entropy_loss(logits, batch["labels"])

    def prefill(self, params, batch, cache):
        src = self._frontend(params, batch)
        x = self._embed(params, batch["tokens"])
        x, cache = self._run_blocks(params, x, "prefill", cache=cache,
                                    src=src)
        logits = self._logits(params, x[:, -1:, :])
        return logits, cache

    def decode(self, params, tokens, cache, pos):
        """tokens: (b, 1); pos: scalar int32 (one shared write position)
        or (b,) int32 (per-row positions — continuous batching)."""
        x = self._embed(params, tokens)
        x, cache = self._run_blocks(params, x, "decode", cache=cache,
                                    pos=pos)
        logits = self._logits(params, x)
        return logits, cache
