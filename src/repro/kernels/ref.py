"""Pure-jnp oracles for the Pallas kernels.

These are *independent* straight-line implementations (no online softmax,
no chunking tricks) used by the kernel test sweeps; the model code paths
(`models.attention._sdpa`, `models.mamba2.ssd_chunked`) are separately
cross-checked against these same oracles.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -2.0e38


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        softcap: Optional[float] = None) -> jax.Array:
    """q: (BH, S, hd); k/v: (BKV, S, hd); GQA via row grouping."""
    bh, s, hd = q.shape
    bkv = k.shape[0]
    g = bh // bkv
    qg = q.reshape(bkv, g, s, hd).astype(jnp.float32)
    scores = jnp.einsum("bgsd,btd->bgst", qg,
                        k.astype(jnp.float32)) / np.sqrt(hd)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgst,btd->bgsd", probs, v.astype(jnp.float32))
    return out.reshape(bh, s, hd).astype(q.dtype)


def ssd_ref(x: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array,
            s0: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array]:
    """Sequential (token-by-token) SSD recurrence — the ground truth.

    x: (BH, L, P); a: (BH, L) log-decay; B, C: (BH, L, N); s0: (BH, P, N).
    h_t = exp(a_t) h_{t-1} + x_t B_t^T ;  y_t = h_t C_t
    """
    bh, l, p = x.shape
    n = B.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((bh, p, n), jnp.float32)

    def step(h, inp):
        xt, at, Bt, Ct = inp
        h = jnp.exp(at)[:, None, None] * h \
            + xt[..., :, None].astype(jnp.float32) * Bt[..., None, :]
        y = jnp.einsum("bpn,bn->bp", h, Ct)
        return h, y

    xs = (x.transpose(1, 0, 2), a.astype(jnp.float32).transpose(1, 0),
          B.astype(jnp.float32).transpose(1, 0, 2),
          C.astype(jnp.float32).transpose(1, 0, 2))
    hT, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2).astype(x.dtype), hT
