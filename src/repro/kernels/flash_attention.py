"""Flash attention forward — Pallas TPU kernel.

TPU-native adaptation: the online-softmax accumulator lives in VMEM
scratch that persists across the innermost (sequential) grid dimension;
block shapes are MXU-aligned (multiples of 128 on the contraction dims).
Supports causal masking, sliding-window (gemma2 local layers), logit
softcap (gemma2), and GQA via a head→kv-head index map — no KV
duplication in HBM.

Layout contract: q (B*KV*G, S, hd) where G = n_heads // n_kv_heads and
consecutive G rows share one kv head; k/v (B*KV, S, hd).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, causal: bool,
                  window: Optional[int], softcap: Optional[float],
                  seq_len: int, scale: float, q_offset: int):
    """``seq_len`` is the KV extent; query row i sits at absolute position
    ``q_offset + qi·block_q + i`` (rectangular q/kv supports decode)."""
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window

    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        v = v_ref[0].astype(jnp.float32)
        # Zero padded KV rows: out-of-bounds block reads are undefined
        # (NaN in interpret mode) and 0·NaN would poison the p·V dot.
        kvalid = kj * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_k, 1), 0) < seq_len
        v = jnp.where(kvalid, v, 0.0)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[...] = m_new
        l_ref[...] = l_new

    if causal:
        # Skip fully-masked blocks (upper triangle / outside the window).
        q_max = q_offset + qi * block_q + block_q - 1
        k_min = kj * block_k
        needed = k_min <= q_max
        if window is not None:
            k_max = kj * block_k + block_k - 1
            needed &= k_max > q_max - block_q - window + 1
        pl.when(needed)(_compute)
    else:
        _compute()

    @pl.when(kj == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_bkv(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        softcap: Optional[float] = None,
                        block_q: int = 128, block_k: int = 128,
                        q_offset: int = 0,
                        interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, hd) with BH = B*KV*G; k/v: (BKV, Skv, hd).

    Rectangular q/kv: ``Sq == Skv`` for training/prefill; ``Sq == 1`` with
    ``q_offset = position`` is the flash-decode step (the KV cache never
    leaves VMEM-blocked streaming — no score materialization in HBM).
    """
    bh, sq, hd = q.shape
    bkv, skv, _ = k.shape
    g = bh // bkv
    scale = 1.0 / np.sqrt(hd)
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    nq = pl.cdiv(sq, block_q)
    nk = pl.cdiv(skv, block_k)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, causal=causal,
        window=window, softcap=softcap, seq_len=skv, scale=scale,
        q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // g, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
