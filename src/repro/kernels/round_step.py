"""Fused Pallas round-step kernel for the event-rounds sweep engine.

One outer step of ``repro.sim.rounds`` — masked window compaction,
dynamic-slice job admission, the per-chunk size classes and the
``compact_every`` unrolled event rounds (multi-pass first-fit, size-class
kill selection, prefix-sum queue admission, the contended-stretch
coalescer) — executes as ONE ``pl.pallas_call`` per lane instead of the
few hundred XLA ops the traced body dispatches. The per-op dispatch
overhead at (P, K) lane sizes is the measured cost floor of the rounds
engine (see the README perf ledger); fusing the whole body into a single
kernel program attacks exactly that floor. Lanes stay ordinary vmap
axes, so the (point × trace) grid AND the ``sharded_grid_map`` backend
compose unchanged — under vmap the kernel's batch axis becomes the
Pallas grid.

Bit-equality by construction
----------------------------
The kernel body does not reimplement the round math: it reads its refs
into plain jnp values, rebuilds the same ``ctx`` dict the XLA path uses
(:func:`_ctx_from_inputs` mirrors ``rounds._lane_ctx``) and calls the
SAME :func:`repro.sim.rounds._chunk_core`. The loop state round-trips
through a float pack (:func:`pack_carry` / :func:`unpack_carry`) that is
exact for every field — bools are 0/1, the int cursors stay far below
2**24, times and node counts are already the pack dtype — so the fused
backend is bit-identical to ``kernel="xla"`` on both f32 and f64
(tests/test_round_step_kernel.py asserts equality on the packed state
after every chunk, not just on the final rows).

State layout
------------
``sc`` (``SC_SIZE``,) scalar vector: the nine loop scalars followed by
the eleven metric accumulators in ``rounds.ACC_KEYS`` order. ``win``
(``WIN_ROWS``, K) window matrix: submit / size / runtime / run / done /
start / end per lane. Inputs per lane: ``jobs`` (3, Jp) job table,
``rises`` (2, NR) FB demand-rise stops, ``wstab`` (2, NT) WS fold
tables, ``prm`` policy scalars ((2,) fb: lease, C; (6,) flb_nub: lease,
B, lb_ws, U, V, G).

``interpret`` defaults to True off-TPU (validation mode, the only mode
CI exercises) and False on TPU — the target regime, where the fused
program runs from VMEM without per-op dispatch.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.sim import rounds as _rounds
from repro.sim.rounds import ACC_KEYS, RoundsSpec

# ----------------------------------------------------------- state layout

SC_T = 0            # current time (the while_loop exit test reads this)
SC_OWNED = 1
SC_POOL = 2
SC_USED = 3
SC_HAS_QUEUE = 4    # bool as 0/1
SC_WSV = 5
SC_ALLOC_PREV = 6
SC_RISE_I = 7       # int cursor as float (exact < 2**24)
SC_NEXT_ROW = 8     # int cursor as float (exact < 2**24)
SC_ACC0 = 9         # first of the len(ACC_KEYS) accumulators
SC_SIZE = SC_ACC0 + len(ACC_KEYS)

WIN_SUB, WIN_SZ, WIN_RT, WIN_RUN, WIN_DONE, WIN_START, WIN_END = range(7)
WIN_ROWS = 7


def pack_carry(core) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """17-tuple loop state → ``(sc (SC_SIZE,), win (WIN_ROWS, K))``."""
    (t, owned, pool_pbj, used, has_queue, wsv, alloc_prev, rise_i,
     next_row, w_sub, w_sz, w_rt, run, done, start_t, end_t, acc) = core
    f = w_sub.dtype
    sc = jnp.stack([jnp.asarray(v, f) for v in
                    (t, owned, pool_pbj, used, has_queue, wsv, alloc_prev,
                     rise_i, next_row)]
                   + [jnp.asarray(acc[k], f) for k in ACC_KEYS])
    win = jnp.stack([w_sub, w_sz, w_rt, run.astype(f), done.astype(f),
                     start_t, end_t])
    return sc, win


def unpack_carry(sc: jnp.ndarray, win: jnp.ndarray):
    """Inverse of :func:`pack_carry` — exact for every field."""
    acc = {k: sc[SC_ACC0 + i] for i, k in enumerate(ACC_KEYS)}
    return (sc[SC_T], sc[SC_OWNED], sc[SC_POOL], sc[SC_USED],
            sc[SC_HAS_QUEUE] > 0, sc[SC_WSV], sc[SC_ALLOC_PREV],
            sc[SC_RISE_I].astype(jnp.int32),
            sc[SC_NEXT_ROW].astype(jnp.int32),
            win[WIN_SUB], win[WIN_SZ], win[WIN_RT],
            win[WIN_RUN] > 0, win[WIN_DONE] > 0,
            win[WIN_START], win[WIN_END], acc)


def lane_inputs(policy: str, ctx: Dict) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                 jnp.ndarray, jnp.ndarray]:
    """One lane's ``rounds._lane_ctx`` dict → the kernel's four stacked
    input arrays ``(jobs, rises, wstab, prm)``."""
    jobs = jnp.stack([ctx["tr_submit"], ctx["tr_size"], ctx["tr_runtime"]])
    rises = jnp.stack([ctx["rise_times"], ctx["rise_vals"]])
    wstab = jnp.stack([ctx["ws_winmax"], ctx["ws_at_tick"]])
    f = jobs.dtype
    if policy == "fb":
        prm = jnp.stack([ctx["L"].astype(f), ctx["C"].astype(f)])
    else:
        prm = jnp.stack([ctx[k].astype(f)
                         for k in ("L", "B", "lb_ws", "U", "V", "G")])
    return jobs, rises, wstab, prm


def _ctx_from_inputs(policy: str, jobs, rises, wstab, prm) -> Dict:
    """Rebuild the ``rounds._lane_ctx`` dict from the stacked kernel
    inputs — the exact inverse of :func:`lane_inputs`, so the kernel
    body feeds ``_chunk_core`` the same values the XLA path does."""
    ctx = {
        "L": prm[0],
        "tr_submit": jobs[0], "tr_size": jobs[1], "tr_runtime": jobs[2],
        "rise_times": rises[0], "rise_vals": rises[1],
        "ws_winmax": wstab[0], "ws_at_tick": wstab[1],
    }
    if policy == "fb":
        ctx["C"] = prm[1]
    else:
        ctx["B"], ctx["lb_ws"], ctx["U"], ctx["V"], ctx["G"] = (
            prm[1], prm[2], prm[3], prm[4], prm[5])
    return ctx


# ------------------------------------------------------------- the kernel

@functools.lru_cache(maxsize=None)
def _chunk_kernel(policy: str, spec: RoundsSpec):
    """The fused kernel body for one (policy, spec): read refs, rebuild
    ctx, run the shared ``_chunk_core``, write the packed state back.
    Cached so repeated traces reuse one function object (the jit caches
    above this key on (policy, spec) too — see ``rounds._rounds_lane``)."""

    def kernel(jobs_ref, rises_ref, wstab_ref, prm_ref, sc_ref, win_ref,
               sc_out_ref, win_out_ref):
        ctx = _ctx_from_inputs(policy, jobs_ref[...], rises_ref[...],
                               wstab_ref[...], prm_ref[...])
        core = unpack_carry(sc_ref[...], win_ref[...])
        core = _rounds._chunk_core(policy, ctx, spec, core)
        sc, win = pack_carry(core)
        sc_out_ref[...] = sc
        win_out_ref[...] = win

    return kernel


def chunk_step(jobs, rises, wstab, prm, sc, win, *, policy: str,
               spec: RoundsSpec, interpret: Optional[bool] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One fused outer step: compaction + admission + size classes +
    ``spec.compact_every`` rounds, as a single ``pallas_call``. Under
    vmap the lane axis becomes the Pallas grid."""
    if interpret is None:
        from repro.kernels.ops import _default_interpret
        interpret = _default_interpret()
    return pl.pallas_call(
        _chunk_kernel(policy, spec),
        out_shape=[jax.ShapeDtypeStruct(sc.shape, sc.dtype),
                   jax.ShapeDtypeStruct(win.shape, win.dtype)],
        interpret=interpret,
    )(jobs, rises, wstab, prm, sc, win)


def chunk_step_ref(jobs, rises, wstab, prm, sc, win, *, policy: str,
                   spec: RoundsSpec, interpret: Optional[bool] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Unfused reference with the kernel's exact signature: the same
    pack → ``_chunk_core`` → unpack round-trip as plain traced jnp ops
    (a few hundred XLA dispatches). The bit-equality tests and the
    ``roundstep`` microbenchmark diff :func:`chunk_step` against this."""
    del interpret
    ctx = _ctx_from_inputs(policy, jobs, rises, wstab, prm)
    core = unpack_carry(sc, win)
    return pack_carry(_rounds._chunk_core(policy, ctx, spec, core))
