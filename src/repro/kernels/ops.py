"""Jit'd public wrappers around the Pallas kernels.

These expose model-layout entry points (``flash_attention`` over
(b, s, h, hd) tensors; ``ssd`` over (b, l, h, p) + grouped B/C) and fold
them into the kernel layouts. ``interpret`` defaults to True on CPU
(validation mode) and False on TPU (the real kernel).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_bkv
from repro.kernels.flash_decode import flash_decode_bkv
from repro.kernels.ssd_scan import ssd_scan_bh


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Model layout: q (b, s, h, hd); k/v (b, s, kv, hd) → (b, s, h, hd)."""
    if interpret is None:
        interpret = _default_interpret()
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    # (b, s, kv, g, hd) → (b*kv*g, s, hd); consecutive g rows share a kv head.
    qk = q.reshape(b, s, kv, g, hd).transpose(0, 2, 3, 1, 4) \
        .reshape(b * kv * g, s, hd)
    kk = k.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    vk = v.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    out = flash_attention_bkv(qk, kk, vk, causal=causal, window=window,
                              softcap=softcap, block_q=block_q,
                              block_k=block_k, interpret=interpret)
    return out.reshape(b, kv, g, s, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(b, s, h, hd)


@functools.partial(jax.jit, static_argnames=("window", "softcap",
                                             "block_k", "interpret"))
def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 pos: jax.Array, *, window: Optional[int] = None,
                 softcap: Optional[float] = None, block_k: int = 512,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Serving layout: q (b, 1, h, hd); k/v cache (b, kv, s, hd);
    pos () int32. Returns (b, 1, h, hd)."""
    if interpret is None:
        interpret = _default_interpret()
    b, one, h, hd = q.shape
    kv = k_cache.shape[1]
    g = h // kv
    qf = q.reshape(b, kv, g, hd).reshape(b * kv, g, hd)
    kf = k_cache.reshape(b * kv, k_cache.shape[2], hd)
    vf = v_cache.reshape(b * kv, v_cache.shape[2], hd)
    out = flash_decode_bkv(qf, kf, vf, pos, window=window, softcap=softcap,
                           block_k=block_k, interpret=interpret)
    return out.reshape(b, kv, g, hd).reshape(b, 1, h, hd)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array, *,
        init_state: Optional[jax.Array] = None, chunk: int = 128,
        interpret: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """Model layout: x (b, l, h, p); a (b, l, h); B/C (b, l, g, n);
    init_state (b, h, p, n). Returns (y (b,l,h,p), state (b,h,p,n))."""
    if interpret is None:
        interpret = _default_interpret()
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * h, l, n)
    Ch = jnp.repeat(C, rep, axis=2).transpose(0, 2, 1, 3).reshape(b * h, l, n)
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, l, p)
    af = a.transpose(0, 2, 1).reshape(b * h, l)
    s0 = None if init_state is None else \
        init_state.reshape(b * h, p, n).astype(jnp.float32)
    y, sT = ssd_scan_bh(xf, af, Bh, Ch, s0=s0, chunk=chunk,
                        interpret=interpret)
    return (y.reshape(b, h, l, p).transpose(0, 2, 1, 3),
            sT.reshape(b, h, p, n))
