"""Pallas kernels — OPTIONAL layer for the repo's compute hot-spots.

Four kernels, each with an interpret-mode CPU fallback selected
automatically off-TPU (``ops._default_interpret``) so every code path
runs — and is tested — on plain CPU CI, while TPU gets the compiled
program:

* ``flash_attention.py`` — blocked online-softmax attention over
  (b·kv·g, s, hd) lanes (causal / windowed / softcapped); public entry
  ``ops.flash_attention``. Fallback: the same math as a jnp reference
  (``ref.py``) validated bit-close in tests/test_kernels.py.
* ``flash_decode.py`` — single-position KV-cache decode attention,
  split-K over cache blocks; public entry ``ops.flash_decode``.
* ``ssd_scan.py`` — chunked state-space (SSD) scan over (b·h, l, p)
  with grouped B/C; public entry ``ops.ssd``.
* ``round_step.py`` — the fused round-step of the event-rounds sweep
  engine (``repro.sim.rounds``): window compaction, job admission,
  size classes and the unrolled ``compact_every`` event rounds as ONE
  kernel per (point × trace) lane, selected via
  ``ScanOptions(kernel="pallas")``. No separate reference module: the
  kernel body calls the engine's own ``_chunk_core``, so the unfused
  engine IS the reference (``round_step.chunk_step_ref``), bit-identical
  rows by construction (tests/test_round_step_kernel.py).

Add further kernels ONLY for hot-spots the paper itself optimizes.
"""
