"""Mamba2 SSD chunked scan — Pallas TPU kernel.

The chunk-local work is three MXU-friendly matmuls ((Q,Q)·(Q,P),
(N,Q)·(Q,P), (Q,N)·(N,P)); the inter-chunk recurrence is carried in a
(P, N) fp32 VMEM scratch that persists across the innermost (sequential)
chunk grid dimension — the TPU-native replacement for the GPU kernel's
warp-level scan.

Layout contract: x (BH, L, P); a (BH, L); B, C (BH, L, N) — the caller
broadcasts groups to heads and folds batch×heads into the leading dim.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, s0_ref, y_ref, sT_ref,
                state_ref, *, chunk: int):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    a = a_ref[0].astype(jnp.float32)          # (Q,)
    B = b_ref[0].astype(jnp.float32)          # (Q, N)
    C = c_ref[0].astype(jnp.float32)          # (Q, N)

    a_cum = jnp.cumsum(a)                     # (Q,)
    # Intra-chunk: L[i, j] = exp(a_cum[i] - a_cum[j]) for i >= j.
    seg = a_cum[:, None] - a_cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    y = jax.lax.dot_general(cb * L, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)
    # Off-diagonal: contribution of the carried state.
    state = state_ref[...]                                        # (P, N)
    decay_out = jnp.exp(a_cum)                                    # (Q,)
    y += decay_out[:, None] * jax.lax.dot_general(
        C, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                       # (Q, P)
    y_ref[0] = y.astype(y_ref.dtype)
    # State update: S' = S * exp(sum a) + sum_i exp(a_cum[-1]-a_cum[i]) x_i B_i^T
    total = a_cum[-1]
    w = jnp.exp(total - a_cum)                                    # (Q,)
    xB = jax.lax.dot_general(x * w[:, None], B, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (P, N)
    state_ref[...] = state * jnp.exp(total) + xB

    @pl.when(ci == nc - 1)
    def _finish():
        sT_ref[0] = state_ref[...].astype(sT_ref.dtype)


def ssd_scan_bh(x: jax.Array, a: jax.Array, B: jax.Array, C: jax.Array,
                s0: Optional[jax.Array] = None, chunk: int = 128,
                interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (BH, L, P); a: (BH, L); B, C: (BH, L, N); s0: (BH, P, N)."""
    bh, l, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, l)
    nc = pl.cdiv(l, chunk)
    assert nc * chunk == l, (l, chunk)
    if s0 is None:
        s0 = jnp.zeros((bh, p, n), jnp.float32)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, sT = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, p, n), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, p, n), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, l, p), x.dtype),
            jax.ShapeDtypeStruct((bh, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, a, B, C, s0)
    return y, sT
