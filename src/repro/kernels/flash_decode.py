"""Flash-decode — single-token attention against a (B·KV, S, hd) cache.

The serving hot loop: one query token per sequence attends to a 32k–512k
KV cache. The kernel streams the cache through VMEM in ``block_k`` tiles
with an online-softmax accumulator held in VMEM scratch; scores never
touch HBM, and the write position ``pos`` is a scalar-prefetch operand so
decode steps never recompile. HBM traffic per layer ≈ one cache read —
the bandwidth-bound ideal (see EXPERIMENTS.md §Perf cell A).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, block_k: int, window: Optional[int],
                   softcap: Optional[float], kv_len: int, scale: float):
    kj = pl.program_id(1)
    nk = pl.num_programs(1)
    pos = pos_ref[0]

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (1, block_k), 1)[0]
    valid = (k_pos <= pos) & (k_pos < kv_len)
    if window is not None:
        valid &= k_pos > pos - window

    @pl.when(kj * block_k <= pos)          # skip fully-future blocks
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale          # (G, hd)
        k = k_ref[0].astype(jnp.float32)                  # (block_k, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(valid[None, :], s, NEG_INF)         # (G, block_k)
        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        v = jnp.where(valid[:, None], v_ref[0].astype(jnp.float32), 0.0)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = alpha * l_prev + jnp.sum(p, axis=-1)

    @pl.when(kj == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode_bkv(q: jax.Array, k: jax.Array, v: jax.Array,
                     pos: jax.Array, *, window: Optional[int] = None,
                     softcap: Optional[float] = None, block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: (BKV, G, hd) one token per row-group; k/v: (BKV, S, hd);
    pos: () int32 — the current absolute position (cache write index)."""
    bkv, g, hd = q.shape
    _, s, _ = k.shape
    block_k = min(block_k, s)
    nk = pl.cdiv(s, block_k)
    kernel = functools.partial(
        _decode_kernel, block_k=block_k, window=window, softcap=softcap,
        kv_len=s, scale=1.0 / np.sqrt(hd))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bkv, nk),
        in_specs=[
            pl.BlockSpec((1, g, hd), lambda b, j, pos_ref: (b, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j, pos_ref: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, j, pos_ref: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, hd), lambda b, j, pos_ref: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bkv, g, hd), q.dtype),
        interpret=interpret,
    )(pos[None].astype(jnp.int32), q, k, v)
