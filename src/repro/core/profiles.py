"""Demand-profile helpers shared across layers.

A *demand profile* is a right-continuous step series — (time, value)
change points — which is how every demand signal in the reproduction is
represented: the WS resource-consumption trace (Fig. 10), the EC2
per-job allocation curve, and the serving replicas' slot-utilization
samples. This module is the single place that integrates, samples and
windows such series; it is reused by

  * ``repro.sim.sweep``     — exact WS node-hour integrals and change
                              points for the vectorized sweep,
  * ``repro.core.jaxsim``   — the per-substep WS demand profile of the
                              lax.scan tick simulator,
  * ``repro.core.ws_manager`` (and through it the serving autoscaler) —
                              the trailing-window utilization average of
                              the §6.4 instance-adjustment policy.
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

__all__ = ["step_points", "step_integral", "sample_steps",
           "per_tick_profile", "job_demand_profile", "scale_profile",
           "windowed_mean"]


def step_points(trace: Sequence[Tuple[float, float]], duration: float
                ) -> Tuple[np.ndarray, np.ndarray]:
    """Normalize a change-point series to ``(times, values)`` arrays.

    Matches the event engine's reading of a WS trace exactly: entries at
    ``t <= 0`` collapse into the initial value (the last one wins), and
    entries beyond ``duration`` never fire. The returned series starts
    at ``times[0] == 0.0`` and is right-continuous.
    """
    initial = 0.0
    times: List[float] = [0.0]
    values: List[float] = [initial]
    for t, d in trace:
        if t <= 0:
            values[0] = float(d)
        elif t <= duration + 1e-9:
            times.append(float(t))
            values.append(float(d))
    t_arr = np.asarray(times, np.float64)
    v_arr = np.asarray(values, np.float64)
    # The event engine heap-orders whatever it is given (insertion order
    # breaking time ties); a stable sort reproduces that for unsorted input.
    order = np.argsort(t_arr, kind="stable")
    return t_arr[order], v_arr[order]


def step_integral(times: np.ndarray, values: np.ndarray,
                  duration: float) -> float:
    """``∫_0^duration`` of the step series (value·seconds, exact)."""
    edges = np.minimum(np.append(times[1:], duration), duration)
    widths = np.maximum(edges - np.minimum(times, duration), 0.0)
    return float(np.dot(values, widths))


def sample_steps(times: np.ndarray, values: np.ndarray,
                 at: np.ndarray) -> np.ndarray:
    """Value of the step series at each query time (right-continuous)."""
    idx = np.searchsorted(times, at, side="right") - 1
    return values[np.clip(idx, 0, len(values) - 1)]


def per_tick_profile(trace: Sequence[Tuple[float, float]], duration: float,
                     tick_seconds: float) -> np.ndarray:
    """Per-lease-tick demand profile: the series sampled at ``k·tick``."""
    times, values = step_points(trace, duration)
    n = int(np.ceil(duration / tick_seconds))
    return sample_steps(times, values, np.arange(n) * tick_seconds)


def job_demand_profile(submits: np.ndarray, sizes: np.ndarray,
                       duration: float, tick_seconds: float) -> np.ndarray:
    """Aggregate node demand *submitted* within each lease window — a
    segment-sum of job sizes over lease windows; a quick feasibility
    read on a capacity C (see examples/sweep_capacity.py)."""
    n = int(np.ceil(duration / tick_seconds))
    submits = np.asarray(submits, np.float64)
    keep = (submits >= 0) & (submits < duration)
    idx = (submits[keep] // tick_seconds).astype(np.int64)
    return np.bincount(np.minimum(idx, n - 1),
                       weights=np.asarray(sizes, np.float64)[keep],
                       minlength=n)


def scale_profile(trace: Sequence[Tuple[float, float]], factor: float
                  ) -> List[Tuple[float, int]]:
    """Scale a WS demand trace's values by ``factor`` (times unchanged).

    The multi-trace sweep studies (``run_sweep_workloads``) batch the
    same parameter grid over demand variants — e.g. the §6.2 World Cup
    profile at 0.5× / 2× its recorded intensity — and this is the
    canonical way to derive them: values round to whole VMs and never go
    negative, so a scaled trace is still a valid demand profile.
    """
    if factor < 0:
        raise ValueError(f"factor must be >= 0, got {factor}")
    return [(t, max(0, int(round(v * factor)))) for t, v in trace]


def windowed_mean(samples: Sequence[Tuple[float, float]], t: float,
                  window: float) -> Tuple[float, List[Tuple[float, float]]]:
    """Trailing-window average: prune samples older than ``t - window``
    and average the rest. Returns ``(average, pruned_samples)``."""
    kept = [(ts, u) for ts, u in samples if ts >= t - window]
    if not kept:
        return 0.0, kept
    return sum(u for _, u in kept) / len(kept), kept
