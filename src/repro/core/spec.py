"""Runtime Environment (RE) specification — PhoenixCloud §4.2.

The paper expresses RE requirements as an XML document (Fig. 3). Here the
specification is a typed dataclass with the same fields plus the
TPU-adaptation fields (chip granularity, arch payload). ``to_xml`` emits a
document shaped like the paper's Fig. 3 so specs remain interchangeable
with the original format.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional
from xml.etree import ElementTree as ET


class Relationship(enum.Enum):
    """Provider relationship (§4.2 item 1)."""

    SAME = "same"          # one party is both resource + service provider (DCS)
    AFFILIATED = "affiliated"  # Case Three: private cloud inside one org
    BUSINESS = "business"  # Case One/Two: public cloud tenancy


class WorkloadType(enum.Enum):
    """Workload families (§4.2 item 2).

    The paper supports parallel batch jobs and Web services. On the TPU
    cluster these are training jobs and serving replicas respectively; the
    original names are kept as aliases so the reproduction reads like the
    paper.
    """

    PARALLEL_BATCH_JOBS = "parallel_batch_jobs"   # == training jobs
    WEB_SERVICE = "web_service"                   # == inference serving

    # Modern aliases.
    TRAINING = "parallel_batch_jobs"
    SERVING = "web_service"


class Granularity(enum.Enum):
    """Allocation granularity (§4.2 item 3)."""

    NODE = "node"
    VIRTUAL_MACHINE = "virtual_machine"
    CHIP_SLICE = "chip_slice"   # TPU adaptation: contiguous mesh slice


class CoordinationModel(enum.Enum):
    """Resource coordination models (§4.2 item 5)."""

    NONE = "none"          # independent provisioning (RightScale-style)
    FB = "FB"              # Fixed Bound — private cloud
    FLB_NUB = "FLB_NUB"    # Fixed Lower Bound / No Upper Bound — public cloud


class SetupPolicy(enum.Enum):
    """Setup work on provision/release (§4.2 item 6)."""

    NONE = "NO"            # hand nodes over as-is
    WIPE = "WIPE"          # scrub state (OS/data in the paper; HBM here)
    RELOAD = "RELOAD"      # TPU adaptation: reload weights onto the slice


@dataclasses.dataclass(frozen=True)
class ResourceBounds:
    """Lower (rigid) and upper (flexible) resource bounds (§4.2, Fig. 2).

    ``lower`` is guaranteed to the RE (or its coordinated partner).
    ``upper`` may be ``None`` — the FLB-NUB model leaves it undefined.
    """

    lower: int
    upper: Optional[int] = None

    def __post_init__(self) -> None:
        if self.lower < 0:
            raise ValueError(f"lower bound must be >= 0, got {self.lower}")
        if self.upper is not None and self.upper < self.lower:
            raise ValueError(
                f"upper bound {self.upper} < lower bound {self.lower}")


@dataclasses.dataclass(frozen=True)
class RuntimeEnvironmentSpec:
    """A complete RE specification (paper Fig. 3 + TPU fields)."""

    name: str
    relationship: Relationship
    workload: WorkloadType
    granularity: Granularity
    coordination: CoordinationModel
    bounds: ResourceBounds
    setup_policy: SetupPolicy = SetupPolicy.NONE
    # Consent bits (§4.2 item 4).
    wants_coordinated_partner: bool = True      # (a) partner from same provider
    allows_foreign_coordination: bool = True    # (b) share with other providers
    # TPU adaptation: which architecture config this RE's payload runs.
    arch: Optional[str] = None

    def validate(self) -> None:
        if self.coordination is CoordinationModel.FB:
            if self.bounds.upper is None or self.bounds.upper != self.bounds.lower:
                raise ValueError(
                    "FB model requires upper == lower (paper §5.1 rule 1)")
        if self.coordination is CoordinationModel.FLB_NUB:
            if self.bounds.upper is not None:
                raise ValueError(
                    "FLB-NUB model requires an undefined upper bound (§5.2 rule 1)")

    def to_xml(self) -> str:
        root = ET.Element("runtime_environment_agreement", name=self.name)
        ET.SubElement(root, "relationship", type=self.relationship.value)
        ET.SubElement(root, "workload", type=self.workload.value)
        env = ET.SubElement(
            root,
            "environment",
            type="coordinated" if self.coordination is not CoordinationModel.NONE
            else "independent",
            granularity=self.granularity.value,
            resource_coordination_mode=self.coordination.value,
            lower_bound_size=str(self.bounds.lower),
            upper_bound_size="null" if self.bounds.upper is None
            else str(self.bounds.upper),
            setup_policy=self.setup_policy.value,
        )
        if self.arch is not None:
            env.set("arch", self.arch)
        return ET.tostring(root, encoding="unicode")

    @staticmethod
    def from_xml(text: str) -> "RuntimeEnvironmentSpec":
        root = ET.fromstring(text)
        env = root.find("environment")
        assert env is not None
        upper = env.get("upper_bound_size")
        rel = root.find("relationship")
        wl = root.find("workload")
        assert rel is not None and wl is not None
        spec = RuntimeEnvironmentSpec(
            name=root.get("name", ""),
            relationship=Relationship(rel.get("type", "").strip()),
            workload=WorkloadType(wl.get("type", "").strip()),
            granularity=Granularity(env.get("granularity", "node").strip()),
            coordination=CoordinationModel(
                env.get("resource_coordination_mode", "none")),
            bounds=ResourceBounds(
                lower=int(env.get("lower_bound_size", "0")),
                upper=None if upper in (None, "null") else int(upper),
            ),
            setup_policy=SetupPolicy(env.get("setup_policy", "NO")),
            arch=env.get("arch"),
        )
        return spec


def paper_fig3_example() -> RuntimeEnvironmentSpec:
    """The example specification from the paper's Fig. 3."""
    return RuntimeEnvironmentSpec(
        name="user1",
        relationship=Relationship.BUSINESS,
        workload=WorkloadType.PARALLEL_BATCH_JOBS,
        granularity=Granularity.NODE,
        coordination=CoordinationModel.FLB_NUB,
        bounds=ResourceBounds(lower=100, upper=None),
        setup_policy=SetupPolicy.NONE,
    )
