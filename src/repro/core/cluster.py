"""Cloud-site resource ledger and accounting.

The paper counts ``nodes``; on the TPU adaptation the unit is a chip
(slice of the production mesh). The ledger is policy-free: it enforces
conservation (allocations never exceed capacity, never go negative) and
integrates the consumption curves that §6.1 of the paper defines as the
evaluation metrics:

  * total resource consumption  — integral of allocated units (node×hour),
  * peak resource consumption   — max instantaneous allocation,
  * accumulated times of adjusting resources — count of request / release /
    provision events (the management-overhead metric of Fig. 18).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional


class LedgerError(RuntimeError):
    pass


@dataclasses.dataclass
class _RESlot:
    allocated: int = 0
    adjust_events: int = 0


class Cluster:
    """Allocation ledger for one Cloud site.

    ``capacity=None`` models the public-cloud assumption of §5.2 (the
    provider owns "enough resources", N >> 2 tenants).
    """

    def __init__(self, capacity: Optional[int], t0: float = 0.0):
        if capacity is not None and capacity < 0:
            raise ValueError("capacity must be >= 0")
        self.capacity = capacity
        self._failed = 0
        self._res: Dict[str, _RESlot] = {}
        # Accounting state (piecewise-constant integration).
        self._t_last = t0
        self._node_seconds = 0.0
        self._peak = 0
        self._per_re_node_seconds: Dict[str, float] = {}

    # ---------------------------------------------------------------- ledger

    def register(self, re_name: str) -> None:
        if re_name in self._res:
            raise LedgerError(f"RE {re_name!r} already registered")
        self._res[re_name] = _RESlot()
        self._per_re_node_seconds[re_name] = 0.0

    def allocated(self, re_name: str) -> int:
        return self._res[re_name].allocated

    @property
    def total_allocated(self) -> int:
        return sum(s.allocated for s in self._res.values())

    @property
    def failed(self) -> int:
        """Nodes currently down (fault injection, ``repro.sim.faults``)."""
        return self._failed

    @property
    def effective_capacity(self) -> Optional[int]:
        """Surviving capacity: ``capacity - failed`` (None if unbounded)."""
        if self.capacity is None:
            return None
        return self.capacity - self._failed

    @property
    def idle(self) -> int:
        if self.capacity is None:
            raise LedgerError("idle undefined for unbounded capacity")
        # Clamped: right after a failure the site may transiently hold
        # more than the surviving capacity until the provision service's
        # on_fail handler drains the overflow.
        return max(0, self.capacity - self._failed - self.total_allocated)

    def adjust_events(self, re_name: Optional[str] = None) -> int:
        if re_name is not None:
            return self._res[re_name].adjust_events
        return sum(s.adjust_events for s in self._res.values())

    def allocate(self, t: float, re_name: str, n: int) -> None:
        """Provision ``n`` units to an RE (one adjust event if n > 0)."""
        if n < 0:
            raise LedgerError("allocate() takes n >= 0; use release()")
        if n == 0:
            return
        if (self.capacity is not None
                and self.total_allocated + n > self.capacity - self._failed):
            raise LedgerError(
                f"allocation of {n} to {re_name!r} exceeds capacity "
                f"{self.capacity} - {self._failed} failed "
                f"(allocated={self.total_allocated})")
        self._advance(t)
        slot = self._res[re_name]
        slot.allocated += n
        slot.adjust_events += 1
        self._peak = max(self._peak, self.total_allocated)

    def release(self, t: float, re_name: str, n: int) -> None:
        if n < 0:
            raise LedgerError("release() takes n >= 0")
        if n == 0:
            return
        slot = self._res[re_name]
        if slot.allocated < n:
            raise LedgerError(
                f"RE {re_name!r} releasing {n} but holds {slot.allocated}")
        self._advance(t)
        slot.allocated -= n
        slot.adjust_events += 1

    def transfer(self, t: float, src: str, dst: str, n: int) -> None:
        """Move units between coordinated REs (kill-reallocate path, §5.1)."""
        if n < 0:
            raise LedgerError("transfer() takes n >= 0")
        if n == 0:
            return
        if self._res[src].allocated < n:
            raise LedgerError(
                f"transfer {n} from {src!r} exceeds holding "
                f"{self._res[src].allocated}")
        self._advance(t)
        self._res[src].allocated -= n
        self._res[dst].allocated += n
        self._res[src].adjust_events += 1
        self._res[dst].adjust_events += 1

    # ------------------------------------------------------- fault injection

    def fail_nodes(self, t: float, n: int) -> int:
        """Mark ``n`` nodes as failed (clamped to the surviving count).
        Returns the number actually failed. The ledger itself stays
        policy-free: draining the overflow (killed jobs, shed WS
        replicas) is the provision service's job (``on_fail``)."""
        if self.capacity is None:
            raise LedgerError("fail_nodes undefined for unbounded capacity")
        if n < 0:
            raise LedgerError("fail_nodes() takes n >= 0")
        n = min(n, self.capacity - self._failed)
        if n > 0:
            self._advance(t)
            self._failed += n
        return n

    def repair_nodes(self, t: float, n: int) -> int:
        """Return ``n`` previously-failed nodes to service (clamped to
        the failed count). Returns the number actually repaired."""
        if n < 0:
            raise LedgerError("repair_nodes() takes n >= 0")
        n = min(n, self._failed)
        if n > 0:
            self._advance(t)
            self._failed -= n
        return n

    # ------------------------------------------------------------ accounting

    def _advance(self, t: float) -> None:
        if t < self._t_last - 1e-9:
            raise LedgerError(f"time went backwards: {t} < {self._t_last}")
        dt = max(0.0, t - self._t_last)
        if dt > 0:
            self._node_seconds += dt * self.total_allocated
            for name, slot in self._res.items():
                self._per_re_node_seconds[name] += dt * slot.allocated
            self._t_last = t

    def finalize(self, t_end: float) -> None:
        self._advance(t_end)

    @property
    def node_hours(self) -> float:
        return self._node_seconds / 3600.0

    def node_hours_of(self, re_name: str) -> float:
        return self._per_re_node_seconds[re_name] / 3600.0

    @property
    def peak(self) -> int:
        return self._peak


def ceil_to_lease(t: float, lease_seconds: float) -> float:
    """Next lease-tick boundary at or after ``t`` (EC2 billing rule §6.6.2)."""
    k = math.ceil((t - 1e-9) / lease_seconds)
    return max(k, 0) * lease_seconds
