"""Resource Provision Service — coordinated provisioning policies (§5).

Two services implement the paper's two coordination models:

  * ``FBProvisionService``  (§5.1) — private cloud, fixed capacity C.
    WS demand has strict priority: it is satisfied from the idle pool,
    then from the PBJ TRE's idle nodes, then by force-killing PBJ jobs.
    On every lease tick all idle nodes are provisioned to the PBJ TRE.

  * ``FLBNUBProvisionService`` (§5.2) — public cloud, unbounded capacity.
    The coordinated pool holds B = lb_pbj + lb_ws nodes permanently (the
    rigid lower bounds — they are paid for whether idle or not, which is
    exactly why Fig. 14 shows total consumption growing with B). WS demand
    is always satisfied (within-pool share first, elastic beyond). On each
    lease tick idle pool nodes go to the PBJ TRE, then the PBJ manager
    runs its U/V/G adjustment; requests are granted from the cloud.

Both count every request/release/provision as an adjust event — the
management-overhead metric of Fig. 18.
"""

from __future__ import annotations

from typing import List

from repro.core.cluster import Cluster
from repro.core.pbj_manager import PBJManager, Started
from repro.core.system import ProvisioningSystem
from repro.core.ws_manager import WSManager

POOL = "POOL"   # ledger name for the permanently-held coordinated pool


class FBProvisionService(ProvisioningSystem):
    """Fixed Bound model (§5.1): capacity C, WS-priority with kills."""

    def __init__(self, capacity: int, pbj: PBJManager, ws: WSManager,
                 lease_seconds: float = 3600.0):
        self.cluster = Cluster(capacity)
        self.cluster.register(pbj.name)
        self.cluster.register(ws.name)
        self.pbj = pbj
        self.ws = ws
        self.lease_seconds = lease_seconds
        self.shed_count = 0
        # Raw (unclamped) WS demand, remembered so a REPAIR event can
        # refill the WS TRE to min(demand, surviving capacity).
        self._ws_demand_raw = 0

    def startup(self, t: float, ws_initial: int = 0) -> List[Started]:
        """Allocate lower bounds at TRE startup (§5.1 rule 2: the
        coordinated pool is the sum of the lower bounds == C; everything
        not needed by WS goes to PBJ)."""
        self._ws_demand_raw = ws_initial
        ws_initial = min(ws_initial, self.cluster.capacity)
        if ws_initial:
            self.cluster.allocate(t, self.ws.name, ws_initial)
            self.ws.set_demand(ws_initial)
        grant = self.cluster.idle
        self.cluster.allocate(t, self.pbj.name, grant)
        return self.pbj.grant(t, grant)

    # -------------------------------------------------------------- events

    def on_ws_demand(self, t: float, demand: int) -> List[Started]:
        """§5.1 rule 3 — WS demand beats PBJ, killing jobs if necessary.
        Under degraded capacity (failed nodes) demand beyond the
        surviving count is shed — counted, not granted — until repairs
        land (graceful degradation)."""
        self._ws_demand_raw = demand
        granted = min(demand, self.cluster.effective_capacity)
        if demand > granted:
            self.shed_count += demand - granted
        demand = granted
        self.ws.set_demand(demand)
        cur = self.cluster.allocated(self.ws.name)
        if demand > cur:
            need = demand - cur
            take_idle = min(need, self.cluster.idle)
            if take_idle:
                self.cluster.allocate(t, self.ws.name, take_idle)
                need -= take_idle
            restarts: List[Started] = []
            if need > 0:
                released, restarts = self.pbj.force_release(t, need)
                assert released == need, (released, need)
                self.cluster.transfer(t, self.pbj.name, self.ws.name, need)
            return restarts
        elif demand < cur:
            # Shrink: nodes return to the idle pool until the next tick.
            self.cluster.release(t, self.ws.name, cur - demand)
        return []

    def on_lease_tick(self, t: float) -> List[Started]:
        """§5.1 rule 4 — provision all idle resources to the PBJ TRE."""
        idle = self.cluster.idle
        if idle > 0:
            self.cluster.allocate(t, self.pbj.name, idle)
            return self.pbj.grant(t, idle)
        return []

    # --------------------------------------------------------- fault hooks

    def on_fail(self, t: float, k: int) -> List[Started]:
        """Chaos tier: ``k`` nodes die. Absorption order — idle pool
        first, then PBJ jobs (killed through the existing §5.1 path:
        checkpoint hook, requeue, restart from checkpointed progress),
        then WS replicas (shed — demand exceeds surviving capacity until
        a repair). WS keeps its §5.1 priority throughout: after the
        handler, ``ws_alloc == min(demand, C - failed)``, which is
        exactly the time-varying share line the rounds engine folds into
        its WS tables."""
        k = self.cluster.fail_nodes(t, k)
        if k == 0:
            return []
        overflow = (self.cluster.total_allocated
                    - self.cluster.effective_capacity)
        restarts: List[Started] = []
        if overflow > 0:
            give = min(overflow, self.cluster.allocated(self.pbj.name))
            if give:
                released, restarts = self.pbj.force_release(t, give)
                assert released == give, (released, give)
                self.cluster.release(t, self.pbj.name, give)
                overflow -= give
            if overflow > 0:
                # The failure reached WS replicas: drain and shed.
                self.cluster.release(t, self.ws.name, overflow)
                self.ws.set_demand(self.cluster.allocated(self.ws.name))
                self.shed_count += overflow
        return restarts

    def on_repair(self, t: float, k: int) -> List[Started]:
        """Chaos tier: ``k`` nodes return. The WS shortfall refills
        immediately (§5.1 priority); remaining recovered nodes sit idle
        until the next lease tick provisions them to PBJ (rule 4)."""
        k = self.cluster.repair_nodes(t, k)
        if k == 0:
            return []
        cur = self.cluster.allocated(self.ws.name)
        target = min(self._ws_demand_raw, self.cluster.effective_capacity)
        grow = min(target - cur, self.cluster.idle)
        if grow > 0:
            self.cluster.allocate(t, self.ws.name, grow)
            self.ws.set_demand(cur + grow)
        return []


class FLBNUBProvisionService(ProvisioningSystem):
    """Fixed Lower Bound / No Upper Bound model (§5.2)."""

    def __init__(self, lb_pbj: int, lb_ws: int, pbj: PBJManager,
                 ws: WSManager, lease_seconds: float = 3600.0):
        # Unbounded site (§5.2 presumes the provider owns enough resources).
        self.cluster = Cluster(capacity=None)
        self.cluster.register(POOL)      # the B permanently-held nodes
        self.cluster.register(pbj.name)  # leased beyond the pool
        self.cluster.register(ws.name)   # WS demand beyond its lower bound
        self.pbj = pbj
        self.ws = ws
        self.lb_pbj = lb_pbj
        self.lb_ws = lb_ws
        self.lease_seconds = lease_seconds
        # Pool split bookkeeping (who is using the B nodes right now).
        self._pool_pbj = 0     # pool nodes provisioned to PBJ
        self._pool_ws = 0      # pool nodes serving WS demand (<= lb_ws)
        self._pool_failed = 0  # pool nodes currently down (chaos tier)

    @property
    def coordinated_size(self) -> int:
        return self.lb_pbj + self.lb_ws

    @property
    def _pool_idle(self) -> int:
        return (self.coordinated_size - self._pool_failed
                - self._pool_pbj - self._pool_ws)

    def startup(self, t: float, ws_initial: int = 0) -> List[Started]:
        """§5.2 rule 2: allocate lower bounds at startup. The whole pool B
        is held (and paid for) from t0."""
        self.cluster.allocate(t, POOL, self.coordinated_size)
        started = self.pbj.grant(t, self.lb_pbj)
        self._pool_pbj = self.lb_pbj
        if ws_initial:
            self.on_ws_demand(t, ws_initial)
        return started

    # -------------------------------------------------------------- events

    def on_ws_demand(self, t: float, demand: int) -> List[Started]:
        """§5.2 rule 4: WS demand is always satisfied — within-pool share
        first (up to lb_ws), elastically leased beyond."""
        self.ws.set_demand(demand)
        pool_share = min(demand, self.lb_ws, self._pool_ws + self._pool_idle)
        self._pool_ws = pool_share
        beyond = max(0, demand - pool_share)
        cur_beyond = self.cluster.allocated(self.ws.name)
        if beyond > cur_beyond:
            self.cluster.allocate(t, self.ws.name, beyond - cur_beyond)
        elif beyond < cur_beyond:
            self.cluster.release(t, self.ws.name, cur_beyond - beyond)
        return []

    def on_lease_tick(self, t: float) -> List[Started]:
        """§5.2 rule 3 (idle pool → PBJ), then the PBJ U/V/G adjustment."""
        started: List[Started] = []
        idle = self._pool_idle
        if idle > 0:
            self._pool_pbj += idle
            started += self.pbj.grant(t, idle)
        action, n = self.pbj.adjust(t)
        if action == "request":
            # Granted immediately from the unbounded cloud (leased nodes).
            self.cluster.allocate(t, self.pbj.name, n)
            started += self.pbj.grant(t, n)
        elif action == "release":
            # Release leased nodes first (they cost money); pool nodes
            # simply return to the pool and flow back next tick.
            leased = self.cluster.allocated(self.pbj.name)
            from_lease = min(n, leased)
            from_pool = n - from_lease
            self.pbj.confirm_release(n)
            if from_lease:
                self.cluster.release(t, self.pbj.name, from_lease)
            if from_pool:
                self._pool_pbj -= from_pool
                assert self._pool_pbj >= 0
        return started

    # --------------------------------------------------------- fault hooks

    def on_fail(self, t: float, k: int) -> List[Started]:
        """Chaos tier: ``k`` pool nodes die (faults target the
        permanently-held B nodes; elastic leases model the provider's
        replaceable inventory, §5.2's N >> 2 assumption). Absorption
        order: pool idle, then pool PBJ nodes (§5.1 kill path — U/V/G
        re-leases at the next tick), then the WS pool share — which is
        re-satisfied immediately with an elastic lease, so WS never
        sheds under FLB-NUB."""
        k = min(k, self.coordinated_size - self._pool_failed)
        if k <= 0:
            return []
        self._pool_failed += k
        # Down pool nodes stop accruing node-hours until repaired.
        self.cluster.release(t, POOL, k)
        overflow = (self._pool_pbj + self._pool_ws
                    - (self.coordinated_size - self._pool_failed))
        restarts: List[Started] = []
        if overflow > 0:
            give = min(overflow, self._pool_pbj)
            if give:
                released, restarts = self.pbj.force_release(t, give)
                assert released == give, (released, give)
                self._pool_pbj -= give
                overflow -= give
            if overflow > 0:
                self._pool_ws -= overflow
                self.cluster.allocate(t, self.ws.name, overflow)
        return restarts

    def on_repair(self, t: float, k: int) -> List[Started]:
        """Chaos tier: ``k`` pool nodes return and are held (paid for)
        again. The WS share moves back onto recovered pool nodes first
        (pool-first rule 4), releasing the elastic leases that replaced
        them; PBJ re-grows at the next tick (idle pool → PBJ, U/V/G)."""
        k = min(k, self._pool_failed)
        if k <= 0:
            return []
        self._pool_failed -= k
        self.cluster.allocate(t, POOL, k)
        pool_share = min(self.ws.demand, self.lb_ws,
                         self._pool_ws + self._pool_idle)
        delta = pool_share - self._pool_ws
        if delta > 0:
            self._pool_ws = pool_share
            self.cluster.release(t, self.ws.name, delta)
        return []
