"""The paper's two comparison systems (§6.5.1, §6.6.1).

* ``DCSSystem`` — dedicated cluster system: static partition, PRC_PBJ
  nodes for the batch TRE and PRC_WS for the web TRE, no coordination.

* ``EC2RightScaleSystem`` — public-cloud baseline: WS is autoscaled
  exactly like PhoenixCloud (RightScale provides the same scalable
  management, §6.6.1), while each batch job's end user leases its nodes
  individually at submission, runs immediately (no queue, no scheduler),
  and releases only at the next lease-unit boundary after completion
  (§6.6.2 — EC2 bills whole hours and users can't predict completions).

Both are concrete ``ProvisioningSystem``s (core/system.py), so the event
engine drives them through the same lifecycle protocol as the two
PhoenixCloud services.
"""

from __future__ import annotations

from typing import List

from repro.core.cluster import Cluster, ceil_to_lease
from repro.core.jobs import Job
from repro.core.pbj_manager import PBJManager, Started
from repro.core.system import ProvisioningSystem
from repro.core.ws_manager import WSManager


class DCSSystem(ProvisioningSystem):
    """Static partition baseline (§6.5.1)."""

    def __init__(self, prc_pbj: int, prc_ws: int, pbj: PBJManager,
                 ws: WSManager, lease_seconds: float = 3600.0):
        self.cluster = Cluster(prc_pbj + prc_ws)
        self.cluster.register(pbj.name)
        self.cluster.register(ws.name)
        self.pbj = pbj
        self.ws = ws
        self.prc_pbj = prc_pbj
        self.prc_ws = prc_ws
        self.lease_seconds = lease_seconds

    def startup(self, t: float, ws_initial: int = 0) -> List[Started]:
        del ws_initial  # static: WS owns its full partition regardless
        self.cluster.allocate(t, self.ws.name, self.prc_ws)
        self.cluster.allocate(t, self.pbj.name, self.prc_pbj)
        return self.pbj.grant(t, self.prc_pbj)

    def on_ws_demand(self, t: float, demand: int) -> List[Started]:
        # Static allocation: demand changes never move resources.
        self.ws.set_demand(demand)
        return []

    def on_lease_tick(self, t: float) -> List[Started]:
        return []


class EC2RightScaleSystem(ProvisioningSystem):
    """EC2 + RightScale baseline (§6.6.1)."""

    def __init__(self, pbj: PBJManager, ws: WSManager,
                 lease_seconds: float = 3600.0):
        self.cluster = Cluster(capacity=None)
        self.cluster.register(pbj.name)
        self.cluster.register(ws.name)
        self.pbj = pbj            # used only for completion bookkeeping
        self.ws = ws
        self.lease_seconds = lease_seconds
        self._pending_release: List[tuple] = []   # (release_time, size)

    def startup(self, t: float, ws_initial: int = 0) -> List[Started]:
        if ws_initial:
            self.on_ws_demand(t, ws_initial)
        return []

    def on_ws_demand(self, t: float, demand: int) -> List[Started]:
        """RightScale autoscaling == replaying the same consumption trace."""
        self.ws.set_demand(demand)
        cur = self.cluster.allocated(self.ws.name)
        if demand > cur:
            self.cluster.allocate(t, self.ws.name, demand - cur)
        elif demand < cur:
            self.cluster.release(t, self.ws.name, cur - demand)
        return []

    def submit(self, t: float, job: Job) -> List[Started]:
        """End user leases nodes and the job starts immediately."""
        self.cluster.allocate(t, self.pbj.name, job.size)
        return [self.pbj.start_immediately(t, job)]

    def on_finish(self, t: float, jid: int, epoch: int) -> List[Started]:
        job, starts = self.pbj.on_finish(t, jid, epoch)
        if job is not None:
            # §6.6.2: resources released at the end of the lease unit.
            release_at = ceil_to_lease(t, self.lease_seconds)
            self._pending_release.append((release_at, job.size))
        return starts

    def on_lease_tick(self, t: float) -> List[Started]:
        due = [(rt, n) for rt, n in self._pending_release if rt <= t + 1e-6]
        self._pending_release = [(rt, n) for rt, n in self._pending_release
                                 if rt > t + 1e-6]
        for _, n in due:
            self.cluster.release(t, self.pbj.name, n)
            self.pbj.owned -= n
        return []


def billable_requests(row) -> int:
    """Provisioning-API request count a sweep row implies — the unit the
    capacity layer's cost lens (``repro.sim.capacity.CostModel``) prices
    at a provider's per-request rate.

    Every ``adjust_events`` entry is one allocate/release transition of
    the site ledger: under §6.6.2's whole-lease-unit billing each such
    transition is one management-API round-trip on a public cloud
    (RunInstances/TerminateInstances-shaped), so the ledger count IS the
    billable request count. Accepts a sweep row dict or any object with
    an ``adjust_events`` attribute (e.g. ``SimResult``); rows without
    the metric (vectorized DCS carries cost/peak only — a static
    partition makes zero requests) price as zero.
    """
    if isinstance(row, dict):
        n = row.get("adjust_events", 0)
    else:
        n = getattr(row, "adjust_events", 0)
    return int(n or 0)
