"""Parallel-batch-job (== training-job) primitives.

A job in the paper is a rigid parallel application: it demands ``size``
nodes for ``runtime`` seconds. In the TPU adaptation a job additionally
names the architecture config it trains (``arch``) so the runtime bridge
can launch a real ``train_step`` payload; the provisioning logic only ever
looks at ``size``/``runtime``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class Job:
    jid: int
    submit: float          # submission time (s)
    size: int              # rigid node/chip demand
    runtime: float         # execution seconds needed (fresh run)
    arch: Optional[str] = None   # payload architecture (TPU adaptation)
    min_size: Optional[int] = None  # elastic floor (beyond-paper; None = rigid)

    # Mutable bookkeeping.
    start: float = -1.0
    end: float = -1.0
    kills: int = 0
    completed: bool = False
    # Beyond-paper checkpoint-preempt: completed work carried across kills.
    progress: float = 0.0

    def remaining(self, checkpoint_preempt: bool) -> float:
        if checkpoint_preempt:
            return max(0.0, self.runtime - self.progress)
        return self.runtime

    @property
    def turnaround(self) -> float:
        assert self.completed
        return self.end - self.submit

    @property
    def execution(self) -> float:
        assert self.completed
        return self.end - self.start


class JobQueue:
    """FCFS-ordered queue with the paper's first-fit scan (§6.5.2)."""

    def __init__(self) -> None:
        self._q: List[Job] = []

    def __len__(self) -> int:
        return len(self._q)

    def __iter__(self):
        return iter(self._q)

    def push(self, job: Job) -> None:
        """Insert keeping arrival order (killed jobs keep their position)."""
        # Jobs arrive mostly in order; killed jobs re-enter near the front.
        i = len(self._q)
        while i > 0 and self._q[i - 1].submit > job.submit:
            i -= 1
        self._q.insert(i, job)

    def accumulated_demand(self) -> int:
        """Sum of node demands of all queued jobs (the §5.2 numerator)."""
        return sum(j.size for j in self._q)

    def biggest(self) -> Optional[Job]:
        if not self._q:
            return None
        return max(self._q, key=lambda j: j.size)

    def first_fit(self, free: int) -> List[Job]:
        """Pop every job that fits, scanning in arrival order (§6.5.2).

        "Scans all the queued jobs in the order of job arrival and chooses
        the first job whose resources requirement can be met" — applied
        repeatedly until nothing fits.
        """
        started: List[Job] = []
        kept: List[Job] = []
        for job in self._q:
            if job.size <= free:
                free -= job.size
                started.append(job)
            else:
                kept.append(job)
        self._q = kept
        return started


class RunningSet:
    """Running jobs with completion times and the §5.1 kill ordering."""

    def __init__(self) -> None:
        self._running: Dict[int, Tuple[Job, float]] = {}
        self._epoch = itertools.count()   # disambiguates stale finish events

    def __len__(self) -> int:
        return len(self._running)

    def __contains__(self, jid: int) -> bool:
        return jid in self._running

    def jobs(self) -> List[Job]:
        return [j for j, _ in self._running.values()]

    def used(self) -> int:
        return sum(j.size for j, _ in self._running.values())

    def add(self, job: Job, end_time: float) -> int:
        epoch = next(self._epoch)
        self._running[job.jid] = (job, end_time)
        return epoch

    def pop(self, jid: int) -> Tuple[Job, float]:
        return self._running.pop(jid)

    def kill_order(self) -> List[Job]:
        """§5.1 rule 2: smallest size first; ties → latest start first."""
        return sorted(self.jobs(), key=lambda j: (j.size, -j.start))
