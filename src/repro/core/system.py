"""The shared provisioning-system abstraction.

Every system in the paper's comparison matrix (§6) — DCS, PhoenixCloud
FB, PhoenixCloud FLB-NUB, EC2+RightScale — is one concrete
``ProvisioningSystem``: a cloud-site ledger (``cluster``), one PBJ TRE
manager, one WS TRE manager, and a lease time unit, driven through five
lifecycle events:

    startup(t, ws_initial)      initial allocation of the site
    submit(t, job)              a batch job arrives
    on_finish(t, jid, epoch)    a previously-started job completes
    on_ws_demand(t, demand)     the web-service consumption changes
    on_lease_tick(t)            a lease time-unit boundary (§4: resource
                                provisioning happens in lease units)
    on_fail(t, k)               k nodes fail (chaos tier, repro.sim.faults)
    on_repair(t, k)             k previously-failed nodes return

Every handler returns the jobs it *started* as ``Started`` events — the
single return channel through which new completion events enter the
event engine (``repro.sim.engine``). The engine is therefore completely
policy-free: it never reaches into managers, and new provisioning
policies plug in by subclassing (the pluggability argument of the
RightScale-replay baselines, arXiv 1003.0958, and the provisioning
taxonomy of arXiv 1411.5077).
"""

from __future__ import annotations

import abc
from typing import List

from repro.core.cluster import Cluster
from repro.core.jobs import Job
from repro.core.pbj_manager import PBJManager, Started
from repro.core.ws_manager import WSManager

__all__ = ["ProvisioningSystem"]


class ProvisioningSystem(abc.ABC):
    """Base class of the four paper systems (and any new policy).

    Concrete subclasses must set four attributes in ``__init__``:

      * ``cluster`` — the :class:`~repro.core.cluster.Cluster` ledger,
      * ``pbj``     — the batch-queue TRE manager,
      * ``ws``      — the web-service TRE manager,
      * ``lease_seconds`` — the lease time unit L driving tick events,

    and implement the three policy hooks (``startup``, ``on_ws_demand``,
    ``on_lease_tick``). ``submit``/``on_finish`` default to delegating
    to the PBJ manager's queue + first-fit scheduler; systems where jobs
    bypass the queue (EC2's per-user leasing) override them.
    """

    cluster: Cluster
    pbj: PBJManager
    ws: WSManager
    lease_seconds: float

    # WS demand units dropped because demand exceeded surviving capacity
    # (graceful degradation under faults). The pump samples the delta
    # around every handler into the ledger's ``shed`` column.
    shed_count: int = 0

    # ------------------------------------------------------ policy hooks

    @abc.abstractmethod
    def startup(self, t: float, ws_initial: int = 0) -> List[Started]:
        """Perform the system's initial allocation (§5 rule 1/2)."""

    @abc.abstractmethod
    def on_ws_demand(self, t: float, demand: int) -> List[Started]:
        """React to a change of the WS TRE's resource consumption."""

    @abc.abstractmethod
    def on_lease_tick(self, t: float) -> List[Started]:
        """React to a lease time-unit boundary."""

    # ------------------------------------------------------- fault hooks

    def on_fail(self, t: float, k: int) -> List[Started]:
        """``k`` nodes fail at ``t``. Non-abstract on purpose: faults
        are only ever injected explicitly (``EventPump.add_faults``), so
        systems without a failure model (DCS, EC2 baselines) stay valid
        as long as no schedule targets them."""
        raise NotImplementedError(
            f"{type(self).__name__} has no failure model; only inject "
            f"fault schedules into systems implementing on_fail/on_repair")

    def on_repair(self, t: float, k: int) -> List[Started]:
        """``k`` previously-failed nodes return to service at ``t``."""
        raise NotImplementedError(
            f"{type(self).__name__} has no failure model; only inject "
            f"fault schedules into systems implementing on_fail/on_repair")

    # ----------------------------------------------- default job routing

    def submit(self, t: float, job: Job) -> List[Started]:
        """A batch job arrives: queue it and run the first-fit scan."""
        return self.pbj.submit(t, job)

    def on_finish(self, t: float, jid: int, epoch: int) -> List[Started]:
        """A job completes; stale events (killed epochs) are no-ops."""
        _, starts = self.pbj.on_finish(t, jid, epoch)
        return starts
