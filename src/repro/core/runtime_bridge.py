"""Runtime bridge — PhoenixCloud TREs driving *real* JAX payloads.

The simulator (``repro.sim``) exercises the provisioning policies against
traces; this bridge exercises them against actual work: PBJ jobs run
``TrainJob`` steps, WS replicas run the serving engine, and the
ResourceProvisionService moves *logical chip leases* between them. On the
CPU container every logical chip maps to the same physical device — the
provisioning layer is deliberately agnostic to that mapping (it tracks
leases, not devices), exactly as the paper's provision service tracks
nodes, not their MAC addresses.

Since the event-core unification, the bridge runs on the SAME
:class:`~repro.sim.pump.EventPump` as the reference simulator: one heap,
one clock, one ``ProvisioningSystem`` lifecycle. ``set_ws_demand`` /
``lease_tick`` are ordinary pump events; ``run_quantum`` is a CALL
handler that pushes FINISH events for payloads that completed; and
checkpoint-preempt is first-class — a ``PBJManager.preempt_hooks`` entry
checkpoints the real payload at the manager's single kill site, whatever
provisioning path caused the kill. Every decision lands in the same
:class:`~repro.sim.pump.DecisionLedger` format the simulator writes, so
live and simulated runs of one trace diff directly
(``CONTRACTS["live"]``, ``tests/test_live_vs_sim.py``).

This is what ``examples/consolidation_live.py`` runs end-to-end: a live
FB-policy cloud where a serving spike force-preempts (checkpoint, not
kill — the beyond-paper mode) a training job and the job later resumes
from its checkpoint on the recovered chips.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.jobs import Job
from repro.core.lifecycle import LifecycleManagementService
from repro.core.pbj_manager import PBJManager, PBJPolicyParams
from repro.core.provision import FBProvisionService
from repro.core.spec import (CoordinationModel, Granularity,
                             Relationship, ResourceBounds,
                             RuntimeEnvironmentSpec, SetupPolicy,
                             WorkloadType)
from repro.core.ws_manager import WSManager
from repro.sim.pump import (CALL, FINISH, SUBMIT, TICK, WS,
                            DecisionLedger, EventPump)


@dataclasses.dataclass
class LiveJob:
    """A PBJ queue entry bound to a real TrainJob payload."""

    job: Job
    payload: "TrainJob"
    steps_per_grant: int = 10


class LiveCloud:
    """A miniature live PhoenixCloud site under the FB policy.

    Chips are logical lease tokens (capacity C); the PBJ TRE runs real
    training steps whenever it holds >= job.size chips; the WS TRE's
    demand is driven by the serving autoscaler (or a replayed trace).
    Preemption uses checkpoint-preempt: the payload checkpoints and the
    queue entry keeps its progress.

    Jobs come in two tiers sharing the one pump:

      * **live** jobs (``submit_training``) carry a real ``TrainJob``;
        their ``runtime`` is in *steps* and completion is detected by
        payload progress inside ``run_quantum``, so the pump's
        auto-FINISH scheduling is gated off for them;
      * **virtual** jobs (``submit_job`` / ``load_trace``) are plain
        trace entries in seconds; the pump schedules their FINISH from
        ``Started.end_time`` exactly as the simulator does — the replay
        tier (``repro.serving.replay``) that runs days of trace in
        seconds.
    """

    def __init__(self, capacity: int, mesh=None, *,
                 lease_seconds: float = 60.0,
                 checkpoint_root: str = "/tmp/phoenixcloud_ckpt",
                 duration: float = math.inf, ws_initial: int = 0,
                 ws: Optional[WSManager] = None,
                 ledger: Optional[DecisionLedger] = None):
        self.mesh = mesh
        self.lifecycle = LifecycleManagementService()
        params = PBJPolicyParams(checkpoint_preempt=True)
        self.pbj = PBJManager(params=params)
        self.pbj.preempt_hooks.append(self._checkpoint_victim)
        self.ws = ws if ws is not None else WSManager()
        self.service = FBProvisionService(capacity, self.pbj, self.ws,
                                          lease_seconds)
        self.checkpoint_root = checkpoint_root
        self._live: Dict[int, LiveJob] = {}
        self._register_tres(capacity)
        self.ledger = ledger if ledger is not None else DecisionLedger()
        self.pump = EventPump(
            self.service, duration, ledger=self.ledger,
            # Live payloads finish by real progress, not simulated time.
            finish_gate=lambda s: s.job.jid not in self._live)
        self.pump.startup(ws_initial=ws_initial)

    @property
    def t(self) -> float:
        """The shared clock — the pump's, not a bridge-private one."""
        return self.pump.now

    def _register_tres(self, capacity: int) -> None:
        pbj_spec = RuntimeEnvironmentSpec(
            name="pbj_tre", relationship=Relationship.AFFILIATED,
            workload=WorkloadType.PARALLEL_BATCH_JOBS,
            granularity=Granularity.CHIP_SLICE,
            coordination=CoordinationModel.FB,
            bounds=ResourceBounds(capacity, capacity),
            setup_policy=SetupPolicy.RELOAD)
        ws_spec = dataclasses.replace(
            pbj_spec, name="ws_tre", workload=WorkloadType.WEB_SERVICE)
        self.lifecycle.create(pbj_spec)
        self.lifecycle.create(ws_spec)
        self.lifecycle.activate("pbj_tre", self.pbj)
        self.lifecycle.activate("ws_tre", self.ws)
        assert self.lifecycle.tre("pbj_tre").partner == "ws_tre"

    # --------------------------------------------------------------- API

    def submit_training(self, jid: int, arch: str, chips: int,
                        steps: int = 30, batch: int = 4,
                        seq_len: int = 64) -> None:
        """Submit a live training job with a real TrainJob payload."""
        from repro.configs.base import get_config, reduced_config
        from repro.train.trainer import TrainJob, TrainJobConfig
        rcfg = reduced_config(get_config(arch))
        payload = TrainJob(rcfg, TrainJobConfig(
            arch=arch, steps=steps, batch=batch, seq_len=seq_len,
            checkpoint_dir=f"{self.checkpoint_root}/job{jid}",
            checkpoint_every=10), self.mesh)
        job = Job(jid=jid, submit=self.t, size=chips,
                  runtime=float(steps))   # runtime in steps (bridge units)
        self._live[jid] = LiveJob(job, payload)
        self.submit_job(job)

    def submit_job(self, job: Job) -> None:
        """Submit a virtual (trace) job — or the Job half of a live one —
        through the pump at the current time."""
        self.pump.push(max(self.t, job.submit), SUBMIT, job)
        self.pump.run_until(self.t)

    def load_trace(self, jobs: Sequence[Job],
                   ws_trace: Sequence[Tuple[float, int]] = (),
                   lease_ticks: bool = False) -> None:
        """Pre-schedule a whole trace (the replay tier): virtual jobs,
        WS demand change points, and — when the demand stream is the
        trace itself rather than a live autoscaler — the lease ticks."""
        self.pump.add_jobs(jobs)
        for t, d in ws_trace:
            if t > 0:
                self.pump.push(t, WS, d)
        if lease_ticks:
            self.pump.add_lease_ticks(self.service.lease_seconds)

    def inject_faults(self, schedule) -> None:
        """Chaos tier: schedule a :class:`repro.sim.faults.FaultSchedule`
        on the shared pump. FAIL/REPAIR events dispatch through the FB
        service's ``on_fail``/``on_repair`` exactly as in the simulator
        — the same schedule replayed here and in ``run_sim`` produces
        the same decision ledger, which is what the chaos differential
        (``benchmarks.run faults``, ``tests/test_faults.py``) diffs.
        Live payloads killed by a failure checkpoint through the same
        ``preempt_hooks`` entry as any WS-spike preemption."""
        self.pump.add_faults(schedule)

    def set_ws_demand(self, demand: int) -> None:
        self.pump.push(self.t, WS, demand)
        self.pump.run_until(self.t)

    def lease_tick(self) -> None:
        t1 = self.t + self.service.lease_seconds
        self.pump.push(t1, TICK, None)
        self.pump.run_until(t1)

    def run_until(self, t_stop: float) -> None:
        """Advance the shared clock, dispatching everything scheduled."""
        self.pump.run_until(t_stop)

    def run_quantum(self, steps: int = 10) -> List[int]:
        """Run every currently-scheduled live job for ``steps`` train
        steps (the bridge's time quantum); returns finished jids. A CALL
        event on the pump: completions it detects become FINISH events
        dispatched — and ledgered — like any simulated completion."""
        finished: List[int] = []
        self.pump.push(self.t, CALL,
                       lambda t: self._quantum(t, steps, finished))
        self.pump.run_until(self.t)
        return finished

    def _quantum(self, t: float, steps: int, finished: List[int]):
        for jid in list(self._live):
            lj = self._live[jid]
            if lj.job.jid not in self.pbj.running:
                continue   # queued or preempted
            payload = lj.payload
            target = min(payload.jc.steps, payload.step + steps)
            saved = payload.jc.steps
            payload.jc.steps = target
            payload.run()
            payload.jc.steps = saved
            lj.job.progress = float(payload.step)
            if payload.step >= saved:
                epoch = self.pbj._epochs.get(jid, -1)
                # Ungate before pushing: on_finish must see a normal job.
                del self._live[jid]
                self.pump.push(t, FINISH, (jid, epoch))
                finished.append(jid)
        return []

    def preempt_for_ws(self, demand: int) -> List[int]:
        """A WS spike. Checkpointing happens in the preempt hook at the
        manager's kill site; this helper just reports who was preempted."""
        before = {j.jid for j in self.pbj.running.jobs()}
        self.set_ws_demand(demand)
        after = {j.jid for j in self.pbj.running.jobs()}
        return sorted(before - after)

    # ---------------------------------------------------------- internals

    def _checkpoint_victim(self, t: float, job: Job) -> None:
        """preempt_hooks entry: checkpoint the real payload of a killed
        live job and pin its progress to the payload's step count (the
        bridge's time unit — overriding the manager's wall-clock
        progress formula, which is correct only for virtual jobs)."""
        lj = self._live.get(job.jid)
        if lj is None:
            return
        lj.payload.checkpoint(block=True)
        job.progress = float(lj.payload.step)
