"""Runtime bridge — PhoenixCloud TREs driving *real* JAX payloads.

The simulator (``repro.sim``) exercises the provisioning policies against
traces; this bridge exercises them against actual work: PBJ jobs run
``TrainJob`` steps, WS replicas run the serving engine, and the
ResourceProvisionService moves *logical chip leases* between them. On the
CPU container every logical chip maps to the same physical device — the
provisioning layer is deliberately agnostic to that mapping (it tracks
leases, not devices), exactly as the paper's provision service tracks
nodes, not their MAC addresses.

This is what ``examples/consolidation_live.py`` runs end-to-end: a live
FB-policy cloud where a serving spike force-preempts (checkpoint, not
kill — the beyond-paper mode) a training job and the job later resumes
from its checkpoint on the recovered chips.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax.numpy as jnp

from repro.configs.base import get_config
from repro.core.jobs import Job
from repro.core.lifecycle import LifecycleManagementService, TREState
from repro.core.pbj_manager import PBJManager, PBJPolicyParams
from repro.core.provision import FBProvisionService
from repro.core.spec import (CoordinationModel, Granularity,
                             Relationship, ResourceBounds,
                             RuntimeEnvironmentSpec, SetupPolicy,
                             WorkloadType)
from repro.core.ws_manager import WSManager
from repro.train.trainer import TrainJob, TrainJobConfig


@dataclasses.dataclass
class LiveJob:
    """A PBJ queue entry bound to a real TrainJob payload."""

    job: Job
    payload: TrainJob
    steps_per_grant: int = 10


class LiveCloud:
    """A miniature live PhoenixCloud site under the FB policy.

    Chips are logical lease tokens (capacity C); the PBJ TRE runs real
    training steps whenever it holds >= job.size chips; the WS TRE's
    demand is driven by the serving autoscaler (or a replayed trace).
    Preemption uses checkpoint-preempt: the payload checkpoints and the
    queue entry keeps its progress.
    """

    def __init__(self, capacity: int, mesh, *, lease_seconds: float = 60.0,
                 checkpoint_root: str = "/tmp/phoenixcloud_ckpt"):
        self.mesh = mesh
        self.lifecycle = LifecycleManagementService()
        params = PBJPolicyParams(checkpoint_preempt=True)
        self.pbj = PBJManager(params=params)
        self.ws = WSManager()
        self.service = FBProvisionService(capacity, self.pbj, self.ws,
                                          lease_seconds)
        self.checkpoint_root = checkpoint_root
        self._live: Dict[int, LiveJob] = {}
        self._register_tres(capacity)
        self.t = 0.0
        self.service.startup(0.0, ws_initial=0)

    def _register_tres(self, capacity: int) -> None:
        pbj_spec = RuntimeEnvironmentSpec(
            name="pbj_tre", relationship=Relationship.AFFILIATED,
            workload=WorkloadType.PARALLEL_BATCH_JOBS,
            granularity=Granularity.CHIP_SLICE,
            coordination=CoordinationModel.FB,
            bounds=ResourceBounds(capacity, capacity),
            setup_policy=SetupPolicy.RELOAD)
        ws_spec = dataclasses.replace(
            pbj_spec, name="ws_tre", workload=WorkloadType.WEB_SERVICE)
        self.lifecycle.create(pbj_spec)
        self.lifecycle.create(ws_spec)
        self.lifecycle.activate("pbj_tre", self.pbj)
        self.lifecycle.activate("ws_tre", self.ws)
        assert self.lifecycle.tre("pbj_tre").partner == "ws_tre"

    # --------------------------------------------------------------- API

    def submit_training(self, jid: int, arch: str, chips: int,
                        steps: int = 30, batch: int = 4,
                        seq_len: int = 64) -> None:
        cfg = get_config(arch)
        from repro.configs.base import reduced_config
        rcfg = reduced_config(cfg)
        payload = TrainJob(rcfg, TrainJobConfig(
            arch=arch, steps=steps, batch=batch, seq_len=seq_len,
            checkpoint_dir=f"{self.checkpoint_root}/job{jid}",
            checkpoint_every=10), self.mesh)
        job = Job(jid=jid, submit=self.t, size=chips,
                  runtime=float(steps))   # runtime in steps (bridge units)
        self._live[jid] = LiveJob(job, payload)
        self.pbj.submit(self.t, job)

    def set_ws_demand(self, demand: int) -> None:
        self.service.on_ws_demand(self.t, demand)

    def lease_tick(self) -> None:
        self.t += self.service.lease_seconds
        self.service.on_lease_tick(self.t)

    def run_quantum(self, steps: int = 10) -> List[int]:
        """Run every currently-scheduled live job for ``steps`` train
        steps (the bridge's time quantum); returns finished jids."""
        finished = []
        for jid in list(self._live):
            lj = self._live[jid]
            if lj.job.jid not in self.pbj.running:
                continue   # queued or preempted
            payload = lj.payload
            target = min(payload.jc.steps, payload.step + steps)
            saved = payload.jc.steps
            payload.jc.steps = target
            payload.run()
            payload.jc.steps = saved
            lj.job.progress = float(payload.step)
            if payload.step >= saved:
                self.pbj.on_finish(self.t, jid,
                                   self.pbj._epochs.get(jid, -1))
                finished.append(jid)
                del self._live[jid]
        return finished

    def preempt_for_ws(self, demand: int) -> None:
        """A WS spike: checkpoint-preempt whatever must be killed."""
        victims_before = set(self.pbj.running.jobs() and
                             [j.jid for j in self.pbj.running.jobs()])
        self.set_ws_demand(demand)
        victims_after = {j.jid for j in self.pbj.running.jobs()}
        for jid in victims_before - victims_after:
            if jid in self._live:
                self._live[jid].payload.checkpoint(block=True)
