"""PBJ TRE Manager — the batch-queue (training-job) runtime environment.

Implements the paper's resource-management policies:

  * first-fit scheduling (§6.5.2) via ``JobQueue.first_fit``;
  * the FB kill path (§5.1 rule 2): release idle first, then kill running
    jobs smallest-size-first (latest start breaks ties) and requeue them;
  * the FLB-NUB elastic policy (§5.2): on each lease tick compute the
    *ratio of adjusting resources* = queued demand / owned nodes and apply
    the U (request, DR1/DR2) and V/G (release, RSS) rules.

Beyond-paper: ``checkpoint_preempt=True`` turns the kill into a
checkpoint-preempt — killed jobs keep their completed progress and only
need the remainder re-run (quantified in EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.jobs import Job, JobQueue, RunningSet


@dataclasses.dataclass(frozen=True)
class PBJPolicyParams:
    """§5.2 knobs. Baseline values from §6.6.3: U=1.2, V=0.2, G=0.5.

    A jax pytree (U/V/G are data leaves, the preemption mode is static
    metadata) so policy parameters flow directly into the jitted sweep
    paths — ``repro.sim.scan`` builds its vmapped U/V/G grids from these
    fields, and a batch of params can itself be ``tree_map``-ed or
    stacked for parameter studies. The registration lives in
    ``repro.sim.scan`` (the jax-side consumer): this module stays
    importable with numpy alone, like the rest of the event engine.
    """

    request_threshold: float = 1.2     # U — threshold ratio of requesting
    release_threshold: float = 0.2     # V — threshold ratio of releasing
    elastic_factor: float = 0.5        # G — fraction of idle released
    checkpoint_preempt: bool = False   # beyond-paper preemption mode


@dataclasses.dataclass(frozen=True)
class Started:
    job: Job
    end_time: float
    epoch: int


class PBJManager:
    """Manager + Scheduler of the parallel-batch-jobs TRE."""

    def __init__(self, name: str = "PBJ",
                 params: PBJPolicyParams = PBJPolicyParams()):
        self.name = name
        self.params = params
        self.owned = 0                  # nodes currently owned by this TRE
        self.queue = JobQueue()
        self.running = RunningSet()
        self._epochs: Dict[int, int] = {}
        self._next_epoch = 0
        self.completed: List[Job] = []
        self.kill_count = 0
        # Called at the single kill site as hook(t, job), after progress
        # bookkeeping and before the job re-enters the queue. The live
        # bridge registers the checkpoint-preempt of its real payloads
        # here — first-class for EVERY kill path (WS spikes, replayed
        # demand, force_release), not just an interactive helper.
        self.preempt_hooks: List[Callable[[float, Job], None]] = []

    # ------------------------------------------------------------- state

    @property
    def free(self) -> int:
        return self.owned - self.running.used()

    def _start(self, t: float, job: Job) -> Started:
        job.start = t
        end = t + job.remaining(self.params.checkpoint_preempt)
        self._next_epoch += 1
        self._epochs[job.jid] = self._next_epoch
        self.running.add(job, end)
        return Started(job, end, self._next_epoch)

    def schedule(self, t: float) -> List[Started]:
        """First-fit scan over the queue (§6.5.2)."""
        return [self._start(t, j) for j in self.queue.first_fit(self.free)]

    # ------------------------------------------------------------- events

    def submit(self, t: float, job: Job) -> List[Started]:
        self.queue.push(job)
        return self.schedule(t)

    def start_immediately(self, t: float, job: Job) -> Started:
        """Grant the job its own nodes and start it, bypassing the queue.

        The EC2 per-user leasing model (§6.6.1): each end user leases
        exactly ``job.size`` nodes at submission, so the manager's owned
        count grows by the job's size and the job runs at once. This is
        the public API for queue-less systems — completion bookkeeping
        (epochs, running set, ``on_finish``) stays consistent with the
        scheduled path.
        """
        self.owned += job.size
        return self._start(t, job)

    def on_finish(self, t: float, jid: int, epoch: int) -> Tuple[Optional[Job], List[Started]]:
        """Handle a completion event; stale events (killed job) are no-ops."""
        if jid not in self.running or self._epochs.get(jid) != epoch:
            return None, []
        job, _ = self.running.pop(jid)
        del self._epochs[jid]
        job.end = t
        job.completed = True
        job.progress = job.runtime
        self.completed.append(job)
        return job, self.schedule(t)

    def grant(self, t: float, n: int) -> List[Started]:
        """Receive provisioned resources (§5.1 rule 1 / §5.2 rule 5)."""
        assert n >= 0
        self.owned += n
        return self.schedule(t) if n > 0 else []

    # ------------------------------------------------- FB forced release

    def force_release(self, t: float, n: int) -> Tuple[int, List[Started]]:
        """FB §5.1 rule 2: give back exactly ``n`` nodes (idle, then kills).

        Returns (released, restarts): ``released == n`` whenever
        ``owned >= n``. Killed jobs are requeued and may immediately
        restart in leftover freed space.
        """
        n = min(n, self.owned)
        if n == 0:
            return 0, []
        need = n - self.free
        if need > 0:
            for victim in self.running.kill_order():
                if need <= 0:
                    break
                self._kill(t, victim)
                need -= victim.size
        assert self.free >= n, (self.free, n, self.owned)
        self.owned -= n
        # Leftover freed capacity (kill overshoot) may restart queued jobs.
        return n, self.schedule(t)

    def _kill(self, t: float, job: Job) -> None:
        self.running.pop(job.jid)
        del self._epochs[job.jid]
        job.kills += 1
        self.kill_count += 1
        if self.params.checkpoint_preempt:
            job.progress = min(job.runtime, job.progress + (t - job.start))
        for hook in self.preempt_hooks:
            hook(t, job)
        job.start = -1.0
        self.queue.push(job)   # re-enters at its arrival-order position

    # ------------------------------------------------- FLB-NUB lease tick

    def adjust(self, t: float) -> Tuple[str, int]:
        """§5.2 rules 2–4. Returns ('request'|'release'|'hold', n)."""
        demand = self.queue.accumulated_demand()
        if self.owned == 0:
            ratio = math.inf if demand > 0 else 0.0
        else:
            ratio = demand / self.owned
        p = self.params
        if ratio > p.request_threshold:
            dr1 = demand - self.owned            # §5.2 rule 2
            if dr1 > 0:
                return "request", dr1
        biggest = self.queue.biggest()
        if biggest is not None and biggest.size > self.owned:
            dr2 = biggest.size - self.free        # §5.2 rule 3
            if dr2 > 0:
                return "request", dr2
        if ratio < p.release_threshold and self.free > 0:
            rss = int(p.elastic_factor * self.free)   # §5.2 rule 4
            if rss > 0:
                return "release", rss
        return "hold", 0

    def confirm_release(self, n: int) -> None:
        assert 0 <= n <= self.free, (n, self.free)
        self.owned -= n
