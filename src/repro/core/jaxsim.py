"""JAX-native FLB-NUB tick simulator — the paper's policy as a
``lax.scan``, ``vmap``-able over policy parameters.

The event simulator (repro.sim) is the reproduction workhorse; this
module re-expresses the FLB-NUB dynamics (§5.2) as a pure, jittable
program over fixed-size arrays so that the paper's §6.6.4 parameter
study — B × U × V × G, 20+ configurations, each a full two-week trace —
runs as ONE batched XLA program instead of 20 sequential event-driven
simulations. This is the paper's contribution as a *composable JAX
module* (DESIGN.md §3).

The sweep engine generalizes this approach: ``repro.sim.scan`` extends
the tick-simulator idea with a sliding job window, the FB kill path and
a traced lease axis, and ``repro.sim.sweep`` exposes it as
``run_sweep(..., mode="scan")`` over full ``SweepPoint`` grids and
batched workload traces. This module remains the minimal, fixed-lease
B × U × V × G study (§6.6.4) in its simplest vmappable form.

Approximations vs the event simulator (both documented and measured in
tests): time is discretized to the lease tick L (job completions round up
to tick boundaries), and the WS demand is sampled per tick. Fidelity is
cross-validated in tests/test_jaxsim.py: completed-jobs within ~2 %,
node-hours within ~15 %, and all parameter-sweep TRENDS (J1/J2, Fig 18)
match the event simulator.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.jobs import Job
from repro.core.profiles import per_tick_profile


@dataclasses.dataclass(frozen=True)
class FLBNUBParams:
    """The §5.2 knobs, as a vmap-able pytree of scalars."""

    B: jnp.ndarray          # coordinated pool size (lower bounds sum)
    U: jnp.ndarray          # threshold ratio of requesting
    V: jnp.ndarray          # threshold ratio of releasing
    G: jnp.ndarray          # elastic factor


jax.tree_util.register_dataclass(
    FLBNUBParams, data_fields=["B", "U", "V", "G"], meta_fields=[])


SUBSTEPS = 12    # job dynamics advance at L/12 (300 s at L=1h); policy
#                  actions (provision / U-V-G adjust) fire on tick
#                  boundaries only, exactly like the event simulator.


def pack_trace(jobs: Sequence[Job], ws_trace: Sequence[Tuple[float, int]],
               duration: float, lease_seconds: float,
               substeps: int = SUBSTEPS, dtype=None):
    """Fixed-size arrays: job table + per-substep WS demand.

    ``dtype`` defaults to the active x64 setting — float64 inside the
    ``enable_x64`` scope the sweep engine (``repro.sim.sweep``) runs its
    exact paths under, float32 otherwise — so scan-vs-event comparisons
    are never limited by the packing precision.
    """
    dtype = compat.resolve_pack_dtype(dtype)
    dt = lease_seconds / substeps
    n_steps = int(np.ceil(duration / dt))
    submit = np.array([j.submit for j in jobs], dtype)
    size = np.array([j.size for j in jobs], dtype)
    runtime = np.array([j.runtime for j in jobs], dtype)
    ws = per_tick_profile(ws_trace, duration, dt)[:n_steps].astype(dtype)
    return (jnp.asarray(submit), jnp.asarray(size), jnp.asarray(runtime),
            jnp.asarray(ws), n_steps)


@functools.partial(jax.jit, static_argnames=("n_steps", "lease_seconds",
                                             "lb_ws", "substeps"))
def simulate(params: FLBNUBParams, submit, size, runtime, ws_demand,
             n_steps: int, lease_seconds: float, lb_ws: int = 12,
             substeps: int = SUBSTEPS) -> Dict:
    """One FLB-NUB run; vmap over ``params`` for parameter sweeps."""
    n_jobs = submit.shape[0]
    lb_pbj = jnp.maximum(params.B - lb_ws, 1.0)
    dt = lease_seconds / substeps

    def step(state, s_ws):
        s_idx, ws = s_ws
        t = (s_idx + 1.0) * dt
        is_tick = (s_idx.astype(jnp.int32) % substeps) == (substeps - 1)
        owned, pool_pbj, remaining, running, done, finish_t = state

        # 1. Advance running jobs one substep.
        remaining = jnp.where(running, remaining - dt, remaining)
        completing = running & (remaining <= 0)
        finish_t = jnp.where(completing, t, finish_t)
        done = done | completing
        running = running & ~completing

        queued = (submit <= t) & ~running & ~done
        demand = jnp.sum(jnp.where(queued, size, 0.0))
        used = jnp.sum(jnp.where(running, size, 0.0))

        # 2+3. On tick boundaries: pool flow + the §5.2 U/V/G adjust.
        pool_ws = jnp.minimum(ws, float(lb_ws))
        pool_idle = jnp.maximum(params.B - pool_ws - pool_pbj, 0.0)
        grant = jnp.where(is_tick, pool_idle, 0.0)
        owned = owned + grant
        pool_pbj = pool_pbj + grant
        ratio = jnp.where(owned > 0, demand / jnp.maximum(owned, 1.0),
                          jnp.where(demand > 0, jnp.inf, 0.0))
        biggest = jnp.max(jnp.where(queued, size, 0.0))
        free = owned - used
        dr1 = jnp.maximum(demand - owned, 0.0)
        dr2 = jnp.maximum(biggest - free, 0.0)
        req = jnp.where(is_tick & (ratio > params.U), dr1,
                        jnp.where(is_tick & (biggest > owned), dr2, 0.0))
        rss = jnp.where(is_tick & (ratio < params.V) & (req == 0.0),
                        jnp.floor(params.G * jnp.maximum(free, 0.0)), 0.0)
        owned = owned + req - rss
        pool_pbj = jnp.minimum(pool_pbj, owned)   # leased-first release

        # 4. First-fit in arrival order (sequential scan over the table);
        # runs every substep, like submit/finish events in the event sim.
        free = owned - used

        def ff(carry, inp):
            fr = carry
            is_q, sz = inp
            start = is_q & (sz <= fr)
            return fr - jnp.where(start, sz, 0.0), start

        _, starts = jax.lax.scan(ff, free, (queued, size))
        running = running | starts

        # 5. Accounting: consumption = B pool + leased + WS-beyond-lb.
        leased = jnp.maximum(owned - pool_pbj, 0.0)
        ws_beyond = jnp.maximum(ws - pool_ws, 0.0)
        alloc = params.B + leased + ws_beyond
        events = (req > 0).astype(jnp.float32) + (rss > 0).astype(jnp.float32)
        state = (owned, pool_pbj, remaining, running, done, finish_t)
        return state, (alloc, events)

    state0 = (lb_pbj, lb_pbj, runtime, jnp.zeros(n_jobs, bool),
              jnp.zeros(n_jobs, bool), jnp.zeros(n_jobs, submit.dtype))
    steps = (jnp.arange(n_steps, dtype=submit.dtype), ws_demand)
    state, (alloc, events) = jax.lax.scan(step, state0, steps)
    _, _, _, running, done, finish_t = state
    turnaround = jnp.where(done, finish_t - submit, 0.0)
    return {
        "completed_jobs": jnp.sum(done),
        "avg_turnaround": jnp.sum(turnaround) / jnp.maximum(
            jnp.sum(done), 1),
        "node_hours": jnp.sum(alloc) * dt / 3600.0,
        "peak_nodes": jnp.max(alloc),
        "adjust_events": jnp.sum(events),
    }


def sweep(param_grid: List[Dict[str, float]], jobs, ws_trace, duration,
          lease_seconds: float = 3600.0, lb_ws: int = 12,
          substeps: int = SUBSTEPS) -> List[Dict]:
    """The §6.6.4 study as one vmapped program."""
    packed = pack_trace(jobs, ws_trace, duration, lease_seconds, substeps)
    submit, size, runtime, ws, n_steps = packed
    params = FLBNUBParams(
        B=jnp.array([p["B"] for p in param_grid], jnp.float32),
        U=jnp.array([p["U"] for p in param_grid], jnp.float32),
        V=jnp.array([p["V"] for p in param_grid], jnp.float32),
        G=jnp.array([p["G"] for p in param_grid], jnp.float32))
    fn = jax.vmap(lambda pr: simulate(pr, submit, size, runtime, ws,
                                      n_steps=n_steps,
                                      lease_seconds=lease_seconds,
                                      lb_ws=lb_ws, substeps=substeps))
    out = fn(params)
    return [{**param_grid[i],
             **{k: float(v[i]) for k, v in out.items()}}
            for i in range(len(param_grid))]
