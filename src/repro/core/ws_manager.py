"""WS TRE Manager — the web-service (inference-serving) runtime environment.

Two operating modes mirror the paper's two experiment styles:

  * **Demand replay** (§6.5.1 "the resource simulator simulates the varying
    resources consumption and drives WS Manager"): the manager replays a
    resource-consumption trace (e.g. the World Cup trace of Fig. 10) and
    requests/releases nodes from the provision service to match.

  * **Instance adjustment** (§6.4): the live policy used by the real
    serving engine — if average utilization of the current ``n`` instances
    exceeds 80% over the sampling window, add one instance; if it drops
    below 80%·(n−1)/n, remove one. On the TPU adaptation "utilization" is
    decode-slot occupancy of the serving replicas.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.profiles import windowed_mean


@dataclasses.dataclass(frozen=True)
class InstanceAdjustmentPolicy:
    """§6.4's policy, parameters verbatim from the paper."""

    threshold: float = 0.80      # utilization trigger
    window_seconds: float = 20.0  # averaging window
    initial_instances: int = 2
    min_instances: int = 1
    nodes_per_instance: int = 1

    def decide(self, n_instances: int, avg_utilization: float) -> int:
        """Return the instance-count delta (+1 / -1 / 0)."""
        if avg_utilization > self.threshold:
            return 1
        if (n_instances > self.min_instances
                and avg_utilization < self.threshold * (n_instances - 1) / n_instances):
            return -1
        return 0


class WSManager:
    """Manager of the web-service TRE."""

    def __init__(self, name: str = "WS",
                 policy: InstanceAdjustmentPolicy = InstanceAdjustmentPolicy()):
        self.name = name
        self.policy = policy
        self.instances = policy.initial_instances
        self.demand = 0          # nodes currently demanded (replay mode)
        self._util_samples: List[Tuple[float, float]] = []

    # ------------------------------------------------------- replay mode

    def set_demand(self, demand: int) -> int:
        """Replay-mode update; returns the delta the service must cover."""
        delta = demand - self.demand
        self.demand = demand
        return delta

    # ----------------------------------------------- live-adjustment mode

    def observe_utilization(self, t: float, utilization: float) -> Optional[int]:
        """Feed a utilization sample; returns new instance count on change."""
        self._util_samples.append((t, utilization))
        avg, self._util_samples = windowed_mean(
            self._util_samples, t, self.policy.window_seconds)
        delta = self.policy.decide(self.instances, avg)
        if delta != 0:
            self.instances += delta
            self._util_samples.clear()   # restart the window after a change
            return self.instances
        return None

    @property
    def nodes_needed(self) -> int:
        return self.instances * self.policy.nodes_per_instance
