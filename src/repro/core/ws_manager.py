"""WS TRE Manager — the web-service (inference-serving) runtime environment.

Two operating modes mirror the paper's two experiment styles:

  * **Demand replay** (§6.5.1 "the resource simulator simulates the varying
    resources consumption and drives WS Manager"): the manager replays a
    resource-consumption trace (e.g. the World Cup trace of Fig. 10) and
    requests/releases nodes from the provision service to match.

  * **Instance adjustment** (§6.4): the live policy used by the real
    serving engine — if average utilization of the current ``n`` instances
    exceeds 80% over the sampling window, add one instance; if it drops
    below 80%·(n−1)/n, remove one. On the TPU adaptation "utilization" is
    decode-slot occupancy of the serving replicas.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from repro.core.profiles import windowed_mean


@dataclasses.dataclass(frozen=True)
class InstanceAdjustmentPolicy:
    """§6.4's policy, parameters verbatim from the paper."""

    threshold: float = 0.80      # utilization trigger
    window_seconds: float = 20.0  # averaging window
    initial_instances: int = 2
    min_instances: int = 1
    nodes_per_instance: int = 1

    def decide(self, n_instances: int, avg_utilization: float) -> int:
        """Return the instance-count delta (+1 / -1 / 0)."""
        if avg_utilization > self.threshold:
            return 1
        if (n_instances > self.min_instances
                and avg_utilization < self.threshold * (n_instances - 1) / n_instances):
            return -1
        return 0


class WSManager:
    """Manager of the web-service TRE."""

    def __init__(self, name: str = "WS",
                 policy: InstanceAdjustmentPolicy = InstanceAdjustmentPolicy()):
        self.name = name
        self.policy = policy
        self.instances = policy.initial_instances
        self.draining = 0        # instances marked for removal, not yet gone
        self.demand = 0          # nodes currently demanded (replay mode)
        self._util_samples: List[Tuple[float, float]] = []

    # ------------------------------------------------------- replay mode

    def set_demand(self, demand: int) -> int:
        """Replay-mode update; returns the delta the service must cover."""
        delta = demand - self.demand
        self.demand = demand
        return delta

    # ----------------------------------------------- live-adjustment mode

    def observe_utilization(self, t: float, utilization: float) -> Optional[int]:
        """Feed a utilization sample; returns the new *serving* target
        when the policy fires (None otherwise).

        Growth commits immediately (``instances`` rises — or a draining
        instance is resurrected). Shrink is DEFERRED: an instance still
        holds requests when the policy fires, so it is only *marked*
        draining here; ``instances`` — and therefore ``nodes_needed`` —
        drops when the caller confirms the drain completed
        (:meth:`confirm_shrink`). This is what keeps the manager's count
        and the autoscaler's replica list in lockstep: the count changes
        exactly when a replica actually appears or disappears.
        """
        self._util_samples.append((t, utilization))
        avg, self._util_samples = windowed_mean(
            self._util_samples, t, self.policy.window_seconds)
        serving = self.instances - self.draining
        delta = self.policy.decide(serving, avg)
        if delta > 0:
            if self.draining:
                self.draining -= 1      # resurrect a draining instance
            else:
                self.instances += delta
            self._util_samples.clear()  # restart the window after a change
            return self.instances - self.draining
        if delta < 0:
            self.draining += 1          # marked; confirmed when drained
            self._util_samples.clear()
            return self.instances - self.draining
        return None

    def confirm_shrink(self, n: int = 1) -> None:
        """A marked instance finished draining and is gone: the count —
        and the node lease behind it — drops now, not before."""
        assert 0 <= n <= self.draining, (n, self.draining)
        self.draining -= n
        self.instances -= n

    @property
    def nodes_needed(self) -> int:
        """Nodes the WS TRE holds: draining instances still serve their
        outstanding requests, so they keep their lease until confirmed."""
        return self.instances * self.policy.nodes_per_instance
