"""Lifecycle Management Service — TRE states and creation flow (§4.3).

A TRE moves through ``uninitialized → created → running`` (and back via
``deactivate``/``destroy``). The flow follows the paper's nine-step
lifecycle: spec registration, deployment (here: building the payload —
model/optimizer/serving engine factories), configuration hand-off to the
Resource Provision Service, component start, and initial provisioning of
the lower bound.

The CSF ("common service framework") is the collection of services the
resource provider runs: this lifecycle service, a provision service
(``core.provision``), and — in the live system — the deployment hooks
that build JAX payloads (``runtime_bridge``).
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Callable, Dict, Optional

from repro.core.spec import (CoordinationModel, RuntimeEnvironmentSpec,
                             WorkloadType)


class TREState(enum.Enum):
    UNINITIALIZED = "uninitialized"
    CREATED = "created"
    RUNNING = "running"
    DEACTIVATED = "deactivated"


@dataclasses.dataclass
class TRE:
    """A thin runtime environment: Manager + Scheduler + payload."""

    spec: RuntimeEnvironmentSpec
    state: TREState = TREState.UNINITIALIZED
    manager: Optional[object] = None     # PBJManager or WSManager
    payload: Optional[object] = None     # deployed JAX payload (bridge)
    partner: Optional[str] = None        # coordinated partner TRE name


class LifecycleManagementService:
    """Creates coordinated TREs on demand from RE specifications."""

    def __init__(self) -> None:
        self._tres: Dict[str, TRE] = {}
        self._deployers: Dict[WorkloadType, Callable[[RuntimeEnvironmentSpec], object]] = {}

    def register_deployer(self, workload: WorkloadType,
                          deploy: Callable[[RuntimeEnvironmentSpec], object]) -> None:
        """CSF Deployment Service hook: builds the workload payload."""
        self._deployers[workload] = deploy

    def tre(self, name: str) -> TRE:
        return self._tres[name]

    # ------------------------------------------------------- lifecycle steps

    def create(self, spec: RuntimeEnvironmentSpec) -> TRE:
        """Steps 2–3: register the spec, deploy the TRE software."""
        spec.validate()
        if spec.name in self._tres:
            raise ValueError(f"TRE {spec.name!r} already exists")
        tre = TRE(spec=spec)
        self._tres[spec.name] = tre
        deployer = self._deployers.get(spec.workload)
        if deployer is not None:
            tre.payload = deployer(spec)
        tre.state = TREState.CREATED
        # Step 5 (partner search): "for a new PBJ TRE, Resource Provision
        # Service will search a WS TRE from another service provider for
        # coordinated resource provisioning if a service provider allows it".
        if (spec.coordination is not CoordinationModel.NONE
                and spec.allows_foreign_coordination):
            tre.partner = self._find_partner(spec)
            if tre.partner is not None:
                self._tres[tre.partner].partner = spec.name
        return tre

    def _find_partner(self, spec: RuntimeEnvironmentSpec) -> Optional[str]:
        for name, other in self._tres.items():
            if name == spec.name or other.partner is not None:
                continue
            if other.spec.workload is spec.workload:
                continue   # coordination pairs *heterogeneous* workloads
            if other.spec.coordination is not spec.coordination:
                continue
            if not other.spec.allows_foreign_coordination:
                continue
            return name
        return None

    def activate(self, name: str, manager: object) -> TRE:
        """Steps 4–6: attach the Manager and mark the TRE running."""
        tre = self._tres[name]
        if tre.state is not TREState.CREATED:
            raise ValueError(f"TRE {name!r} is {tre.state}, expected CREATED")
        tre.manager = manager
        tre.state = TREState.RUNNING
        return tre

    def deactivate(self, name: str) -> None:
        self._tres[name].state = TREState.DEACTIVATED

    def destroy(self, name: str) -> None:
        del self._tres[name]
