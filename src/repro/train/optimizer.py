"""Optimizers with mesh-sharded state (no external deps).

State tensors inherit the parameter PartitionSpecs, so optimizer memory is
fully sharded over (data × model) — ZeRO-style. ``adafactor`` (factored
second moment, no first moment by default) is used for the ≥90 B configs
so that optimizer state fits 16 GB/chip on the 16×16 mesh; ``adamw`` is
the default elsewhere. See EXPERIMENTS.md §Dry-run for the per-arch
memory analysis.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jax.Array], Tuple[PyTree, PyTree]]
    state_specs: Callable[[PyTree], PyTree]   # param specs → state specs


def _global_norm(tree: PyTree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads)


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.01,
          max_grad_norm: float = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step_lr):
        grads = clip_by_global_norm(grads, max_grad_norm)
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1.0 - b1 ** c
        bc2 = 1.0 - b2 ** c

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - step_lr * step
            return newp.astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        newp = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
        return newp, {"mu": mu, "nu": nu, "count": count}

    def state_specs(pspecs):
        from jax.sharding import PartitionSpec as P
        return {"mu": pspecs, "nu": pspecs, "count": P()}

    return Optimizer(init, update, state_specs)


def adafactor(lr: float = 1e-3, eps: float = 1e-30, decay: float = 0.8,
              max_grad_norm: float = 1.0,
              min_factored_ndim: int = 2) -> Optimizer:
    """Factored second-moment optimizer (Shazeer & Stern, 2018).

    Tensors with ndim >= 2 store row/col second-moment vectors instead of
    a full tensor: state is O(sum of dims), not O(numel) — the memory
    trick that lets grok-1/jamba/llama-90b train on 256 chips.
    """
    def _factored(p):
        return p.ndim >= min_factored_ndim

    def init(params):
        def one(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(one, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, step_lr):
        grads = clip_by_global_norm(grads, max_grad_norm)
        count = state["count"] + 1
        beta = 1.0 - count.astype(jnp.float32) ** -decay

        def upd(g, v, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                rfac = (vr / jnp.maximum(denom, eps))[..., None]
                cfac = vc[..., None, :]
                precond = g * jax.lax.rsqrt(
                    jnp.maximum(rfac * cfac, eps))
                newv = {"vr": vr, "vc": vc}
            else:
                nv = beta * v["v"] + (1 - beta) * g2
                precond = g * jax.lax.rsqrt(jnp.maximum(nv, eps))
                newv = {"v": nv}
            # Update clipping (RMS <= 1) as in the paper.
            rms = jnp.sqrt(jnp.mean(precond * precond) + 1e-30)
            precond = precond / jnp.maximum(1.0, rms)
            newp = (p.astype(jnp.float32) - step_lr * precond).astype(p.dtype)
            return newp, newv

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        new_p, new_v = [], []
        for g, v, p in zip(flat_g, flat_v, flat_p):
            np_, nv_ = upd(g, v, p)
            new_p.append(np_)
            new_v.append(nv_)
        return (jax.tree.unflatten(tdef, new_p),
                {"v": jax.tree.unflatten(tdef, new_v), "count": count})

    def state_specs(pspecs):
        from jax.sharding import PartitionSpec as P

        def one(spec):
            t = tuple(spec)
            if len(t) >= min_factored_ndim:
                return {"vr": P(*t[:-1]), "vc": P(*(t[:-2] + t[-1:]))}
            return {"v": P(*t) if t else P()}

        return {"v": jax.tree.map(one, pspecs,
                                  is_leaf=lambda s: isinstance(s, jax.sharding.PartitionSpec)),
                "count": P()}

    return Optimizer(init, update, state_specs)


def get_optimizer(name: str, lr: float = 3e-4) -> Optimizer:
    if name == "adamw":
        return adamw(lr=lr)
    if name == "adafactor":
        return adafactor(lr=lr)
    raise KeyError(name)
