"""Deterministic, shardable data pipeline.

Batches are a pure function of (seed, step) — ``batch_at(step)`` — so:

  * restart/elastic-rescale resumes mid-epoch exactly (the checkpoint
    stores only the step counter);
  * any data-parallel worker can regenerate any shard (straggler
    reassignment never loses data);
  * no host-side state needs checkpointing.

Two sources: a synthetic Zipf-distributed LM stream (default), and a
binary token-file source (memory-mapped) for file-backed corpora. Both
emit {tokens, labels} with next-token labels, plus the stub frontend
embeddings for vlm/audio archs.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zipf_a: float = 1.2         # synthetic token distribution


class SyntheticLM:
    """Zipf-token synthetic LM stream with learnable bigram structure
    (token t+1 depends on t through a fixed permutation mix), so training
    loss actually decreases — useful for end-to-end example runs."""

    def __init__(self, cfg: ArchConfig, batch: int, seq_len: int,
                 data_cfg: DataConfig = DataConfig()):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.dc = data_cfg
        rng = np.random.default_rng(data_cfg.seed + 1234)
        self._perm = rng.permutation(cfg.vocab)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.dc.seed, step]))
        b, s, v = self.batch, self.seq_len, self.cfg.vocab
        # Zipf marginals, clipped to vocab.
        base = rng.zipf(self.dc.zipf_a, size=(b, s + 1))
        toks = np.minimum(base - 1, v - 1).astype(np.int32)
        # Inject bigram structure: with p=0.5 the next token is a fixed
        # function of the current one.
        follow = self._perm[toks[:, :-1]]
        use = rng.random((b, s)) < 0.5
        nxt = np.where(use, follow, toks[:, 1:])
        seq = np.concatenate([toks[:, :1], nxt], axis=1)
        out = {"tokens": seq[:, :-1].astype(np.int32),
               "labels": seq[:, 1:].astype(np.int32)}
        if self.cfg.family in ("vlm", "audio"):
            out["frontend"] = rng.standard_normal(
                (b, self.cfg.frontend_len, self.cfg.d_model),
                dtype=np.float32) * 0.02
        return out


class TokenFileSource:
    """Memory-mapped flat token file (uint16/uint32), deterministic
    window sampling by step."""

    def __init__(self, cfg: ArchConfig, path: str, batch: int, seq_len: int,
                 dtype=np.uint16, seed: int = 0):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step]))
        n = len(self.tokens) - self.seq_len - 1
        starts = rng.integers(0, n, size=self.batch)
        rows = np.stack([self.tokens[s:s + self.seq_len + 1]
                         for s in starts]).astype(np.int32)
        rows = np.minimum(rows, self.cfg.vocab - 1)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


def make_source(cfg: ArchConfig, batch: int, seq_len: int,
                path: Optional[str] = None, seed: int = 0):
    if path:
        return TokenFileSource(cfg, path, batch, seq_len, seed=seed)
    return SyntheticLM(cfg, batch, seq_len, DataConfig(seed=seed))
