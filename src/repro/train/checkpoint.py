"""Async, mesh-shape-agnostic checkpointing — the fault-tolerance backbone.

Design (no orbax available offline; built from scratch):

  * A checkpoint is a directory ``step_<n>/`` holding one ``.npy`` blob
    per pytree leaf plus a msgpack ``manifest`` (treedef paths, shapes,
    dtypes, crc32 checksums, user metadata such as the data step).
  * Writes go to ``step_<n>.tmp/`` and are published by an atomic
    ``os.rename`` — a crash mid-write can never corrupt the latest
    checkpoint (restart scans for the newest *complete* directory).
  * ``save_async`` snapshots to host memory synchronously (cheap) and
    writes in a background thread — training continues during the write
    (compute/IO overlap).
  * ``restore`` takes the *target* mesh + PartitionSpecs: leaves are
    ``jax.device_put`` with the new NamedSharding, so a job preempted on
    a 16-chip slice restores onto an 8- or 32-chip slice unchanged —
    elastic rescale is just restore-with-different-mesh.
"""

from __future__ import annotations

import os
import re
import shutil
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

PyTree = Any
_STEP_RE = re.compile(r"^step_(\d+)$")


class TornCheckpointError(IOError):
    """A checkpoint directory is incomplete or corrupt — torn by a crash
    mid-write, partial storage loss, or bit rot (CRC mismatch).
    ``restore`` raises this instead of the raw IO/parse error so callers
    can tell "this step is damaged, try an older one"
    (:meth:`Checkpointer.restore_latest`) apart from programming errors
    like restoring into a template of the wrong structure."""


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save

    def save(self, step: int, tree: PyTree,
             metadata: Optional[Dict] = None) -> str:
        self.wait()
        host = self._snapshot(tree)
        return self._write(step, host, metadata or {})

    def save_async(self, step: int, tree: PyTree,
                   metadata: Optional[Dict] = None) -> None:
        """Snapshot synchronously, write in the background."""
        self.wait()
        host = self._snapshot(tree)
        meta = dict(metadata or {})

        def work():
            try:
                self._write(step, host, meta)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _snapshot(self, tree: PyTree):
        paths, leaves, _ = _flatten_with_paths(tree)
        return paths, [np.asarray(jax.device_get(x)) for x in leaves]

    def _write(self, step: int, host, metadata: Dict) -> str:
        paths, arrays = host
        final = os.path.join(self.directory, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "metadata": metadata, "leaves": []}
        for i, (path, arr) in enumerate(zip(paths, arrays)):
            fname = f"leaf_{i}.npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"].append({
                "path": path, "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "crc32": zlib.crc32(np.ascontiguousarray(arr).tobytes()),
            })
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)      # atomic publish
        self._gc()
        return final

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore

    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "manifest.msgpack")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template: PyTree, mesh=None,
                specs: Optional[PyTree] = None,
                verify: bool = True) -> Tuple[PyTree, Dict]:
        """Restore into the structure of ``template``; if mesh+specs are
        given, leaves are placed with the *target* sharding (reshard).
        A torn directory — unreadable/unparsable manifest, missing leaf
        blob or manifest entry, checksum mismatch — raises
        :class:`TornCheckpointError`."""
        d = os.path.join(self.directory, f"step_{step}")
        try:
            with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
                manifest = msgpack.unpackb(f.read())
        except (OSError, msgpack.UnpackException, ValueError) as e:
            raise TornCheckpointError(
                f"step {step}: unreadable manifest ({e})") from e
        by_path = {e["path"]: e for e in manifest["leaves"]}
        paths, leaves, treedef = _flatten_with_paths(template)
        spec_leaves = None
        if specs is not None:
            spec_leaves = treedef.flatten_up_to(specs)
        out = []
        for i, (path, tmpl) in enumerate(zip(paths, leaves)):
            entry = by_path.get(path)
            if entry is None:
                raise TornCheckpointError(
                    f"step {step}: leaf {path!r} missing from manifest")
            try:
                arr = np.load(os.path.join(d, entry["file"]))
            except (OSError, ValueError) as e:
                raise TornCheckpointError(
                    f"step {step}: unreadable leaf {path!r} ({e})") from e
            if verify:
                crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
                if crc != entry["crc32"]:
                    raise TornCheckpointError(
                        f"step {step}: checksum mismatch for {path}")
            if mesh is not None and spec_leaves is not None:
                from jax.sharding import NamedSharding
                arr = jax.device_put(arr,
                                     NamedSharding(mesh, spec_leaves[i]))
            out.append(arr)
        return treedef.unflatten(out), manifest["metadata"]

    def restore_latest(self, template: PyTree, mesh=None,
                       specs: Optional[PyTree] = None,
                       verify: bool = True
                       ) -> Optional[Tuple[PyTree, Dict, int]]:
        """Restore the newest *intact* checkpoint: torn steps (crash
        mid-write that beat the atomic rename, damaged blobs) are
        reported via ``warnings.warn`` and skipped, walking backwards
        until one verifies. Returns ``(tree, metadata, step)``, or
        ``None`` when no restorable checkpoint exists — exactly the
        restart semantics the chaos tier's checkpoint-restart path
        needs (a failure can never wedge a job on a torn file)."""
        import warnings
        for step in reversed(self.all_steps()):
            try:
                tree, meta = self.restore(step, template, mesh=mesh,
                                          specs=specs, verify=verify)
                return tree, meta, step
            except TornCheckpointError as e:
                # stacklevel=2: attribute the skip to restore_latest's
                # caller, not this loop body.
                warnings.warn(f"skipping torn checkpoint: {e}",
                              stacklevel=2)
        return None
