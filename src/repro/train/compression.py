"""Gradient compression over slow links — int8 error-feedback all-reduce.

At multi-pod scale the per-step gradient all-reduce crosses the inter-pod
links exactly once; those links are the slowest in the system (DCN or
sparse ICI). This module provides a ring all-reduce whose *wire format is
int8* (4× fewer bytes than fp32, 2× fewer than bf16):

  1. error feedback:  y = g + e   (residual from the previous step)
  2. per-shard scale: s = max|y| / 127  (psum-max over the axis)
  3. quantize int8, ring reduce-scatter (K-1 ppermute steps of int8
     chunks, accumulated in int32), requantize, ring all-gather (int8)
  4. new residual:    e' = y − dequantized(result-share broadcast)

Error feedback makes the quantization bias vanish over steps (Karimireddy
et al., 2019). Used by the manual-DP trainer path and quantified for the
collective-bound cells in EXPERIMENTS.md §Perf.

All functions here must run *inside* ``jax.shard_map`` with the named
axis present.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def _axis_size(axis: str) -> int:
    from repro.compat import axis_size
    return axis_size(axis)


def quantize_int8(y: jax.Array, axis: str) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization with a shared (psum-max) scale."""
    amax = jnp.max(jnp.abs(y))
    amax = jax.lax.pmax(amax, axis)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(y / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ring_reduce_scatter_int8(q: jax.Array, axis: str) -> jax.Array:
    """Ring reduce-scatter over int8 chunks, int32 accumulation.

    q: (K*C,) flat int8 on each of K shards → returns this shard's (C,)
    int32 reduced chunk. Wire traffic: (K-1)·C int8 bytes per shard.
    """
    k = _axis_size(axis)
    idx = jax.lax.axis_index(axis)
    if k == 1:
        return q.astype(jnp.int32)
    chunks = q.reshape(k, -1)
    perm = [(i, (i + 1) % k) for i in range(k)]
    # Standard ring schedule: each shard starts by sending its own chunk;
    # after step i it holds the partial sum of chunk (idx - i - 1) mod k.
    send = jax.lax.dynamic_index_in_dim(chunks, idx, axis=0,
                                        keepdims=False).astype(jnp.int32)
    acc = send
    for i in range(k - 1):
        send = jax.lax.ppermute(send, axis, perm)
        piece = jax.lax.dynamic_index_in_dim(
            chunks, (idx - i - 1) % k, axis=0, keepdims=False)
        acc = send + piece.astype(jnp.int32)
        send = acc
    return acc


def ring_all_gather(x: jax.Array, axis: str, shift: int = 0) -> jax.Array:
    """Ring all-gather ((K-1) ppermute steps).

    Piece j arriving at this shard originated at shard (idx - j) mod K;
    it is placed at slot (origin + shift) mod K. ``shift=1`` matches the
    chunk→shard mapping produced by ``ring_reduce_scatter_int8`` (shard s
    finishes holding chunk (s+1) mod K).
    """
    k = _axis_size(axis)
    if k == 1:
        return x[None]
    perm = [(i, (i + 1) % k) for i in range(k)]
    pieces = [x]
    cur = x
    for _ in range(k - 1):
        cur = jax.lax.ppermute(cur, axis, perm)
        pieces.append(cur)
    idx = jax.lax.axis_index(axis)
    stacked = jnp.stack(pieces)                     # [me, me-1, me-2, ...]
    order = (idx - jnp.arange(k) + shift) % k
    return jnp.zeros_like(stacked).at[order].set(stacked)


def ef_allreduce_mean(g: jax.Array, err: jax.Array, axis: str
                      ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 mean-all-reduce of one tensor over ``axis``.

    Returns (mean_g, new_err). Shapes are preserved; the tensor is padded
    to a multiple of the axis size internally.
    """
    k = _axis_size(axis)
    shape = g.shape
    y = g.astype(jnp.float32) + err
    q, scale = quantize_int8(y, axis)
    flat = q.reshape(-1)
    pad = (-flat.size) % (k * 128)
    flat = jnp.pad(flat, (0, pad))
    chunk = ring_reduce_scatter_int8(flat, axis)        # (C,) int32
    # Re-quantize the reduced chunk to int8 for the gather leg.
    cmax = jnp.max(jnp.abs(chunk)).astype(jnp.float32)
    cmax = jax.lax.pmax(cmax, axis)
    cscale = jnp.maximum(cmax, 1.0) / 127.0
    cq = jnp.clip(jnp.round(chunk.astype(jnp.float32) / cscale),
                  -127, 127).astype(jnp.int8)
    gathered = ring_all_gather(cq, axis, shift=1).reshape(-1)  # (K*C,) int8
    summed = gathered.astype(jnp.float32) * cscale * scale
    summed = summed[:y.size].reshape(shape)
    mean = summed / k
    # Residual: what this shard failed to communicate.
    new_err = y - (q.astype(jnp.float32) * scale)
    return mean, new_err


def ef_allreduce_tree(grads, errs, axis: str):
    """Apply ef_allreduce_mean leaf-wise over a gradient pytree."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(errs)
    means, new_errs = [], []
    for g, e in zip(flat_g, flat_e):
        m, ne = ef_allreduce_mean(g, e, axis)
        means.append(m.astype(g.dtype))
        new_errs.append(ne)
    return tdef.unflatten(means), tdef.unflatten(new_errs)


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
