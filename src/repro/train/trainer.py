"""Train-step factory: gradient accumulation, mixed precision, sharded
optimizer, and the elastic training-job runner used by the PhoenixCloud
PBJ TRE.

``make_train_step`` builds the jit-able (params, opt_state, batch) →
(params, opt_state, metrics) function used by both the real trainer and
the multi-pod dry-run. The microbatch loop is a ``lax.scan`` so the HLO
stays compact; gradients accumulate in fp32.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.models.transformer import Model
from repro.train.checkpoint import Checkpointer
from repro.train.data import make_source
from repro.train.optimizer import Optimizer, get_optimizer

PyTree = Any


def batch_pspecs(cfg: ArchConfig, ax) -> Dict[str, P]:
    specs = {"tokens": P(ax.batch_axes, None),
             "labels": P(ax.batch_axes, None)}
    if cfg.family in ("vlm", "audio"):
        specs["frontend"] = P(ax.batch_axes, None, None)
    return specs


def make_train_step(model: Model, optimizer: Optimizer,
                    accum_steps: int = 1, grad_pspecs=None) -> Callable:
    """Returns train_step(params, opt_state, batch) → (p, s, metrics).

    ``batch`` has leading global_batch; with accum_steps > 1 it is split
    into (accum, micro, ...) and scanned, accumulating fp32 grads —
    activation memory scales with the microbatch, not global batch.

    ``grad_pspecs`` (the parameter PartitionSpecs) pins the fp32
    accumulator to the parameter sharding — without it GSPMD replicates
    the accumulator and the per-step gradient sync degrades from
    reduce-scatter-sized traffic to full all-reduces (§Perf cell B).
    """

    def loss_fn(params, mb):
        return model.loss(params, mb)

    grad_fn = jax.value_and_grad(loss_fn)

    def _pin(tree):
        if grad_pspecs is None or model.mesh is None or model.mesh.size == 1:
            return tree
        from jax.sharding import NamedSharding
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(model.mesh, s)), tree, grad_pspecs)

    def train_step(params, opt_state, batch, lr):
        if accum_steps == 1:
            loss, grads = grad_fn(params, batch)
            grads = _pin(grads)
        else:
            def reshape(x):
                return x.reshape((accum_steps, x.shape[0] // accum_steps)
                                 + x.shape[1:])
            micro = jax.tree.map(reshape, batch)
            zero = _pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))

            def body(carry, mb):
                acc, loss_acc = carry
                loss, grads = grad_fn(params, mb)
                acc = _pin(jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads))
                return (acc, loss_acc + loss), None

            (gsum, loss_sum), _ = jax.lax.scan(body, (zero, 0.0), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = loss_sum / accum_steps
        params, opt_state = optimizer.update(grads, opt_state, params, lr)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


@dataclasses.dataclass
class TrainJobConfig:
    arch: str
    steps: int = 100
    batch: int = 8
    seq_len: int = 128
    lr: float = 3e-4
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    seed: int = 0
    accum_steps: int = 1
    data_path: Optional[str] = None


class TrainJob:
    """An elastic, preemptible training job — the payload a PhoenixCloud
    PBJ TRE schedules. Supports checkpoint-preempt (§5.1 adaptation):
    ``preempt()`` checkpoints and stops; ``run()`` on a new mesh restores
    and reshards automatically.
    """

    def __init__(self, cfg: ArchConfig, job: TrainJobConfig, mesh,
                 compute_dtype=jnp.float32):
        self.cfg = cfg
        self.jc = job
        self.mesh = mesh
        self.model = Model(cfg, mesh, compute_dtype=compute_dtype)
        self.optimizer = get_optimizer(cfg.optimizer, lr=job.lr)
        self.source = make_source(cfg, job.batch, job.seq_len,
                                  path=job.data_path, seed=job.seed)
        self.ckpt = Checkpointer(job.checkpoint_dir) \
            if job.checkpoint_dir else None
        self._preempt = False
        self.step = 0
        self.params = None
        self.opt_state = None
        self.history = []

    # -------------------------------------------------------------- state

    def _placed(self, tree, specs):
        return jax.tree.map(
            lambda x, s: jax.device_put(x, NamedSharding(self.mesh, s)),
            tree, specs, is_leaf=lambda x: not isinstance(x, dict))

    def initialize(self):
        pspecs = self.model.param_specs()
        if self.ckpt and self.ckpt.latest_step() is not None:
            step = self.ckpt.latest_step()
            template = jax.eval_shape(lambda: self.model.init(self.jc.seed))
            tpl = {"params": template,
                   "opt": jax.eval_shape(self.optimizer.init, template)}
            specs = {"params": pspecs,
                     "opt": self.optimizer.state_specs(pspecs)}
            state, meta = self.ckpt.restore(step, tpl, mesh=self.mesh,
                                            specs=specs)
            self.params, self.opt_state = state["params"], state["opt"]
            self.step = int(meta["step"])
        else:
            with jax.default_device(jax.devices()[0]):
                params = self.model.init(self.jc.seed)
            self.params = params
            self.opt_state = self.optimizer.init(params)
            self.step = 0

    def preempt(self):
        self._preempt = True

    def checkpoint(self, block: bool = False):
        if not self.ckpt:
            return
        self.ckpt.save_async(self.step,
                             {"params": self.params, "opt": self.opt_state},
                             metadata={"step": self.step})
        if block:
            self.ckpt.wait()

    # ---------------------------------------------------------------- run

    def run(self) -> Dict:
        if self.params is None:
            self.initialize()
        step_fn = jax.jit(make_train_step(self.model, self.optimizer,
                                          self.jc.accum_steps),
                          donate_argnums=(0, 1))
        self._preempt = False
        t0 = time.time()
        while self.step < self.jc.steps and not self._preempt:
            batch = jax.tree.map(jnp.asarray,
                                 self.source.batch_at(self.step))
            self.params, self.opt_state, metrics = step_fn(
                self.params, self.opt_state, batch,
                jnp.float32(self.jc.lr))
            self.step += 1
            self.history.append(float(metrics["loss"]))
            if self.ckpt and self.step % self.jc.checkpoint_every == 0:
                self.checkpoint()
        if self.ckpt:
            self.checkpoint(block=True)
        return {
            "completed": self.step >= self.jc.steps,
            "step": self.step,
            "loss": self.history[-1] if self.history else None,
            "wall_seconds": time.time() - t0,
        }
