"""Batched ``lax.scan`` fast path for the stateful PhoenixCloud policies.

The sweep engine (``repro.sim.sweep``) batches the *stateless* baselines
(DCS, EC2+RightScale) as exact vectorized jnp programs, but the paper's
headline grids sweep the two *stateful* coordinated policies — FB
capacity C for Fig. 13 and the FLB-NUB lease unit L for Fig. 18 — and
those used to fall back to one Python event simulation per point. This
module re-expresses both policies as one jitted, twice-vmapped
``lax.scan`` so a whole (system, parameter, trace) grid runs as a single
XLA program: axis 0 batches packed workload traces, axis 1 batches sweep
points. With ``devices`` set, ``scan_grids`` flattens the two batch axes
into one lane axis and ``shard_map``s it across host devices (padding
lanes to a device multiple, dropping the padding from the results), so
the grid's throughput scales with the machine instead of one core's
SIMD width — on CPU-only hosts, split the cores into XLA devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

Design (the scan-friendly queue/kill encoding)
----------------------------------------------

* **Job table with status lanes.** Jobs live in a fixed-size *window* of
  ``K`` lanes over the arrival-sorted job table: per lane a ``running``
  and a ``done`` flag, a remaining-runtime value and a start time.
  "Queued" is *derived* (submitted ∧ ¬running ∧ ¬done), so an FB kill is
  a masked flag flip — the killed lane is instantly queued again at its
  arrival-order position, and its runtime is re-read from the job table
  on the next start (kills need no list surgery).
* **Sliding window.** The window only ever needs to span the oldest
  unfinished job to the newest submitted one; the head advances past
  completed lanes once per chunk (one lease tick), when the next ``K``
  table rows are re-gathered. Completions fold into scalar accumulators
  (completed count, turnaround/execution sums) the substep they happen,
  so nothing outside the window is carried. A diagnostic counts the
  steps on which the backlog outgrew the window (``window_overflow``;
  0 on the paper workloads at the default ``K``).
* **Vectorized first-fit.** The §6.5.2 first-fit queue scan is a few
  *filtered-prefix* passes instead of a sequential per-job scan: each
  pass starts every candidate (queued, fits in free) whose exclusive
  prefix-sum of candidate sizes still fits. A pass never overcommits
  (the prefix bound is conservative) and each pass starts at least the
  first schedulable job, so a small fixed number of passes converges to
  the event engine's first-fit up to rare one-substep start delays.
* **FB kills as a size threshold.** §5.1 rule 2 kills smallest-size
  first. The scan encodes the kill order as power-of-two size classes:
  class sums pick the threshold class, classes strictly below it are
  killed outright, and the remainder is taken from the threshold class
  newest-arrival-first via a reversed prefix sum. This matches the event
  engine's ordering exactly up to ties inside one size class (which the
  event engine breaks by latest *start*, not latest arrival).
* **Time discretization.** Like ``repro.core.jaxsim``: job dynamics
  advance on substeps of ``dt``; policy actions (pool flow, U/V/G
  adjust, FB tick grants) fire when a substep crosses a lease boundary,
  detected per point as a ``floor(t/L)`` increment so the lease axis L
  is *traced* (Fig. 18 sweeps it inside the batch). Completions round to
  the *nearest* substep (unbiased), and each policy runs at its own
  granularity: FB's allocation hugs C between WS moves so ``FB_DT``
  is coarse; the FLB-NUB U/V/G feedback needs ``FLB_DT`` (both
  validated against the event engine at these settings).
* **Event-faithful tick ordering.** Within an FLB-NUB tick substep the
  event engine's sequence is pool grant → first-fit → U/V/G adjust →
  first-fit again on the request grant, and the scan replays exactly
  that: the adjustment reads *post-start* demand and free. Evaluating
  U/V/G on pre-start state looks harmless but lets one tick absorb a
  whole submit burst as a single DR1 request the event engine would
  have started incrementally — >50 % peak overshoot on long-lease
  (L ≥ 2 h) grids under scaled WS demand.

Fidelity contract (cross-validated in tests/test_sweep.py): completed
jobs within 2 %, node-hours within 15 %, peak within 15 % of the event
engine, and identical parameter-sweep orderings (J1/J2 trends). Adjust-
event counts are trend-faithful approximations of the event ledger.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec

from repro import compat
from repro.compat import shard_map
from repro.core.jobs import Job
from repro.core.pbj_manager import PBJPolicyParams
from repro.core.profiles import sample_steps, step_points

# PBJPolicyParams is defined jax-free in core (the event engine imports
# with numpy alone); its pytree registration lives here with the other
# scan pytrees.
jax.tree_util.register_dataclass(
    PBJPolicyParams,
    data_fields=["request_threshold", "release_threshold", "elastic_factor"],
    meta_fields=["checkpoint_preempt"])

__all__ = [
    "FBGrid", "FLBGrid", "PackedWorkloads", "ScanSpec", "pack_workloads",
    "scan_grids", "pick_dt", "DEFAULT_WINDOW", "DEFAULT_SUBSTEPS",
    "DEFAULT_FF_PASSES", "FB_DT", "FLB_DT", "FLB_MIN_DT",
]

DEFAULT_WINDOW = 192       # job-table lanes carried through the scan
FB_WINDOW = 160            # FB backlog is capacity-bound (≤ ~115 unfinished
#                            jobs on the §6.2 traces at the Fig-13 capacities)
FLB_WINDOW = 128           # FLB-NUB leases elastically, so its backlog is
#                            small; the window mostly buffers fresh arrivals
DEFAULT_SUBSTEPS = 12      # substeps per base lease (dt = base_lease / 12)
DEFAULT_FF_PASSES = 2      # filtered-prefix first-fit passes per substep
FB_DT = 900.0              # default FB substep: alloc ≈ C between WS moves,
#                            so FB tolerates a coarse grid (nh < 1 %)
FLB_DT = 300.0             # default FLB-NUB substep: the U/V/G feedback
#                            needs fine demand sampling (validated bound)
FLB_MIN_DT = 60.0          # floor of the WS-spacing cap in pick_dt — a
#                            pathological 1 s demand trace must not explode
#                            the substep count by four orders of magnitude
_KILL_CLASSES = 16         # power-of-two size classes for the FB kill order


@dataclasses.dataclass(frozen=True)
class ScanSpec:
    """Static (hashable) execution parameters of one policy's scan: the
    substep ``dt``, the horizon in substeps, the job-window size and the
    re-gather cadence. One spec per policy, so FB can run its coarse
    grid while FLB-NUB runs the fine one in the same jitted call."""

    n_steps: int
    dt: float
    window: int = DEFAULT_WINDOW
    chunk_len: int = DEFAULT_SUBSTEPS
    ff_passes: int = DEFAULT_FF_PASSES


# ------------------------------------------------------------------ pytrees

@dataclasses.dataclass(frozen=True)
class FBGrid:
    """FB sweep points (§5.1): per-point capacity C and lease unit L."""

    capacity: jnp.ndarray     # (P,)
    lease: jnp.ndarray        # (P,)


@dataclasses.dataclass(frozen=True)
class FLBGrid:
    """FLB-NUB sweep points (§5.2): B, lb_ws, U, V, G and lease L."""

    B: jnp.ndarray            # (P,)
    lb_ws: jnp.ndarray        # (P,)
    U: jnp.ndarray            # (P,)
    V: jnp.ndarray            # (P,)
    G: jnp.ndarray            # (P,)
    lease: jnp.ndarray        # (P,)


@dataclasses.dataclass(frozen=True)
class PackedWorkloads:
    """Fixed-size arrays for W workloads: arrival-sorted job tables padded
    to a common length (padding rows have ``submit = +inf``, size 0) plus
    the per-substep WS demand profile and per-chunk submit frontiers."""

    submit: jnp.ndarray       # (W, J + K) — padded past the table end too
    size: jnp.ndarray         # (W, J + K)
    runtime: jnp.ndarray      # (W, J + K)
    ws: jnp.ndarray           # (W, S) demand sampled at each substep END —
    #                           a change landing exactly on a tick applies
    #                           before the tick, like the event engine
    ws0: jnp.ndarray          # (W,) demand at t = 0 (startup allocation)
    ws_changed: jnp.ndarray   # (W, S) bool: demand differs from prev substep
    hi_chunk: jnp.ndarray     # (W, n_chunks) jobs submitted by chunk end
    n_jobs: jnp.ndarray       # (W,) real (unpadded) job counts


for _cls, _fields in ((FBGrid, ["capacity", "lease"]),
                      (FLBGrid, ["B", "lb_ws", "U", "V", "G", "lease"]),
                      (PackedWorkloads, ["submit", "size", "runtime", "ws",
                                        "ws0", "ws_changed", "hi_chunk",
                                        "n_jobs"])):
    jax.tree_util.register_dataclass(_cls, data_fields=_fields,
                                     meta_fields=[])


# ------------------------------------------------------------------ packing

def pack_workloads(workloads: Sequence[Tuple[Sequence[Job],
                                             Sequence[Tuple[float, int]]]],
                   duration: float, dt: float,
                   window: int = DEFAULT_WINDOW,
                   chunk_len: int = DEFAULT_SUBSTEPS,
                   dtype: Optional[np.dtype] = None
                   ) -> Tuple[PackedWorkloads, int]:
    """Pack ``(jobs, ws_trace)`` workloads into stacked scan arrays.

    Returns ``(packed, n_steps)`` where ``n_steps = ceil(duration / dt)``
    (the scan itself runs ``n_chunks * chunk_len >= n_steps`` substeps;
    the overhang is masked out). ``dtype`` defaults to the active jax
    x64 setting, like :func:`repro.core.jaxsim.pack_trace`.
    """
    if dtype is None:
        dtype = np.float64 if jax.config.jax_enable_x64 else np.float32
    elif np.dtype(dtype) == np.float64 and not jax.config.jax_enable_x64:
        raise ValueError(
            "dtype=float64 requested with jax x64 disabled — jnp.asarray "
            "would silently downcast to float32; wrap the call in "
            "jax.experimental.enable_x64()")
    n_steps = int(np.ceil(duration / dt))
    n_chunks = -(-n_steps // chunk_len)
    s_pad = n_chunks * chunk_len
    max_jobs = max(len(jobs) for jobs, _ in workloads)
    J = max_jobs + window                      # window can slide past the end
    submit = np.full((len(workloads), J), np.inf, dtype)
    size = np.zeros((len(workloads), J), dtype)
    runtime = np.zeros((len(workloads), J), dtype)
    ws = np.zeros((len(workloads), s_pad), dtype)
    ws0 = np.zeros(len(workloads), dtype)
    hi_chunk = np.zeros((len(workloads), n_chunks), np.int32)
    n_jobs = np.zeros(len(workloads), np.int32)
    for w, (jobs, ws_trace) in enumerate(workloads):
        order = sorted(jobs, key=lambda j: j.submit)
        n_jobs[w] = len(order)
        submit[w, :len(order)] = [j.submit for j in order]
        size[w, :len(order)] = [j.size for j in order]
        runtime[w, :len(order)] = [j.runtime for j in order]
        times, values = step_points(ws_trace, duration)
        prof = sample_steps(times, values, np.arange(1, n_steps + 1) * dt)
        ws[w, :n_steps] = prof.astype(dtype)
        ws0[w] = values[0]
        chunk_end_t = (np.arange(1, n_chunks + 1) * chunk_len) * dt
        hi_chunk[w] = np.searchsorted(submit[w, :len(order)], chunk_end_t,
                                      side="right")
    ws_changed = np.zeros(ws.shape, bool)
    ws_changed[:, 1:] = ws[:, 1:] != ws[:, :-1]
    ws_changed[:, 0] = ws[:, 0] != ws0
    return PackedWorkloads(
        submit=jnp.asarray(submit), size=jnp.asarray(size),
        runtime=jnp.asarray(runtime), ws=jnp.asarray(ws),
        ws0=jnp.asarray(ws0), ws_changed=jnp.asarray(ws_changed),
        hi_chunk=jnp.asarray(hi_chunk), n_jobs=jnp.asarray(n_jobs)), n_steps


# ---------------------------------------------------------- scan primitives

def _first_fit(free, queued, size, passes: int):
    """Vectorized §6.5.2 first-fit: ``passes`` filtered-prefix rounds.

    Each round admits every candidate whose exclusive prefix sum of
    *candidate* sizes still fits — a conservative bound (candidates it
    counts are a superset of what actually starts), so the admitted set
    never overcommits, and the earliest schedulable job always starts.
    """
    started = jnp.zeros_like(queued)
    for _ in range(passes):
        cand = queued & ~started & (size <= free)
        sz = jnp.where(cand, size, jnp.zeros_like(size))
        prefix = jnp.cumsum(sz) - sz
        start = cand & (prefix + size <= free)
        free = free - jnp.sum(jnp.where(start, size, jnp.zeros_like(size)))
        started = started | start
    return free, started


def _size_classes(size):
    """Power-of-two size classes encoding the §5.1 kill priority (small
    first). Returns ``(cls, onehot)``; hoisted to once per chunk."""
    cls = jnp.clip(jnp.ceil(jnp.log2(jnp.maximum(size, 1.0))),
                   0, _KILL_CLASSES - 1).astype(jnp.int32)
    onehot = (cls[:, None] == jnp.arange(_KILL_CLASSES)[None, :]
              ).astype(size.dtype)
    return cls, onehot


def _kill_selection(running, size, cls, onehot, kill_need):
    """§5.1 rule 2 kill set: smallest size class first, newest-arrival
    first inside the threshold class, until ``kill_need`` nodes free."""
    run_sz = jnp.where(running, size, jnp.zeros_like(size))
    class_sum = run_sz @ onehot                             # (_KILL_CLASSES,)
    below = jnp.concatenate([jnp.zeros(1, size.dtype),
                             jnp.cumsum(class_sum)[:-1]])  # freed below class c
    # Threshold class: first class whose cumulative sum covers the need.
    covered = below + class_sum >= kill_need
    thresh = jnp.argmax(covered)          # all-False → 0, but then need == 0
    kill_all = running & (cls < thresh)
    # Partial kills inside the threshold class, newest arrival first.
    rem_need = jnp.maximum(kill_need - below[thresh], 0.0)
    in_thr = running & (cls == thresh)
    thr_sz = jnp.where(in_thr, size, jnp.zeros_like(size))
    rev_prefix = jnp.cumsum(thr_sz[::-1])[::-1] - thr_sz
    kill_thr = in_thr & (rev_prefix < rem_need)
    killed = jnp.where(kill_need > 0, kill_all | kill_thr,
                       jnp.zeros_like(running))
    return killed


# ------------------------------------------------------------- the scan core

def _simulate(policy: str, prm: Dict, tr_submit, tr_size, tr_runtime,
              tr_ws, tr_ws0, tr_ws_changed, tr_hi, spec: ScanSpec) -> Dict:
    """One (point, workload) pair; vmapped over both axes by the caller.

    All array args are a single workload's lanes; ``prm`` holds one sweep
    point's scalars. ``policy`` is static ("fb" | "flb_nub").
    """
    n_steps, dt = spec.n_steps, spec.dt
    chunk_len, ff_passes = spec.chunk_len, spec.ff_passes
    K = spec.window
    n_chunks = tr_ws.shape[0] // chunk_len
    Jp = tr_submit.shape[0]        # includes >= K pad rows (submit = +inf)
    f = tr_ws.dtype
    L = prm["lease"].astype(f)
    ws0 = tr_ws0
    if policy == "fb":
        C = prm["capacity"].astype(f)
        owned0 = C - jnp.minimum(ws0, C)     # startup: all idle → PBJ (§5.1)
        pool0 = jnp.zeros((), f)
    else:
        B = prm["B"].astype(f)
        lb_ws = prm["lb_ws"].astype(f)
        U, V, G = (prm[k].astype(f) for k in ("U", "V", "G"))
        owned0 = jnp.maximum(B - lb_ws, 1.0)  # startup lower bound (§5.2)
        pool0 = owned0

    def make_substep(w_sub, w_sz, w_rt, w_cls, w_onehot):
      def substep(carry, xs):
        s_idx, wsv, ws_chg = xs
        (owned, pool_pbj, run, done, rem, start_t, acc) = carry
        t = (s_idx + 1.0) * dt
        active = s_idx < n_steps
        is_tick = active & (jnp.floor(t / L) > jnp.floor(s_idx * dt / L))

        # 1. Advance running jobs one substep; fold completions into the
        # scalar accumulators the moment they happen.
        rem = jnp.where(run & active, rem - dt, rem)
        completing = run & (rem <= 0.5 * dt) & active
        run = run & ~completing
        done = done | completing
        acc["completed"] += jnp.sum(completing)
        acc["turn_sum"] += jnp.sum(jnp.where(completing, t - w_sub, 0.0))
        acc["exec_sum"] += jnp.sum(jnp.where(completing, t - start_t, 0.0))

        queued = active & (w_sub <= t) & ~run & ~done
        used = jnp.sum(jnp.where(run, w_sz, 0.0))

        if policy == "fb":
            # 2. §5.1 rule 3: WS demand beats PBJ (kills if needed). The
            # event engine applies WS changes before tick grants; same
            # order here.
            ws_t = jnp.minimum(wsv, C)
            need = jnp.maximum(owned - (C - ws_t), 0.0)
            free = owned - used
            kill_need = jnp.minimum(jnp.maximum(need - free, 0.0), used)
            killed = _kill_selection(run, w_sz, w_cls, w_onehot, kill_need)
            run = run & ~killed          # killed lanes re-queue derived
            used = used - jnp.sum(jnp.where(killed, w_sz, 0.0))
            owned = owned - need
            acc["kills"] += jnp.sum(killed)
            # 3. §5.1 rule 4: on the tick, all idle resources → PBJ TRE.
            idle = jnp.maximum(C - ws_t - owned, 0.0)
            grant = jnp.where(is_tick, idle, 0.0)
            owned = owned + grant
            pbj_ev = (grant > 0).astype(f) + (need > 0).astype(f)
            alloc = owned + ws_t
            # 4. First-fit in arrival order over the window lanes (§6.5.2).
            free = owned - used
            _, starts = _first_fit(free, queued, w_sz, ff_passes)
            run = run | starts
            rem = jnp.where(starts, w_rt, rem)       # runtime read on start —
            start_t = jnp.where(starts, t, start_t)  # kills reset lazily
        else:
            # 2. §5.2 rule 3: idle pool flows to the PBJ TRE on the tick.
            pool_ws = jnp.minimum(wsv, lb_ws)
            pool_idle = jnp.maximum(B - pool_ws - pool_pbj, 0.0)
            grant = jnp.where(is_tick, pool_idle, 0.0)
            owned = owned + grant
            pool_pbj = pool_pbj + grant
            # 3. First-fit BEFORE the adjustment: the event engine's tick
            # is grant → schedule → adjust → schedule, so the U/V/G rules
            # must see post-start demand and free — evaluating them on
            # pre-start state inflates DR1 by exactly the backlog the
            # grant could have started, and those phantom requests
            # compound into >50 % peak overshoots on long-lease grids.
            free = owned - used
            _, starts = _first_fit(free, queued, w_sz, ff_passes)
            run = run | starts
            rem = jnp.where(starts, w_rt, rem)
            start_t = jnp.where(starts, t, start_t)
            queued = queued & ~starts
            used = used + jnp.sum(jnp.where(starts, w_sz, 0.0))
            # 4. §5.2 rules 2–4: the U/V/G adjustment on the tick.
            demand = jnp.sum(jnp.where(queued, w_sz, 0.0))
            ratio = jnp.where(owned > 0, demand / jnp.maximum(owned, 1.0),
                              jnp.where(demand > 0, jnp.inf, 0.0))
            biggest = jnp.max(jnp.where(queued, w_sz, 0.0))
            free = owned - used
            dr1 = jnp.maximum(demand - owned, 0.0)
            dr2 = jnp.maximum(biggest - free, 0.0)
            req = jnp.where(is_tick & (ratio > U), dr1,
                            jnp.where(is_tick & (biggest > owned), dr2, 0.0))
            rss = jnp.where(is_tick & (ratio < V) & (req == 0.0),
                            jnp.floor(G * jnp.maximum(free, 0.0)), 0.0)
            owned = owned + req - rss
            pool_pbj = jnp.minimum(pool_pbj, owned)   # leased released first
            pbj_ev = (req > 0).astype(f) + (rss > 0).astype(f)
            alloc = B + jnp.maximum(owned - pool_pbj, 0.0) \
                + jnp.maximum(wsv - lb_ws, 0.0)
            # 5. Second first-fit: the event engine runs the §6.5.2 scan
            # again the moment a request is granted.
            free = owned - used
            _, starts2 = _first_fit(free, queued, w_sz, ff_passes)
            run = run | starts2
            rem = jnp.where(starts2, w_rt, rem)
            start_t = jnp.where(starts2, t, start_t)

        # 6. Accounting (§6.1 metrics).
        alloc = jnp.where(active, alloc, 0.0)
        acc["node_seconds"] += alloc * dt
        acc["peak"] = jnp.maximum(acc["peak"], alloc)
        acc["pbj_adjusts"] += jnp.where(active, pbj_ev, 0.0)
        acc["adjusts"] += jnp.where(active, pbj_ev + ws_chg.astype(f), 0.0)
        return (owned, pool_pbj, run, done, rem, start_t, acc), None
      return substep

    lanes = jnp.arange(K, dtype=jnp.int32)

    def chunk(carry, xs):
        chunk_i, ws_c, ws_chg_c, hi_end = xs
        jidx, next_row, owned, pool_pbj, run, rem, start_t, acc = carry
        w_sub = tr_submit[jidx]
        w_sz = tr_size[jidx]
        w_rt = tr_runtime[jidx]
        substep = make_substep(w_sub, w_sz, w_rt, *_size_classes(w_sz))
        s0 = (chunk_i * chunk_len).astype(f)
        steps = (s0 + jnp.arange(chunk_len, dtype=f), ws_c, ws_chg_c)
        done = jnp.zeros(K, bool)
        (owned, pool_pbj, run, done, rem, start_t, acc), _ = jax.lax.scan(
            substep, (owned, pool_pbj, run, done, rem, start_t, acc), steps)
        # Compact finished lanes out of the window (stable, so lane order
        # stays arrival order) and admit the next job-table rows into the
        # freed tail. Rows are admitted ahead of their submit time, so
        # mid-chunk arrivals are already on a lane when they submit.
        keep = ~done
        tgt = jnp.where(keep, jnp.cumsum(keep) - 1, K)      # K → dropped
        n_keep = jnp.sum(keep)
        fresh = jnp.minimum(next_row + lanes - n_keep, Jp - 1)
        compact = lambda a, fill: jnp.where(
            lanes >= n_keep, fill,
            jnp.full((K,), fill, a.dtype).at[tgt].set(a, mode="drop"))
        jidx = jnp.where(lanes >= n_keep, fresh,
                         jnp.zeros(K, jnp.int32).at[tgt].set(jidx,
                                                             mode="drop"))
        run = compact(run, False)
        rem = compact(rem, jnp.zeros((), f))
        start_t = compact(start_t, jnp.zeros((), f))
        next_row = jnp.minimum(next_row + (K - n_keep), Jp - 1)
        acc["window_overflow"] += (hi_end > next_row).astype(f)
        return (jidx, next_row, owned, pool_pbj, run, rem, start_t, acc), None

    acc0 = {k: jnp.zeros((), f) for k in
            ("completed", "turn_sum", "exec_sum", "kills", "node_seconds",
             "peak", "pbj_adjusts", "adjusts", "window_overflow")}
    acc0["adjusts"] = (ws0 > 0).astype(f)   # startup WS allocation event
    carry0 = (lanes, jnp.asarray(K, jnp.int32), owned0, pool0,
              jnp.zeros(K, bool), jnp.zeros(K, f), jnp.zeros(K, f), acc0)
    xs = (jnp.arange(n_chunks, dtype=f),
          tr_ws.reshape(n_chunks, chunk_len),
          tr_ws_changed.reshape(n_chunks, chunk_len),
          tr_hi)
    carry, _ = jax.lax.scan(chunk, carry0, xs)
    acc = carry[-1]
    n_done = jnp.maximum(acc["completed"], 1.0)
    return {
        "completed_jobs": acc["completed"],
        "avg_turnaround": acc["turn_sum"] / n_done,
        "avg_execution": acc["exec_sum"] / n_done,
        "node_hours": acc["node_seconds"] / 3600.0,
        "peak_nodes": acc["peak"],
        "adjust_events": acc["adjusts"],
        "pbj_adjust_events": acc["pbj_adjusts"],
        "kills": acc["kills"],
        "window_overflow": acc["window_overflow"],
    }


@functools.partial(jax.jit, static_argnames=("fb_spec", "flb_spec"))
def _scan_grids_single(fb: Optional[FBGrid], flb: Optional[FLBGrid],
                       fb_packed: Optional[PackedWorkloads],
                       flb_packed: Optional[PackedWorkloads], *,
                       fb_spec: Optional[ScanSpec] = None,
                       flb_spec: Optional[ScanSpec] = None
                       ) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Single-device execution: the (trace, point) grid as nested vmaps."""
    def run(policy, prm_tree, packed, spec):
        one = lambda prm, s, z, r, w, w0, wc, h: _simulate(
            policy, prm, s, z, r, w, w0, wc, h, spec)
        over_points = jax.vmap(one, in_axes=(0,) + (None,) * 7)
        over_traces = jax.vmap(over_points, in_axes=(None,) + (0,) * 7)
        return over_traces(prm_tree, packed.submit, packed.size,
                           packed.runtime, packed.ws, packed.ws0,
                           packed.ws_changed, packed.hi_chunk)

    out: Dict[str, Dict[str, jnp.ndarray]] = {}
    if fb_spec is not None:
        out["fb"] = run("fb", _prm_tree("fb", fb), fb_packed, fb_spec)
    if flb_spec is not None:
        out["flb_nub"] = run("flb_nub", _prm_tree("flb_nub", flb),
                             flb_packed, flb_spec)
    return out


def _prm_tree(policy: str, grid) -> Dict[str, jnp.ndarray]:
    if policy == "fb":
        return {"capacity": grid.capacity, "lease": grid.lease}
    return {"B": grid.B, "lb_ws": grid.lb_ws, "U": grid.U, "V": grid.V,
            "G": grid.G, "lease": grid.lease}


@functools.partial(jax.jit, static_argnames=("policy", "spec", "mesh"))
def _lanes_sharded(prm_tree, packed: PackedWorkloads, w_idx, p_idx, *,
                   policy: str, spec: ScanSpec, mesh):
    """One policy's flattened (trace, point) lanes split across ``mesh``.

    ``w_idx`` / ``p_idx`` map each lane to its workload row and sweep
    point; they are sharded over the mesh's ``lanes`` axis while the
    grid and the packed workloads stay replicated, so each device
    gathers just its own lane slice and runs the plain vmapped scan on
    it — no collectives, the lanes are embarrassingly parallel.
    """
    def lanes(w_l, p_l, prm, pk):
        prm_l = jax.tree_util.tree_map(lambda a: a[p_l], prm)
        one = lambda prm1, s, z, r, w, w0, wc, h: _simulate(
            policy, prm1, s, z, r, w, w0, wc, h, spec)
        return jax.vmap(one)(prm_l, pk.submit[w_l], pk.size[w_l],
                             pk.runtime[w_l], pk.ws[w_l], pk.ws0[w_l],
                             pk.ws_changed[w_l], pk.hi_chunk[w_l])

    lane = PartitionSpec("lanes")
    rep = PartitionSpec()
    fn = shard_map(lanes, mesh, in_specs=(lane, lane, rep, rep),
                   out_specs=lane, check_vma=False)
    return fn(w_idx, p_idx, prm_tree, packed)


def _scan_grids_sharded(fb, flb, fb_packed, flb_packed, fb_spec, flb_spec,
                        devices) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Shard each policy's (trace × point) lanes across ``devices``.

    Lanes are padded up to a multiple of the device count with copies of
    lane 0 (every device needs an equal shard); the padding is dropped
    before the metrics are reshaped back to ``(W, P)``, so padded lanes
    never reach a reported metric. Each lane runs the identical
    ``_simulate`` program the single-device path vmaps, so per-lane
    results do not depend on the device split.
    """
    mesh = Mesh(np.asarray(devices), ("lanes",))
    d = len(devices)

    def run(policy, grid, packed, spec):
        prm_tree = _prm_tree(policy, grid)
        w = int(packed.submit.shape[0])
        p = int(grid.lease.shape[0])
        n = w * p
        pad = -n % d
        w_idx = np.concatenate([np.repeat(np.arange(w), p),
                                np.zeros(pad, np.int64)]).astype(np.int32)
        p_idx = np.concatenate([np.tile(np.arange(p), w),
                                np.zeros(pad, np.int64)]).astype(np.int32)
        flat = _lanes_sharded(prm_tree, packed, jnp.asarray(w_idx),
                              jnp.asarray(p_idx), policy=policy, spec=spec,
                              mesh=mesh)
        return {k: v[:n].reshape(w, p) for k, v in flat.items()}

    out: Dict[str, Dict[str, jnp.ndarray]] = {}
    if fb_spec is not None:
        out["fb"] = run("fb", fb, fb_packed, fb_spec)
    if flb_spec is not None:
        out["flb_nub"] = run("flb_nub", flb, flb_packed, flb_spec)
    return out


def scan_grids(fb: Optional[FBGrid], flb: Optional[FLBGrid],
               fb_packed: Optional[PackedWorkloads],
               flb_packed: Optional[PackedWorkloads], *,
               fb_spec: Optional[ScanSpec] = None,
               flb_spec: Optional[ScanSpec] = None,
               devices: compat.Devices = None
               ) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Evaluate FB and FLB-NUB sweep grids over all packed workloads in
    one jitted program. Returns ``{"fb": metrics, "flb_nub": metrics}``
    where each metric array has shape ``(W, P_policy)``; a policy is
    skipped when its spec is ``None``. Each policy runs at its own
    (static) :class:`ScanSpec` — the packs may use different substeps.

    ``devices`` (``None`` | device count | device sequence, see
    ``repro.compat.resolve_devices``) selects the execution backend:
    ``None`` / one device runs the nested-vmap program on the default
    device; two or more shard the flattened (trace × point) lane axis
    across the devices with ``shard_map``, padding the lane count up to
    a device multiple and dropping the padding from the results. The
    sharded path computes the identical per-lane program, only placed
    differently, so its rows are bit-identical to the single-device
    path's (tests/test_sweep_sharded.py pins this).
    """
    devs = compat.resolve_devices(devices)
    if devs is None:
        return _scan_grids_single(fb, flb, fb_packed, flb_packed,
                                  fb_spec=fb_spec, flb_spec=flb_spec)
    return _scan_grids_sharded(fb, flb, fb_packed, flb_packed,
                               fb_spec, flb_spec, devs)


def pick_dt(policy: str, leases: Sequence[float],
            ws_traces: Optional[Sequence[Sequence[Tuple[float, int]]]] = None,
            duration: Optional[float] = None) -> float:
    """Default substep for a policy's grid: the validated granularity
    (``FB_DT`` / ``FLB_DT``), never coarser than the shortest lease in
    the grid (so every lease gets at least one policy substep).

    For FLB-NUB the substep is additionally capped by the shortest WS
    change-point spacing across ``ws_traces`` (floored at
    ``FLB_MIN_DT``): the scan samples WS demand once per substep, and a
    demand trace finer than the substep would alias the U/V/G feedback
    the §5.2 policy runs on. Change points at or beyond ``duration`` are
    ignored — the scan never simulates them, so they must not shrink the
    substep. The paper's World Cup profile steps every 300 s — exactly
    ``FLB_DT`` — so the cap only bites on finer traces.
    """
    base = FB_DT if policy == "fb" else FLB_DT
    dt = min(base, min(leases))
    if policy == "flb_nub" and ws_traces:
        horizon = duration if duration is not None else np.inf
        spacing = min((b - a for trace in ws_traces
                       for (a, _), (b, _) in zip(trace, trace[1:])
                       if b > a and a < horizon), default=dt)
        dt = min(dt, max(spacing, FLB_MIN_DT))
    return dt
