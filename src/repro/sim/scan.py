"""Batched ``lax.scan`` fast path for the stateful PhoenixCloud policies.

The sweep engine (``repro.sim.sweep``) batches the *stateless* baselines
(DCS, EC2+RightScale) as exact vectorized jnp programs, but the paper's
headline grids sweep the two *stateful* coordinated policies — FB
capacity C for Fig. 13 and the FLB-NUB lease unit L for Fig. 18 — and
those used to fall back to one Python event simulation per point. This
module re-expresses both policies as one jitted, twice-vmapped
``lax.scan`` so a whole (system, parameter, trace) grid runs as a single
XLA program: axis 0 batches packed workload traces, axis 1 batches sweep
points. With ``devices`` set, ``scan_grids`` flattens the two batch axes
into one lane axis and ``shard_map``s it across host devices (padding
lanes to a device multiple, dropping the padding from the results), so
the grid's throughput scales with the machine instead of one core's
SIMD width — on CPU-only hosts, split the cores into XLA devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N``.

Design (the scan-friendly queue/kill encoding)
----------------------------------------------

* **Job table with status lanes.** Jobs live in a fixed-size *window* of
  ``K`` lanes over the arrival-sorted job table: per lane a ``running``
  and a ``done`` flag, a remaining-runtime value and a start time.
  "Queued" is *derived* (submitted ∧ ¬running ∧ ¬done), so an FB kill is
  a masked flag flip — the killed lane is instantly queued again at its
  arrival-order position, and its runtime is re-read from the job table
  on the next start (kills need no list surgery).
* **Sliding window.** The window only ever needs to span the oldest
  unfinished job to the newest submitted one; the head advances past
  completed lanes once per chunk (one lease tick), when the next ``K``
  table rows are re-gathered. Completions fold into scalar accumulators
  (completed count, turnaround/execution sums) the substep they happen,
  so nothing outside the window is carried. A diagnostic counts the
  steps on which the backlog outgrew the window (``window_overflow``;
  0 on the paper workloads at the default ``K``).
* **Vectorized first-fit.** The §6.5.2 first-fit queue scan is a few
  *filtered-prefix* passes instead of a sequential per-job scan: each
  pass starts every candidate (queued, fits in free) whose exclusive
  prefix-sum of candidate sizes still fits. A pass never overcommits
  (the prefix bound is conservative) and each pass starts at least the
  first schedulable job, so a small fixed number of passes converges to
  the event engine's first-fit up to rare one-substep start delays.
* **FB kills as a size threshold.** §5.1 rule 2 kills smallest-size
  first. The scan encodes the kill order as power-of-two size classes:
  class sums pick the threshold class, classes strictly below it are
  killed outright, and the remainder is taken from the threshold class
  newest-arrival-first via a reversed prefix sum. This matches the event
  engine's ordering exactly up to ties inside one size class (which the
  event engine breaks by latest *start*, not latest arrival).
* **Time discretization.** Like ``repro.core.jaxsim``: job dynamics
  advance on substeps of ``dt``; policy actions (pool flow, U/V/G
  adjust, FB tick grants) fire when a substep crosses a lease boundary,
  detected per point as a ``floor(t/L)`` increment so the lease axis L
  is *traced* (Fig. 18 sweeps it inside the batch). Completions round to
  the *nearest* substep (unbiased), and each policy runs at its own
  granularity: FB's allocation hugs C between WS moves so ``FB_DT``
  is coarse; the FLB-NUB U/V/G feedback needs ``FLB_DT`` (both
  validated against the event engine at these settings).
* **Event-faithful tick ordering.** Within an FLB-NUB tick substep the
  event engine's sequence is pool grant → first-fit → U/V/G adjust →
  first-fit again on the request grant, and the scan replays exactly
  that: the adjustment reads *post-start* demand and free. Evaluating
  U/V/G on pre-start state looks harmless but lets one tick absorb a
  whole submit burst as a single DR1 request the event engine would
  have started incrementally — >50 % peak overshoot on long-lease
  (L ≥ 2 h) grids under scaled WS demand.

Fidelity contract (cross-validated in tests/test_sweep.py): completed
jobs within 2 %, node-hours within 15 %, peak within 15 % of the event
engine, and identical parameter-sweep orderings (J1/J2 trends). Adjust-
event counts are trend-faithful approximations of the event ledger.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec

from repro import compat
from repro.compat import shard_map
from repro.core.jobs import Job
from repro.core.pbj_manager import PBJPolicyParams
from repro.core.profiles import sample_steps, step_points

# PBJPolicyParams is defined jax-free in core (the event engine imports
# with numpy alone); its pytree registration lives here with the other
# scan pytrees.
jax.tree_util.register_dataclass(
    PBJPolicyParams,
    data_fields=["request_threshold", "release_threshold", "elastic_factor"],
    meta_fields=["checkpoint_preempt"])

__all__ = [
    "FBGrid", "FLBGrid", "PackedWorkloads", "ScanSpec", "pack_workloads",
    "pack_job_table", "resolve_pack_dtype", "scan_grids", "pick_dt",
    "fb_actions", "flb_actions", "compact_window", "sharded_grid_map",
    "DEFAULT_WINDOW", "DEFAULT_SUBSTEPS", "DEFAULT_FF_PASSES",
    "FB_DT", "FLB_DT", "FLB_MIN_DT",
]

DEFAULT_WINDOW = 192       # job-table lanes carried through the scan
FB_WINDOW = 192            # FB backlog is capacity-bound (≤ 158 unfinished
#                            jobs on the §6.2 traces at the Fig-13
#                            capacities — SDSC BLUE at C=128) and the
#                            window additionally buffers a whole chunk of
#                            arrivals; 160 overflowed there, which the
#                            window_overflow warning now surfaces
FLB_WINDOW = 128           # FLB-NUB leases elastically, so its backlog is
#                            small; the window mostly buffers fresh arrivals
DEFAULT_SUBSTEPS = 12      # substeps per base lease (dt = base_lease / 12)
DEFAULT_FF_PASSES = 2      # filtered-prefix first-fit passes per substep
FB_DT = 900.0              # default FB substep: alloc ≈ C between WS moves,
#                            so FB tolerates a coarse grid (nh < 1 %)
FLB_DT = 300.0             # default FLB-NUB substep: the U/V/G feedback
#                            needs fine demand sampling (validated bound)
FLB_MIN_DT = 60.0          # floor of the WS-spacing cap in pick_dt — a
#                            pathological 1 s demand trace must not explode
#                            the substep count by four orders of magnitude
_KILL_CLASSES = 16         # power-of-two size classes for the FB kill order


@dataclasses.dataclass(frozen=True)
class ScanSpec:
    """Static (hashable) execution parameters of one policy's scan: the
    substep ``dt``, the horizon in substeps, the job-window size and the
    re-gather cadence. One spec per policy, so FB can run its coarse
    grid while FLB-NUB runs the fine one in the same jitted call."""

    n_steps: int
    dt: float
    window: int = DEFAULT_WINDOW
    chunk_len: int = DEFAULT_SUBSTEPS
    ff_passes: int = DEFAULT_FF_PASSES


# ------------------------------------------------------------------ pytrees

@dataclasses.dataclass(frozen=True)
class FBGrid:
    """FB sweep points (§5.1): per-point capacity C and lease unit L."""

    capacity: jnp.ndarray     # (P,)
    lease: jnp.ndarray        # (P,)


@dataclasses.dataclass(frozen=True)
class FLBGrid:
    """FLB-NUB sweep points (§5.2): B, lb_ws, U, V, G and lease L."""

    B: jnp.ndarray            # (P,)
    lb_ws: jnp.ndarray        # (P,)
    U: jnp.ndarray            # (P,)
    V: jnp.ndarray            # (P,)
    G: jnp.ndarray            # (P,)
    lease: jnp.ndarray        # (P,)


@dataclasses.dataclass(frozen=True)
class PackedWorkloads:
    """Fixed-size arrays for W workloads: arrival-sorted job tables padded
    to a common length (padding rows have ``submit = +inf``, size 0) plus
    the per-substep WS demand profile and per-chunk submit frontiers."""

    submit: jnp.ndarray       # (W, J + K) — padded past the table end too
    size: jnp.ndarray         # (W, J + K)
    runtime: jnp.ndarray      # (W, J + K)
    ws: jnp.ndarray           # (W, S) demand sampled at each substep END —
    #                           a change landing exactly on a tick applies
    #                           before the tick, like the event engine
    ws0: jnp.ndarray          # (W,) demand at t = 0 (startup allocation)
    ws_changed: jnp.ndarray   # (W, S) bool: demand differs from prev substep
    hi_chunk: jnp.ndarray     # (W, n_chunks) jobs submitted by chunk end
    n_jobs: jnp.ndarray       # (W,) real (unpadded) job counts


for _cls, _fields in ((FBGrid, ["capacity", "lease"]),
                      (FLBGrid, ["B", "lb_ws", "U", "V", "G", "lease"]),
                      (PackedWorkloads, ["submit", "size", "runtime", "ws",
                                        "ws0", "ws_changed", "hi_chunk",
                                        "n_jobs"])):
    jax.tree_util.register_dataclass(_cls, data_fields=_fields,
                                     meta_fields=[])


# ------------------------------------------------------------------ packing

# Canonical copy lives in repro.compat; re-exported here because every
# pack caller historically imports it from the scan module.
resolve_pack_dtype = compat.resolve_pack_dtype


def pack_job_table(workloads: Sequence[Tuple[Sequence[Job],
                                             Sequence[Tuple[float, int]]]],
                   window: int, dtype: np.dtype
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                              np.ndarray]:
    """Arrival-sorted job tables padded to a common length plus a full
    window of trailing padding rows (``submit = +inf``, size 0) so the
    window can slide past the table end. Shared by the fixed-dt scan
    pack and the event-round pack (``repro.sim.rounds``). Returns
    ``(submit, size, runtime, n_jobs)`` as numpy arrays of shape
    ``(W, max_jobs + window)`` / ``(W,)``.
    """
    max_jobs = max(len(jobs) for jobs, _ in workloads)
    J = max_jobs + window                      # window can slide past the end
    submit = np.full((len(workloads), J), np.inf, dtype)
    size = np.zeros((len(workloads), J), dtype)
    runtime = np.zeros((len(workloads), J), dtype)
    n_jobs = np.zeros(len(workloads), np.int32)
    for w, (jobs, _) in enumerate(workloads):
        order = sorted(jobs, key=lambda j: j.submit)
        n_jobs[w] = len(order)
        submit[w, :len(order)] = [j.submit for j in order]
        size[w, :len(order)] = [j.size for j in order]
        runtime[w, :len(order)] = [j.runtime for j in order]
    return submit, size, runtime, n_jobs


def pack_workloads(workloads: Sequence[Tuple[Sequence[Job],
                                             Sequence[Tuple[float, int]]]],
                   duration: float, dt: float,
                   window: int = DEFAULT_WINDOW,
                   chunk_len: int = DEFAULT_SUBSTEPS,
                   dtype: Optional[np.dtype] = None
                   ) -> Tuple[PackedWorkloads, int]:
    """Pack ``(jobs, ws_trace)`` workloads into stacked scan arrays.

    Returns ``(packed, n_steps)`` where ``n_steps = ceil(duration / dt)``
    (the scan itself runs ``n_chunks * chunk_len >= n_steps`` substeps;
    the overhang is masked out). ``dtype`` defaults to the active jax
    x64 setting, like :func:`repro.core.jaxsim.pack_trace`.
    """
    dtype = resolve_pack_dtype(dtype)
    n_steps = int(np.ceil(duration / dt))
    n_chunks = -(-n_steps // chunk_len)
    s_pad = n_chunks * chunk_len
    submit, size, runtime, n_jobs = pack_job_table(workloads, window, dtype)
    ws = np.zeros((len(workloads), s_pad), dtype)
    ws0 = np.zeros(len(workloads), dtype)
    hi_chunk = np.zeros((len(workloads), n_chunks), np.int32)
    for w, (jobs, ws_trace) in enumerate(workloads):
        times, values = step_points(ws_trace, duration)
        prof = sample_steps(times, values, np.arange(1, n_steps + 1) * dt)
        ws[w, :n_steps] = prof.astype(dtype)
        ws0[w] = values[0]
        chunk_end_t = (np.arange(1, n_chunks + 1) * chunk_len) * dt
        hi_chunk[w] = np.searchsorted(submit[w, :n_jobs[w]], chunk_end_t,
                                      side="right")
    ws_changed = np.zeros(ws.shape, bool)
    ws_changed[:, 1:] = ws[:, 1:] != ws[:, :-1]
    ws_changed[:, 0] = ws[:, 0] != ws0
    return PackedWorkloads(
        submit=jnp.asarray(submit), size=jnp.asarray(size),
        runtime=jnp.asarray(runtime), ws=jnp.asarray(ws),
        ws0=jnp.asarray(ws0), ws_changed=jnp.asarray(ws_changed),
        hi_chunk=jnp.asarray(hi_chunk), n_jobs=jnp.asarray(n_jobs)), n_steps


# ---------------------------------------------------------- scan primitives

def _first_fit(free, queued, size, passes: int):
    """Vectorized §6.5.2 first-fit: ``passes`` filtered-prefix rounds.

    Each round admits every candidate whose exclusive prefix sum of
    *candidate* sizes still fits — a conservative bound (candidates it
    counts are a superset of what actually starts), so the admitted set
    never overcommits, and the earliest schedulable job always starts.
    """
    started = jnp.zeros_like(queued)
    for _ in range(passes):
        cand = queued & ~started & (size <= free)
        sz = jnp.where(cand, size, jnp.zeros_like(size))
        prefix = jnp.cumsum(sz) - sz
        start = cand & (prefix + size <= free)
        free = free - jnp.sum(jnp.where(start, size, jnp.zeros_like(size)))
        started = started | start
    return free, started


def _size_classes(size):
    """Power-of-two size classes encoding the §5.1 kill priority (small
    first). Returns ``(cls, class_masks)`` where ``class_masks`` is the
    ``(_KILL_CLASSES, K)`` membership mask — the per-class sums reduce
    over a masked stack, which XLA:CPU executes an order of magnitude
    faster inside a loop body than the equivalent (K, C) matmul."""
    cls = jnp.clip(jnp.ceil(jnp.log2(jnp.maximum(size, 1.0))),
                   0, _KILL_CLASSES - 1).astype(jnp.int32)
    class_masks = cls[None, :] == jnp.arange(_KILL_CLASSES)[:, None]
    return cls, class_masks


def _kill_selection(running, size, cls, class_masks, kill_need):
    """§5.1 rule 2 kill set: smallest size class first, newest-arrival
    first inside the threshold class, until ``kill_need`` nodes free."""
    run_sz = jnp.where(running, size, jnp.zeros_like(size))
    class_sum = jnp.sum(jnp.where(class_masks, run_sz[None, :],
                                  jnp.zeros_like(size)[None, :]),
                        axis=-1)                            # (_KILL_CLASSES,)
    below = jnp.concatenate([jnp.zeros(1, size.dtype),
                             jnp.cumsum(class_sum)[:-1]])  # freed below class c
    # Threshold class: first class whose cumulative sum covers the need.
    covered = below + class_sum >= kill_need
    thresh = jnp.argmax(covered)          # all-False → 0, but then need == 0
    kill_all = running & (cls < thresh)
    # Partial kills inside the threshold class, newest arrival first.
    rem_need = jnp.maximum(kill_need - below[thresh], 0.0)
    in_thr = running & (cls == thresh)
    thr_sz = jnp.where(in_thr, size, jnp.zeros_like(size))
    rev_prefix = jnp.cumsum(thr_sz[::-1])[::-1] - thr_sz
    kill_thr = in_thr & (rev_prefix < rem_need)
    killed = jnp.where(kill_need > 0, kill_all | kill_thr,
                       jnp.zeros_like(running))
    return killed


# ------------------------------------------------ shared policy-step helpers
#
# One instant of each policy's §5 rules, expressed over the window lanes.
# Both time discretizations drive these: the fixed-dt substep below feeds
# them its substep state, and the event-round engine (repro.sim.rounds)
# feeds them exact event times. Runtime bookkeeping (remaining-time vs
# absolute end-time) stays with the caller, which applies ``starts`` /
# ``killed`` to its own encoding.

def fb_actions(C, owned, run, used, queued, wsv, w_sz, w_cls, w_cls_masks,
               is_tick, ff_passes: int):
    """§5.1 rules 2–4 at one instant: WS reclaim (killing smallest-first
    when idle nodes don't cover the demand rise), the on-tick grant of
    all idle resources to the PBJ TRE, and the arrival-order first-fit.

    Returns ``(owned, run, starts, killed, alloc, pbj_ev)``; ``run`` in
    the result excludes ``killed`` and includes ``starts``.
    """
    ws_t = jnp.minimum(wsv, C)
    need = jnp.maximum(owned - (C - ws_t), 0.0)
    free = owned - used
    kill_need = jnp.minimum(jnp.maximum(need - free, 0.0), used)
    killed = _kill_selection(run, w_sz, w_cls, w_cls_masks, kill_need)
    run = run & ~killed          # killed lanes re-queue derived
    used = used - jnp.sum(jnp.where(killed, w_sz, jnp.zeros_like(w_sz)))
    owned = owned - need
    idle = jnp.maximum(C - ws_t - owned, 0.0)
    grant = jnp.where(is_tick, idle, 0.0)
    owned = owned + grant
    f = w_sz.dtype
    pbj_ev = (grant > 0).astype(f) + (need > 0).astype(f)
    alloc = owned + ws_t
    free = owned - used
    _, starts = _first_fit(free, queued, w_sz, ff_passes)
    run = run | starts
    return owned, run, starts, killed, alloc, pbj_ev


def flb_actions(B, lb_ws, U, V, G, owned, pool_pbj, run, used, queued,
                wsv, w_sz, is_tick, ff_passes: int):
    """§5.2 rules 2–4 at one instant, in the event engine's tick order:
    pool grant → first-fit → U/V/G adjust on *post-start* demand and
    free → second first-fit on the request grant (evaluating the rules
    on pre-start state lets one tick absorb a whole submit burst as a
    single DR1 request — the long-lease peak overshoot fixed in PR 3).

    Returns ``(owned, pool_pbj, run, starts, alloc, pbj_ev)`` where
    ``starts`` is the union of both first-fit passes (same instant, so
    the caller's start-time bookkeeping is identical for both).
    """
    pool_ws = jnp.minimum(wsv, lb_ws)
    pool_idle = jnp.maximum(B - pool_ws - pool_pbj, 0.0)
    grant = jnp.where(is_tick, pool_idle, 0.0)
    owned = owned + grant
    pool_pbj = pool_pbj + grant
    free = owned - used
    _, starts = _first_fit(free, queued, w_sz, ff_passes)
    run = run | starts
    queued = queued & ~starts
    used = used + jnp.sum(jnp.where(starts, w_sz, jnp.zeros_like(w_sz)))
    demand = jnp.sum(jnp.where(queued, w_sz, jnp.zeros_like(w_sz)))
    ratio = jnp.where(owned > 0, demand / jnp.maximum(owned, 1.0),
                      jnp.where(demand > 0, jnp.inf, 0.0))
    biggest = jnp.max(jnp.where(queued, w_sz, jnp.zeros_like(w_sz)))
    free = owned - used
    dr1 = jnp.maximum(demand - owned, 0.0)
    dr2 = jnp.maximum(biggest - free, 0.0)
    req = jnp.where(is_tick & (ratio > U), dr1,
                    jnp.where(is_tick & (biggest > owned), dr2, 0.0))
    rss = jnp.where(is_tick & (ratio < V) & (req == 0.0),
                    jnp.floor(G * jnp.maximum(free, 0.0)), 0.0)
    owned = owned + req - rss
    pool_pbj = jnp.minimum(pool_pbj, owned)       # leased released first
    f = w_sz.dtype
    pbj_ev = (req > 0).astype(f) + (rss > 0).astype(f)
    alloc = B + jnp.maximum(owned - pool_pbj, 0.0) \
        + jnp.maximum(wsv - lb_ws, 0.0)
    free = owned - used
    _, starts2 = _first_fit(free, queued, w_sz, ff_passes)
    run = run | starts2
    return owned, pool_pbj, run, starts | starts2, alloc, pbj_ev


def stable_compact(keep, arrays, fills):
    """Stable partition: kept lanes move to the head in lane order, the
    tail reads ``fills``. One stacked *gather* moves every array at once
    — XLA:CPU runs the equivalent scatter an order of magnitude slower
    inside a loop body, and this compaction sits on the hot path of the
    event-round engine (every few rounds) as well as the scan's chunk
    boundary. Arrays are cast through the float dtype of the first
    array (lane payloads are flags, times and small ints — all exact in
    it). Returns ``(compacted_arrays, n_keep)``.
    """
    K = keep.shape[0]
    f = next((a.dtype for a in arrays if a.dtype.kind == "f"),
             arrays[0].dtype)
    cs = jnp.cumsum(keep)
    n_keep = cs[-1]
    # src[i] = index of the (i+1)-th kept lane (searchsorted over the
    # monotone keep-prefix), valid for lanes < n_keep.
    # arange(K) + 1 (not arange(1, K + 1)): the latter lowers to a
    # captured numpy constant under Pallas tracing; the former is a
    # staged iota, identical values either way.
    src = jnp.minimum(jnp.searchsorted(cs, jnp.arange(K) + 1), K - 1)
    valid = jnp.arange(K) < n_keep
    stacked = jnp.stack([a.astype(f) for a in arrays])
    moved = stacked[:, src]
    fill_col = jnp.stack([jnp.asarray(fill, f).reshape(())
                          for fill in fills])[:, None]
    out = jnp.where(valid[None, :], moved, fill_col)
    return [out[i].astype(a.dtype) for i, a in enumerate(arrays)], n_keep


def compact_window(keep, jidx, next_row, Jp: int, fields):
    """Compact kept lanes to the window head (stable, so lane order
    stays arrival order) and admit the next job-table rows into the
    freed tail. ``fields`` is a sequence of ``(array, fill)`` pairs
    compacted alongside ``jidx``; admitted lanes read ``fill`` until
    their table row is gathered. Returns ``(jidx, next_row, compacted)``.
    """
    K = jidx.shape[0]
    lanes = jnp.arange(K, dtype=jnp.int32)
    arrays, n_keep = stable_compact(
        keep, [jidx] + [a for a, _ in fields],
        [0] + [fill for _, fill in fields])
    fresh = jnp.minimum(next_row + lanes - n_keep, Jp - 1)
    jidx = jnp.where(lanes >= n_keep, fresh, arrays[0])
    next_row = jnp.minimum(next_row + (K - n_keep), Jp - 1)
    return jidx, next_row, arrays[1:]


# ------------------------------------------------------------- the scan core

def _simulate(policy: str, prm: Dict, tr_submit, tr_size, tr_runtime,
              tr_ws, tr_ws0, tr_ws_changed, tr_hi, spec: ScanSpec) -> Dict:
    """One (point, workload) pair; vmapped over both axes by the caller.

    All array args are a single workload's lanes; ``prm`` holds one sweep
    point's scalars. ``policy`` is static ("fb" | "flb_nub").
    """
    n_steps, dt = spec.n_steps, spec.dt
    chunk_len, ff_passes = spec.chunk_len, spec.ff_passes
    K = spec.window
    n_chunks = tr_ws.shape[0] // chunk_len
    Jp = tr_submit.shape[0]        # includes >= K pad rows (submit = +inf)
    f = tr_ws.dtype
    L = prm["lease"].astype(f)
    ws0 = tr_ws0
    if policy == "fb":
        C = prm["capacity"].astype(f)
        owned0 = C - jnp.minimum(ws0, C)     # startup: all idle → PBJ (§5.1)
        pool0 = jnp.zeros((), f)
    else:
        B = prm["B"].astype(f)
        lb_ws = prm["lb_ws"].astype(f)
        U, V, G = (prm[k].astype(f) for k in ("U", "V", "G"))
        owned0 = jnp.maximum(B - lb_ws, 1.0)  # startup lower bound (§5.2)
        pool0 = owned0

    def make_substep(w_sub, w_sz, w_rt, w_cls, w_onehot):
      def substep(carry, xs):
        s_idx, wsv, ws_chg = xs
        (owned, pool_pbj, run, done, rem, start_t, acc) = carry
        t = (s_idx + 1.0) * dt
        active = s_idx < n_steps
        is_tick = active & (jnp.floor(t / L) > jnp.floor(s_idx * dt / L))

        # 1. Advance running jobs one substep; fold completions into the
        # scalar accumulators the moment they happen.
        rem = jnp.where(run & active, rem - dt, rem)
        completing = run & (rem <= 0.5 * dt) & active
        run = run & ~completing
        done = done | completing
        acc["completed"] += jnp.sum(completing)
        acc["turn_sum"] += jnp.sum(jnp.where(completing, t - w_sub, 0.0))
        acc["exec_sum"] += jnp.sum(jnp.where(completing, t - start_t, 0.0))

        queued = active & (w_sub <= t) & ~run & ~done
        used = jnp.sum(jnp.where(run, w_sz, 0.0))

        if policy == "fb":
            # 2-4. §5.1 WS reclaim (kills) → tick grant → first-fit; the
            # event engine applies WS changes before tick grants, and
            # fb_actions replays that order.
            owned, run, starts, killed, alloc, pbj_ev = fb_actions(
                C, owned, run, used, queued, wsv, w_sz, w_cls, w_onehot,
                is_tick, ff_passes)
            acc["kills"] += jnp.sum(killed)
        else:
            # 2-4. §5.2 pool grant → first-fit → U/V/G on post-start
            # state → first-fit (the event engine's tick order).
            owned, pool_pbj, run, starts, alloc, pbj_ev = flb_actions(
                B, lb_ws, U, V, G, owned, pool_pbj, run, used, queued,
                wsv, w_sz, is_tick, ff_passes)
        rem = jnp.where(starts, w_rt, rem)       # runtime read on start —
        start_t = jnp.where(starts, t, start_t)  # kills reset lazily

        # 6. Accounting (§6.1 metrics).
        alloc = jnp.where(active, alloc, 0.0)
        acc["node_seconds"] += alloc * dt
        acc["peak"] = jnp.maximum(acc["peak"], alloc)
        acc["pbj_adjusts"] += jnp.where(active, pbj_ev, 0.0)
        acc["adjusts"] += jnp.where(active, pbj_ev + ws_chg.astype(f), 0.0)
        return (owned, pool_pbj, run, done, rem, start_t, acc), None
      return substep

    lanes = jnp.arange(K, dtype=jnp.int32)

    def chunk(carry, xs):
        chunk_i, ws_c, ws_chg_c, hi_end = xs
        jidx, next_row, owned, pool_pbj, run, rem, start_t, acc = carry
        w_sub = tr_submit[jidx]
        w_sz = tr_size[jidx]
        w_rt = tr_runtime[jidx]
        substep = make_substep(w_sub, w_sz, w_rt, *_size_classes(w_sz))
        s0 = (chunk_i * chunk_len).astype(f)
        steps = (s0 + jnp.arange(chunk_len, dtype=f), ws_c, ws_chg_c)
        done = jnp.zeros(K, bool)
        (owned, pool_pbj, run, done, rem, start_t, acc), _ = jax.lax.scan(
            substep, (owned, pool_pbj, run, done, rem, start_t, acc), steps)
        # Compact finished lanes out of the window and admit the next
        # job-table rows into the freed tail. Rows are admitted ahead of
        # their submit time, so mid-chunk arrivals are already on a lane
        # when they submit.
        jidx, next_row, (run, rem, start_t) = compact_window(
            ~done, jidx, next_row, Jp,
            ((run, False), (rem, jnp.zeros((), f)),
             (start_t, jnp.zeros((), f))))
        acc["window_overflow"] += (hi_end > next_row).astype(f)
        return (jidx, next_row, owned, pool_pbj, run, rem, start_t, acc), None

    acc0 = {k: jnp.zeros((), f) for k in
            ("completed", "turn_sum", "exec_sum", "kills", "node_seconds",
             "peak", "pbj_adjusts", "adjusts", "window_overflow")}
    acc0["adjusts"] = (ws0 > 0).astype(f)   # startup WS allocation event
    carry0 = (lanes, jnp.asarray(K, jnp.int32), owned0, pool0,
              jnp.zeros(K, bool), jnp.zeros(K, f), jnp.zeros(K, f), acc0)
    xs = (jnp.arange(n_chunks, dtype=f),
          tr_ws.reshape(n_chunks, chunk_len),
          tr_ws_changed.reshape(n_chunks, chunk_len),
          tr_hi)
    carry, _ = jax.lax.scan(chunk, carry0, xs)
    acc = carry[-1]
    n_done = jnp.maximum(acc["completed"], 1.0)
    return {
        "completed_jobs": acc["completed"],
        "avg_turnaround": acc["turn_sum"] / n_done,
        "avg_execution": acc["exec_sum"] / n_done,
        "node_hours": acc["node_seconds"] / 3600.0,
        "peak_nodes": acc["peak"],
        "adjust_events": acc["adjusts"],
        "pbj_adjust_events": acc["pbj_adjusts"],
        "kills": acc["kills"],
        "window_overflow": acc["window_overflow"],
    }


@functools.lru_cache(maxsize=None)
def _scan_lane(policy: str, spec: ScanSpec):
    """The per-lane scan program as a ``(prm, packed_row) -> metrics``
    closure. Cached per (policy, spec) so the function object is stable
    across calls — it keys the jit caches of the batched runners."""
    def lane(prm, pk: PackedWorkloads):
        return _simulate(policy, prm, pk.submit, pk.size, pk.runtime,
                         pk.ws, pk.ws0, pk.ws_changed, pk.hi_chunk, spec)
    return lane


@functools.partial(compat.jit, static_argnames=("fb_spec", "flb_spec"),
                   donate_argnums=(2, 3))
def _scan_grids_single(fb: Optional[FBGrid], flb: Optional[FLBGrid],
                       fb_packed: Optional[PackedWorkloads],
                       flb_packed: Optional[PackedWorkloads], *,
                       fb_spec: Optional[ScanSpec] = None,
                       flb_spec: Optional[ScanSpec] = None
                       ) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Single-device execution: the (trace, point) grid as nested vmaps.

    The packed-workload buffers are donated (on backends with buffer
    donation — ``repro.compat.jit``) so a large (point × trace) grid
    never holds the lane tables twice; callers repack per invocation.
    """
    def run(policy, prm_tree, packed, spec):
        lane = _scan_lane(policy, spec)
        over_points = jax.vmap(lane, in_axes=(0, None))
        over_traces = jax.vmap(over_points, in_axes=(None, 0))
        return over_traces(prm_tree, packed)

    out: Dict[str, Dict[str, jnp.ndarray]] = {}
    if fb_spec is not None:
        out["fb"] = run("fb", _prm_tree("fb", fb), fb_packed, fb_spec)
    if flb_spec is not None:
        out["flb_nub"] = run("flb_nub", _prm_tree("flb_nub", flb),
                             flb_packed, flb_spec)
    return out


def _prm_tree(policy: str, grid) -> Dict[str, jnp.ndarray]:
    if policy == "fb":
        return {"capacity": grid.capacity, "lease": grid.lease}
    return {"B": grid.B, "lb_ws": grid.lb_ws, "U": grid.U, "V": grid.V,
            "G": grid.G, "lease": grid.lease}


@functools.partial(compat.jit, static_argnames=("lane_fn", "mesh"),
                   donate_argnums=(1,))
def _sharded_lanes(prm_tree, packed, w_idx, p_idx, *, lane_fn, mesh):
    """Flattened (trace, point) lanes split across ``mesh``, for any
    per-lane program ``lane_fn(prm, packed_row) -> metrics``.

    ``w_idx`` / ``p_idx`` map each lane to its workload row and sweep
    point; they are sharded over the mesh's ``lanes`` axis while the
    grid and the packed workloads stay replicated, so each device
    gathers just its own lane slice and runs the plain vmapped program
    on it — no collectives, the lanes are embarrassingly parallel. The
    packed buffers are donated where the backend supports it.
    """
    def lanes(w_l, p_l, prm, pk):
        prm_l = jax.tree_util.tree_map(lambda a: a[p_l], prm)
        pk_l = jax.tree_util.tree_map(lambda a: a[w_l], pk)
        return jax.vmap(lane_fn)(prm_l, pk_l)

    lane = PartitionSpec("lanes")
    rep = PartitionSpec()
    fn = shard_map(lanes, mesh, in_specs=(lane, lane, rep, rep),
                   out_specs=lane, check_vma=False)
    return fn(w_idx, p_idx, prm_tree, packed)


def sharded_grid_map(lane_fn, prm_tree, packed, n_workloads: int,
                     n_points: int, devices) -> Dict[str, jnp.ndarray]:
    """Run ``lane_fn`` over the flattened (trace × point) lanes sharded
    across ``devices`` and reshape the metrics back to ``(W, P)``.

    Lanes are padded up to a multiple of the device count with copies of
    lane 0 (every device needs an equal shard); the padding is dropped
    before the metrics are reshaped, so padded lanes never reach a
    reported metric. Each lane runs the identical per-lane program the
    single-device path vmaps, so per-lane results do not depend on the
    device split. Shared by the fixed-dt scan and the event-round engine
    (``repro.sim.rounds``); ``lane_fn`` must be a stable (cached) object
    — it keys the jit cache.
    """
    mesh = Mesh(np.asarray(devices), ("lanes",))
    d = len(devices)
    w, p = n_workloads, n_points
    n = w * p
    pad = -n % d
    w_idx = np.concatenate([np.repeat(np.arange(w), p),
                            np.zeros(pad, np.int64)]).astype(np.int32)
    p_idx = np.concatenate([np.tile(np.arange(p), w),
                            np.zeros(pad, np.int64)]).astype(np.int32)
    flat = _sharded_lanes(prm_tree, packed, jnp.asarray(w_idx),
                          jnp.asarray(p_idx), lane_fn=lane_fn, mesh=mesh)
    # Gather host-side: a device-side slice/reshape of a lanes-sharded
    # array compiles a tiny cross-module all-gather, and XLA:CPU's
    # rendezvous can deadlock it against the still-executing sharded
    # program (observed with the long interpret-mode fused round-step
    # executable: rank 0 never reaches the rendezvous and every thread
    # parks at 0% CPU). block_until_ready serializes the two, and
    # np.asarray assembles the shards with no collective at all.
    flat = jax.block_until_ready(flat)
    return {k: np.asarray(v)[:n].reshape(w, p) for k, v in flat.items()}


def _scan_grids_sharded(fb, flb, fb_packed, flb_packed, fb_spec, flb_spec,
                        devices) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Shard each policy's (trace × point) lanes across ``devices``
    (see :func:`sharded_grid_map`)."""
    out: Dict[str, Dict[str, jnp.ndarray]] = {}
    if fb_spec is not None:
        out["fb"] = sharded_grid_map(
            _scan_lane("fb", fb_spec), _prm_tree("fb", fb), fb_packed,
            int(fb_packed.submit.shape[0]), int(fb.lease.shape[0]), devices)
    if flb_spec is not None:
        out["flb_nub"] = sharded_grid_map(
            _scan_lane("flb_nub", flb_spec), _prm_tree("flb_nub", flb),
            flb_packed, int(flb_packed.submit.shape[0]),
            int(flb.lease.shape[0]), devices)
    return out


def scan_grids(fb: Optional[FBGrid], flb: Optional[FLBGrid],
               fb_packed: Optional[PackedWorkloads],
               flb_packed: Optional[PackedWorkloads], *,
               fb_spec: Optional[ScanSpec] = None,
               flb_spec: Optional[ScanSpec] = None,
               devices: compat.Devices = None
               ) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Evaluate FB and FLB-NUB sweep grids over all packed workloads in
    one jitted program. Returns ``{"fb": metrics, "flb_nub": metrics}``
    where each metric array has shape ``(W, P_policy)``; a policy is
    skipped when its spec is ``None``. Each policy runs at its own
    (static) :class:`ScanSpec` — the packs may use different substeps.

    ``devices`` (``None`` | device count | device sequence, see
    ``repro.compat.resolve_devices``) selects the execution backend:
    ``None`` / one device runs the nested-vmap program on the default
    device; two or more shard the flattened (trace × point) lane axis
    across the devices with ``shard_map``, padding the lane count up to
    a device multiple and dropping the padding from the results. The
    sharded path computes the identical per-lane program, only placed
    differently, so its rows are bit-identical to the single-device
    path's (tests/test_sweep_sharded.py pins this).

    On backends with buffer donation (GPU/TPU — see ``repro.compat.jit``)
    the packed-workload buffers are DONATED so large grids never hold
    the lane tables twice: re-pack per call rather than reusing one
    ``PackedWorkloads`` across calls. On CPU donation is dropped and
    reuse is safe.
    """
    devs = compat.resolve_devices(devices)
    if devs is None:
        return _scan_grids_single(fb, flb, fb_packed, flb_packed,
                                  fb_spec=fb_spec, flb_spec=flb_spec)
    return _scan_grids_sharded(fb, flb, fb_packed, flb_packed,
                               fb_spec, flb_spec, devs)


def pick_dt(policy: str, leases: Sequence[float],
            ws_traces: Optional[Sequence[Sequence[Tuple[float, int]]]] = None,
            duration: Optional[float] = None) -> float:
    """Default substep for a policy's grid: the validated granularity
    (``FB_DT`` / ``FLB_DT``), never coarser than the shortest lease in
    the grid (so every lease gets at least one policy substep).

    For FLB-NUB the substep is additionally capped by the shortest WS
    change-point spacing across ``ws_traces`` (floored at
    ``FLB_MIN_DT``): the scan samples WS demand once per substep, and a
    demand trace finer than the substep would alias the U/V/G feedback
    the §5.2 policy runs on. Change points at or beyond ``duration`` are
    ignored — the scan never simulates them, so they must not shrink the
    substep. The paper's World Cup profile steps every 300 s — exactly
    ``FLB_DT`` — so the cap only bites on finer traces.
    """
    base = FB_DT if policy == "fb" else FLB_DT
    dt = min(base, min(leases))
    if policy == "flb_nub" and ws_traces:
        horizon = duration if duration is not None else np.inf
        spacing = min((b - a for trace in ws_traces
                       for (a, _), (b, _) in zip(trace, trace[1:])
                       if b > a and a < horizon), default=dt)
        dt = min(dt, max(spacing, FLB_MIN_DT))
    return dt
