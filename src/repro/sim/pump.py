"""The shared event core — one pump under the simulator AND the live bridge.

``EventPump`` is the heap + tie-order + ``Started``-feedback machinery
factored out of the old monolithic ``run_sim`` loop, so the reference
simulator (``repro.sim.engine``) and the live runtime bridge
(``repro.core.runtime_bridge.LiveCloud``) drive one and the same clock
through one :class:`~repro.core.system.ProvisioningSystem` lifecycle.
The simulator drains the heap to the horizon (:meth:`EventPump.run`);
the live bridge advances incrementally (:meth:`EventPump.run_until`)
and injects its own work — training quanta, serving ticks — as CALL
events between the provisioning events.

Event kinds and their simultaneity order (the paper's §5/§6 semantics,
identical to the old engine loop: demand changes apply before lease
ticks, ticks before submits, submits before finishes; CALL slots in
after demand so an embedder's handler at time t still sees any WS
change at t already applied, and any WS event a CALL handler *pushes*
at its own time t dispatches before a tick at t — the live replay's
autoscaler feedback keeps the WS-before-tick invariant for free):

    WS < CALL < TICK < SUBMIT < FINISH < REPAIR < FAIL

Ties within one kind break by push order (a monotone sequence number),
so rebuilding ``run_sim`` on this pump reproduces the old loop's event
order — and therefore its ``SimResult`` rows — bit for bit.

REPAIR/FAIL are the chaos tier (``repro.sim.faults``): both sort after
FINISH at the same timestamp, so a job finishing exactly when its node
dies still completes (the no-lost-jobs invariant of
``CONTRACTS["faults"]`` — and the same convention the rounds engine
gets for free by folding completions before capacity stops). REPAIR
sorts before FAIL so capacity returning at t is visible to a failure
striking at the same t.

``DecisionLedger`` is the structured record both paths write through
the same dispatch site: one entry per provisioning event (startup,
ws-demand, lease-tick, submit, finish) with the handler's argument, the
jobs it started, the kills it caused, and the post-handler node counts.
Two ledgers from the same (PBJ, WS) trace — one live, one simulated —
diff under ``CONTRACTS["live"]`` (``repro.sim.contracts``).

Pure stdlib on purpose: importable with numpy alone, like the rest of
the event engine.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Callable, List, Optional, Sequence, Tuple

from repro.core.pbj_manager import Started
from repro.core.system import ProvisioningSystem

__all__ = ["WS", "CALL", "TICK", "SUBMIT", "FINISH", "REPAIR", "FAIL",
           "LedgerEntry", "DecisionLedger", "EventPump"]

# Simultaneity order (see module docstring). WS/TICK/SUBMIT/FINISH keep
# their relative order from the old run_sim loop; CALL is the pump's
# extension point for embedders (the live bridge's training quanta and
# serving ticks) and never occurs in pure simulation. REPAIR/FAIL are
# the fault-injection tier and sort last: finishes beat failures at the
# same instant, repairs beat failures at the same instant.
WS, CALL, TICK, SUBMIT, FINISH, REPAIR, FAIL = 0, 1, 2, 3, 4, 5, 6

_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class LedgerEntry:
    """One provisioning decision, as both paths record it."""

    t: float
    kind: str          # "startup" | "ws" | "tick" | "submit" | "finish"
                       # | "fail" | "repair"
    arg: float         # ws: demand; submit/finish: jid; startup:
                       # ws_initial; fail/repair: node count
    started: int       # jobs the handler started
    killed: int        # pbj kill_count delta across the handler — a
                       # kill on a "fail" row is a failure kill, on any
                       # other row a policy kill (§5.1 WS priority)
    pbj_nodes: int     # post-handler allocation of the PBJ TRE
    ws_nodes: int      # post-handler allocation of the WS TRE
    total_nodes: int   # post-handler total allocation of the site
    shed: int = 0      # WS demand units newly shed by the handler
                       # (demand exceeded surviving capacity)


class DecisionLedger:
    """Append-only record of every provisioning decision."""

    def __init__(self) -> None:
        self.entries: List[LedgerEntry] = []

    def record(self, entry: LedgerEntry) -> None:
        self.entries.append(entry)

    # ------------------------------------------------------------ queries

    def demand_series(self) -> List[Tuple[float, int]]:
        """The WS demand step series this run actually applied: the
        startup initial plus every ws-demand event, as (t, demand)
        change points (the live side's autoscaler-derived curve)."""
        out: List[Tuple[float, int]] = []
        for e in self.entries:
            if e.kind == "startup":
                out.append((e.t, int(e.arg)))
            elif e.kind == "ws":
                out.append((e.t, int(e.arg)))
        return out

    def kills(self, kind: Optional[str] = None) -> int:
        """Total kills, optionally restricted to one event kind —
        ``kills("fail")`` counts failure kills, ``kills()`` all kills,
        and their difference the §5.1 policy kills; live-vs-sim diffs
        must not conflate the two."""
        return sum(e.killed for e in self.entries
                   if kind is None or e.kind == kind)

    def sheds(self) -> int:
        """Total WS demand units shed (demand > surviving capacity)."""
        return sum(e.shed for e in self.entries)

    def counts(self) -> dict:
        """Events by kind plus total kills/starts — the summary the
        differential harness prints next to the contract verdict."""
        by_kind: dict = {}
        for e in self.entries:
            by_kind[e.kind] = by_kind.get(e.kind, 0) + 1
        return {"events": by_kind, "kills": self.kills(),
                "failure_kills": self.kills("fail"),
                "sheds": self.sheds(),
                "starts": sum(e.started for e in self.entries)}


def _allocated(cluster, name: str) -> int:
    try:
        return cluster.allocated(name)
    except KeyError:            # a system without that ledger account
        return 0


class EventPump:
    """Heap-ordered event dispatch over one ``ProvisioningSystem``.

    Parameters
    ----------
    system:       the provisioning system whose lifecycle handlers the
                  pump drives.
    duration:     measurement horizon; events beyond ``duration`` are
                  neither scheduled nor dispatched (§6.1). ``math.inf``
                  for an open-ended live session.
    ledger:       optional :class:`DecisionLedger` written at every
                  dispatch.
    finish_gate:  optional predicate over ``Started`` — schedule the
                  job's FINISH event only when it returns True. The
                  live bridge gates out jobs bound to real payloads
                  (their completion is detected by payload progress,
                  not simulated end times); default schedules all.
    """

    def __init__(self, system: ProvisioningSystem,
                 duration: float = math.inf,
                 ledger: Optional[DecisionLedger] = None,
                 finish_gate: Optional[Callable[[Started], bool]] = None):
        self.system = system
        self.duration = duration
        self.ledger = ledger
        self.finish_gate = finish_gate
        self.now = 0.0
        self._heap: List[Tuple[float, int, int, object]] = []
        self._seq = itertools.count()
        self._past_horizon = False

    # ------------------------------------------------------- scheduling

    def push(self, t: float, kind: int, payload: object = None) -> None:
        if t <= self.duration + _EPS:
            heapq.heappush(self._heap, (t, kind, next(self._seq), payload))

    def push_starts(self, starts: List[Started]) -> None:
        for s in starts:
            if self.finish_gate is None or self.finish_gate(s):
                self.push(s.end_time, FINISH, (s.job.jid, s.epoch))

    def add_jobs(self, jobs: Sequence) -> None:
        for job in jobs:
            self.push(job.submit, SUBMIT, job)

    def add_ws_trace(self, ws_trace: Sequence[Tuple[float, int]]) -> int:
        """Schedule a WS demand step series; entries at t <= 0 collapse
        into the returned initial demand (pass it to :meth:`startup`)."""
        ws_initial = 0
        for t, d in ws_trace:
            if t <= 0:
                ws_initial = d
            else:
                self.push(t, WS, d)
        return ws_initial

    def add_faults(self, schedule) -> None:
        """Schedule a :class:`repro.sim.faults.FaultSchedule` (any object
        with an ``events()`` iterator of ``(t, delta)`` pairs — +k means
        k nodes fail at t, -k means k nodes repaired). Events at t <= 0
        are dropped: the startup allocation always sees full capacity,
        matching the rounds engine's packing."""
        for t, delta in schedule.events():
            if t <= 0:
                continue
            if delta > 0:
                self.push(t, FAIL, delta)
            else:
                self.push(t, REPAIR, -delta)

    def add_lease_ticks(self, lease_seconds: float) -> None:
        if lease_seconds <= 0:
            raise ValueError(
                f"lease_seconds must be > 0, got {lease_seconds}")
        k = 1
        while k * lease_seconds <= self.duration:
            self.push(k * lease_seconds, TICK, None)
            k += 1

    # --------------------------------------------------------- dispatch

    def startup(self, ws_initial: int = 0) -> None:
        self._dispatch("startup", 0.0, float(ws_initial),
                       lambda: self.system.startup(0.0,
                                                   ws_initial=ws_initial))

    def _dispatch(self, kind: str, t: float, arg: float,
                  handler: Callable[[], List[Started]]) -> None:
        kills0 = self.system.pbj.kill_count
        shed0 = getattr(self.system, "shed_count", 0)
        starts = handler()
        self.push_starts(starts)
        if self.ledger is not None:
            cl = self.system.cluster
            self.ledger.record(LedgerEntry(
                t=t, kind=kind, arg=arg, started=len(starts),
                killed=self.system.pbj.kill_count - kills0,
                pbj_nodes=_allocated(cl, self.system.pbj.name),
                ws_nodes=_allocated(cl, self.system.ws.name),
                total_nodes=cl.total_allocated,
                shed=getattr(self.system, "shed_count", 0) - shed0))

    def step(self) -> bool:
        """Dispatch the next event. Returns False when the heap is empty
        or every remaining event lies beyond the horizon."""
        if not self._heap or self._past_horizon:
            return False
        t, kind, _, payload = heapq.heappop(self._heap)
        if t > self.duration + _EPS:
            # The heap pops in time order: everything left is later still.
            self._past_horizon = True
            return False
        self.now = t
        sys_ = self.system
        if kind == SUBMIT:
            self._dispatch("submit", t, float(payload.jid),
                           lambda: sys_.submit(t, payload))
        elif kind == FINISH:
            jid, epoch = payload
            self._dispatch("finish", t, float(jid),
                           lambda: sys_.on_finish(t, jid, epoch))
        elif kind == WS:
            self._dispatch("ws", t, float(payload),
                           lambda: sys_.on_ws_demand(t, payload))
        elif kind == TICK:
            self._dispatch("tick", t, -1.0,
                           lambda: sys_.on_lease_tick(t))
        elif kind == FAIL:
            self._dispatch("fail", t, float(payload),
                           lambda: sys_.on_fail(t, payload))
        elif kind == REPAIR:
            self._dispatch("repair", t, float(payload),
                           lambda: sys_.on_repair(t, payload))
        else:                               # CALL — embedder extension
            # Not a provisioning decision: no ledger entry of its own,
            # but anything it starts or pushes flows through the pump
            # (and the ledger) like any other event.
            self.push_starts(payload(t) or [])
        return True

    def run(self) -> None:
        """Drain the heap to the horizon (the simulator's mode)."""
        while self.step():
            pass

    def run_until(self, t_stop: float) -> None:
        """Dispatch every pending event with t <= ``t_stop`` and advance
        the clock to ``t_stop`` (the live bridge's incremental mode)."""
        t_stop = min(t_stop, self.duration)
        while (self._heap and not self._past_horizon
               and self._heap[0][0] <= t_stop + _EPS):
            self.step()
        self.now = max(self.now, t_stop)
