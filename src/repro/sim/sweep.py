"""Parameter-sweep engine — the paper's evaluation methodology at scale.

The headline results of the paper are *sweeps*: Fig. 13 sweeps the
private-cloud capacity C to find the ~40 % configuration-size reduction,
Fig. 14 sweeps the coordinated-pool size B, and Fig. 18 sweeps the lease
time unit L against EC2+RightScale. ``run_sweep`` evaluates a whole grid
of :class:`SweepPoint`s — mixing all four systems — in one call, and
``run_sweep_workloads`` adds a second batch axis over workload traces.

Four execution paths, selected by ``mode``:

  * **Vectorized fast path** (DCS and EC2+RightScale; every mode except
    ``"event"``). Both baselines are *stateless* given the trace —
    DCS is a static partition (its cost/peak curve is closed-form
    arithmetic over the grid) and the EC2 allocation curve is a pure
    function of (submit, runtime, L) evaluated for ALL sweep points at
    once as batched ``jnp`` array ops (``jax.vmap``): the trace's WS
    demand change points are extracted and integrated once
    (``core.profiles``), job release ticks for every lease value are a
    broadcasted rounding to lease boundaries, node-hours is the WS
    integral plus each job's size·(release − submit) span, and peak
    consumption is a cumulative-max over the merged, time-sorted event
    deltas. The arithmetic runs in float64
    (``jax.experimental.enable_x64``) so results agree with the event
    engine to round-off — node-hours match to < 1e-9 relative and every
    integer metric (peak nodes, completed jobs, adjust events) matches
    exactly (tests/test_sweep.py).

  * **Event-round fast path** (PhoenixCloud FB and FLB-NUB; modes
    ``"rounds"`` and ``"auto"`` — the default scan-family mode). The
    coordinated policies are stateful — kills, queue contents and U/V/G
    adjustments feed back into the allocation — so they cannot be
    closed-form; ``repro.sim.rounds`` batches them as a jitted
    ``lax.while_loop`` whose every step jumps straight to the next
    event (submit / completion / WS change / lease boundary) per lane.
    Completions and the allocation integral are *exact*: completed jobs
    match the event engine exactly and node-hours/peak stay within 5 %
    (the residue is first-fit pass convergence and kill tie-breaking,
    not time discretization). ``mode="auto"`` routes FB / FLB-NUB
    points through this engine, except beyond-paper
    ``checkpoint_preempt`` FB points which quietly fall back to the
    event engine (the status-lane kill encoding always restarts from
    scratch).

  * **Batched scan fast path** (PhoenixCloud FB and FLB-NUB; mode
    ``"scan"``). The fixed-``dt`` predecessor of the rounds engine:
    ``repro.sim.scan`` re-expresses both policies as a single jitted
    ``lax.scan`` over a fixed-size job window with status lanes,
    ``vmap``-ed over sweep points AND packed workload traces.
    Approximate by discretization: completed jobs within 2 %,
    node-hours and peak within 15 % of the event engine, parameter-sweep
    orderings (J1/J2 trends) identical (tests/test_sweep.py,
    tests/test_scan_policies.py). Kept as the cross-check of the rounds
    engine and for substep-resolution studies.

  * **Event-engine path** (mode ``"event"``, and the fallback for
    points no fast path accepts). Each point runs through
    ``repro.sim.engine.run_sim`` on its own clone of the trace — the
    per-point reference every fast path is validated against.

The vectorized path replicates the event engine's semantics exactly,
including its tie-breaking: at a shared timestamp, WS demand changes
apply before lease-tick releases, and releases before submits. A job
finishing precisely on a tick boundary is therefore released one full
lease later (the tick event sorts before the finish event).
"""

from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import enable_x64

from repro import compat
from repro.core.jobs import Job
from repro.core.pbj_manager import PBJPolicyParams
from repro.core.profiles import step_integral, step_points
from repro.sim import rounds as roundslib
from repro.sim import scan as scanlib
from repro.sim.engine import (_SUBMIT, _TICK, _WS, SYSTEMS, build_dcs,
                              build_ec2_rightscale, build_fb, build_flb_nub,
                              clone_jobs, default_duration, run_sim)

__all__ = ["SweepPoint", "ScanOptions", "run_sweep", "run_sweep_workloads",
           "paper_grid"]

MODES = ("auto", "event", "scan", "rounds")

# Systems with a stateless closed-form fast path vs the stateful
# coordinated policies that take the batched scan/rounds paths.
_VECTORIZED = ("dcs", "ec2")
_SCANNABLE = ("fb", "flb_nub")


@dataclasses.dataclass(frozen=True)
class SweepPoint:
    """One (system, parameter) point of a sweep grid.

    ``system`` selects the provisioning system; the remaining fields are
    that system's knobs (unused ones are ignored): ``capacity`` is the
    Fig.-13 sweep variable C, ``lb_pbj + lb_ws`` the Fig.-14 pool size
    B, and ``lease_seconds`` the Fig.-18 lease unit L.
    """

    system: str                       # "dcs" | "fb" | "flb_nub" | "ec2"
    prc_pbj: int = 0                  # dcs: static PBJ partition
    prc_ws: int = 0                   # dcs: static WS partition
    capacity: int = 0                 # fb: private-cloud capacity C
    lb_pbj: int = 0                   # flb_nub: PBJ lower bound
    lb_ws: int = 0                    # flb_nub: WS lower bound
    lease_seconds: float = 3600.0     # all: lease time unit L
    params: PBJPolicyParams = PBJPolicyParams()
    label: str = ""

    def __post_init__(self):
        if self.system not in SYSTEMS:
            raise ValueError(
                f"unknown system {self.system!r}; expected one of "
                f"{sorted(SYSTEMS)}")
        if self.lease_seconds <= 0:
            raise ValueError(
                f"lease_seconds must be > 0, got {self.lease_seconds}")

    def name(self) -> str:
        if self.label:
            return self.label
        return {
            "dcs": f"DCS({self.prc_pbj}+{self.prc_ws})",
            "fb": f"FB(C={self.capacity})",
            "flb_nub": f"FLB-NUB(B={self.lb_pbj + self.lb_ws})",
            "ec2": f"EC2+RightScale(L={self.lease_seconds:g}s)",
        }[self.system]


@dataclasses.dataclass(frozen=True)
class ScanOptions:
    """Tuning knobs of the batched fast paths (``mode="scan"`` and
    ``mode="rounds"``, see ``repro.sim.scan`` / ``repro.sim.rounds``).
    The defaults are the settings the fidelity contracts are validated
    at; ``dt=None`` picks each policy's validated substep
    (``scanlib.pick_dt`` — FB coarse, FLB-NUB fine), capped by the
    grid's shortest lease and, for FLB-NUB, by the workloads' WS
    change-point spacing. The rounds engine has no substep — ``dt`` and
    ``chunk_len`` only affect ``mode="scan"``. ``ff_passes=None`` takes
    the engines' shared default (2 filtered-prefix passes; the rounds
    coalescer's drain instants are exact-or-deferred regardless).
    ``coalesce`` is the rounds engine's contended-stretch batch — up to
    that many queued-period completions (plus the arrivals riding the
    same stretch) fold into one event round, each replayed at its
    exact instant; ``repro.sim.rounds.COALESCE_BATCH`` (8) is the
    recommended opt-in value, 1 (the default) leaves one round per
    contended completion — on CPU hosts the coalescer's fixed per-
    round vector work measurably outweighs the rounds it saves, see
    the rounds module docstring. The scan path ignores it. ``kernel``
    selects the rounds engine's round-step backend: ``"xla"`` (the safe
    default) dispatches the traced body op by op, ``"pallas"`` fuses the
    whole outer step — compaction, admission and the unrolled rounds —
    into one Pallas kernel per lane (``repro.kernels.round_step``;
    interpret mode auto-selected off-TPU), bit-identical rows either
    way. The scan path ignores it. ``devices`` selects the execution
    backend (``repro.compat.resolve_devices``): ``None`` runs the whole
    grid on one device, a count or device sequence shards the
    (point × trace) lanes across host devices via ``shard_map``."""

    dt: Optional[float] = None
    window: Optional[int] = None
    chunk_len: Optional[int] = None
    ff_passes: Optional[int] = None
    coalesce: Optional[int] = None
    dtype: Optional[np.dtype] = None
    devices: compat.Devices = None
    kernel: str = "xla"

    def resolve(self, policy: str, leases: Sequence[float],
                duration: float,
                ws_traces: Optional[Sequence[Sequence[Tuple[float, int]]]]
                = None) -> scanlib.ScanSpec:
        dt = self.dt if self.dt is not None else scanlib.pick_dt(
            policy, leases, ws_traces, duration)
        window = (self.window if self.window is not None else
                  (scanlib.FB_WINDOW if policy == "fb"
                   else scanlib.FLB_WINDOW))
        # Re-gather cadence: FB's window turns over slowly (its backlog
        # is bounded by C), FLB-NUB's buffers arrival bursts.
        chunk_seconds = 3600.0 if policy == "fb" else 1800.0
        chunk = (self.chunk_len if self.chunk_len is not None
                 else max(2, int(round(chunk_seconds / dt))))
        ff = (self.ff_passes if self.ff_passes is not None
              else scanlib.DEFAULT_FF_PASSES)
        return scanlib.ScanSpec(
            n_steps=int(np.ceil(duration / dt)), dt=dt, window=window,
            chunk_len=chunk, ff_passes=ff)

    def resolve_rounds(self, policy: str, leases: Sequence[float],
                       duration: float, max_jobs: int,
                       n_ws: int) -> roundslib.RoundsSpec:
        window = (self.window if self.window is not None else
                  (roundslib.FB_ROUNDS_WINDOW if policy == "fb"
                   else roundslib.FLB_ROUNDS_WINDOW))
        ff = (self.ff_passes if self.ff_passes is not None
              else roundslib.ROUNDS_FF_PASSES)
        batch = (self.coalesce if self.coalesce is not None
                 else roundslib.DEFAULT_BATCH)
        if batch < 1:
            raise ValueError(f"coalesce batch must be >= 1, got {batch}")
        return roundslib.RoundsSpec(
            duration=duration,
            max_rounds=roundslib.round_budget(max_jobs, n_ws, duration,
                                              min(leases)),
            window=window, ff_passes=ff, batch=batch,
            kernel=self.kernel)


def _build(p: SweepPoint):
    if p.system == "dcs":
        return build_dcs(p.prc_pbj, p.prc_ws, p.lease_seconds)
    if p.system == "fb":
        return build_fb(p.capacity, p.lease_seconds, p.params)
    if p.system == "flb_nub":
        return build_flb_nub(p.lb_pbj, p.lb_ws, p.lease_seconds, p.params)
    if p.system == "ec2":
        return build_ec2_rightscale(p.lease_seconds)
    raise ValueError(f"unknown system {p.system!r}")


# ------------------------------------------------------- vectorized baselines

def _sweep_dcs(points: List[SweepPoint], duration: float) -> List[Dict]:
    """All DCS points at once: the partition is static, so the cost curve
    is an affine function of the configuration size.

    Vectorized DCS rows carry the cost/peak metrics only — job metrics
    (completed jobs, turnaround) depend on the first-fit queue dynamics
    and need the event engine (``run_sweep(..., mode="event")``).
    """
    rows = []
    for p in points:
        size = p.prc_pbj + p.prc_ws
        rows.append({
            "system": p.name(), "system_kind": "dcs", "engine": "vectorized",
            "lease_seconds": p.lease_seconds,
            "node_hours": size * duration / 3600.0,
            "peak_nodes": size,
            "adjust_events": int(p.prc_ws > 0) + int(p.prc_pbj > 0),
            "pbj_adjust_events": int(p.prc_pbj > 0),
            "kills": 0,
        })
    return rows


def _sweep_ec2(points: List[SweepPoint], jobs: Sequence[Job],
               ws_trace: Sequence[Tuple[float, int]],
               duration: float) -> List[Dict]:
    """All EC2+RightScale points (one per lease value) as batched jnp ops.

    Per job j and lease L: the job allocates ``size_j`` on
    ``[submit_j, rel_j)`` where ``rel_j`` is the first lease tick
    *strictly after* its completion (§6.6.2 whole-hour billing plus the
    engine's tick-before-finish tie order), clipped to the trace
    duration when the tick never fires. The WS curve replays the demand
    trace verbatim and is lease-independent.
    """
    ws_t64, ws_v64 = step_points(ws_trace, duration)
    ws_node_seconds = step_integral(ws_t64, ws_v64, duration)
    ws_deltas64 = np.concatenate([ws_v64[:1], np.diff(ws_v64)])
    ws_adjusts = int(np.count_nonzero(ws_deltas64))

    with enable_x64():
        submit = jnp.asarray([j.submit for j in jobs], jnp.float64)
        size = jnp.asarray([j.size for j in jobs], jnp.float64)
        runtime = jnp.asarray([j.runtime for j in jobs], jnp.float64)
        end = submit + runtime
        in_trace = submit <= duration + 1e-9     # engine drops later submits
        finishes = in_trace & (end <= duration + 1e-9)

        L = jnp.asarray([p.lease_seconds for p in points],
                        jnp.float64)[:, None]                  # (P, 1)
        # First tick strictly after the finish event (see module doc).
        # A tick exists only while k·L <= duration — the engine's strict
        # scheduling comparison, mirrored here without tolerance.
        rel = (jnp.floor(end / L) + 1.0) * L                   # (P, J)
        fired = in_trace & (rel <= duration)
        rel_eff = jnp.where(fired, rel, duration)
        pbj_ns = jnp.sum(jnp.where(in_trace, size * (rel_eff - submit), 0.0),
                         axis=1)
        node_hours = (pbj_ns + ws_node_seconds) / 3600.0

        # Peak: merge WS steps, submits (+size) and releases (−size) and
        # take the cumulative max of the running total. Tie order at one
        # timestamp follows the engine's event kinds (releases happen
        # inside tick events).
        ws_t, ws_d = jnp.asarray(ws_t64), jnp.asarray(ws_deltas64)
        n_ws, n_j = ws_t.shape[0], submit.shape[0]
        ev_t = jnp.concatenate([ws_t, submit, jnp.zeros(n_j)])  # rel filled per point
        ev_kind = jnp.concatenate([jnp.full(n_ws, float(_WS)),
                                   jnp.full(n_j, float(_SUBMIT)),
                                   jnp.full(n_j, float(_TICK))])
        base_delta = jnp.concatenate(
            [ws_d, jnp.where(in_trace, size, 0.0), jnp.zeros(n_j)])

        def peak_one(rel_row, fired_row):
            t = ev_t.at[n_ws + n_j:].set(rel_row)
            delta = base_delta.at[n_ws + n_j:].set(
                jnp.where(fired_row, -size, 0.0))
            order = jnp.lexsort((ev_kind, t))
            running = jnp.cumsum(delta[order])
            return jnp.maximum(jnp.max(running), 0.0)

        peak = jax.vmap(peak_one)(rel, fired)

        completed = jnp.sum(finishes)
        sum_rt = jnp.sum(jnp.where(finishes, runtime, 0.0))
        n_released = jnp.sum(fired, axis=1)
        n_submitted = jnp.sum(in_trace)

    n_completed = int(completed)
    avg_rt = float(sum_rt) / n_completed if n_completed else 0.0
    rows = []
    for i, p in enumerate(points):
        pbj_adjusts = int(n_submitted) + int(n_released[i])
        rows.append({
            "system": p.name(), "system_kind": "ec2", "engine": "vectorized",
            "lease_seconds": p.lease_seconds,
            "node_hours": float(node_hours[i]),
            "peak_nodes": int(round(float(peak[i]))),
            "completed_jobs": n_completed,
            "avg_turnaround": avg_rt,        # EC2 never queues (§6.6.1)
            "avg_execution": avg_rt,
            "adjust_events": pbj_adjusts + ws_adjusts,
            "pbj_adjust_events": pbj_adjusts,
            "kills": 0,
        })
    return rows


# ------------------------------------------------ batched scan/rounds paths

def _reject_preempt(points: List[SweepPoint], mode: str) -> None:
    for p in points:
        # The status-lane kill encoding resets a killed lane to its full
        # runtime (repro.sim.scan / repro.sim.rounds); the beyond-paper
        # checkpoint-preempt mode only exists on the event engine — fail
        # loudly rather than silently report full-restart metrics for a
        # preemption study. The guard is FB-only on purpose: FLB-NUB
        # never force-releases (§5.2 satisfies WS elastically and only
        # ever releases *free* nodes), so it has no kills for the
        # preemption mode to change —
        # tests/test_scan_policies.py::test_flb_nub_never_kills pins
        # that invariant, making the exemption safe.
        if p.system == "fb" and p.params.checkpoint_preempt:
            raise ValueError(
                f"{p.name()}: checkpoint_preempt is not supported by "
                f"mode=\"{mode}\"; run this point with mode=\"auto\" or "
                f"mode=\"event\"")


def _fb_grid(points: List[SweepPoint], idxs: List[int],
             f) -> scanlib.FBGrid:
    return scanlib.FBGrid(
        capacity=jnp.asarray([float(points[i].capacity) for i in idxs], f),
        lease=jnp.asarray([points[i].lease_seconds for i in idxs], f))


def _flb_grid(points: List[SweepPoint], idxs: List[int],
              f) -> scanlib.FLBGrid:
    return scanlib.FLBGrid(
        B=jnp.asarray([float(points[i].lb_pbj + points[i].lb_ws)
                       for i in idxs], f),
        lb_ws=jnp.asarray([float(points[i].lb_ws) for i in idxs], f),
        U=jnp.asarray([points[i].params.request_threshold
                       for i in idxs], f),
        V=jnp.asarray([points[i].params.release_threshold
                       for i in idxs], f),
        G=jnp.asarray([points[i].params.elastic_factor for i in idxs], f),
        lease=jnp.asarray([points[i].lease_seconds for i in idxs], f))


_DIAG_KEYS = ("window_overflow", "truncated", "rounds", "coalesced")


def _assemble_rows(points: List[SweepPoint], fb_idx: List[int],
                   flb_idx: List[int], out: Dict, n_workloads: int,
                   engine: str) -> List[List[Dict]]:
    """Metric arrays → one row list per workload, aligned with
    ``points``; diagnostics (window overflow, round truncation) ride
    along per row so callers can see them."""
    per_workload: List[List[Dict]] = []
    for w in range(n_workloads):
        rows: List[Optional[Dict]] = [None] * len(points)
        for kind, idxs in (("fb", fb_idx), ("flb_nub", flb_idx)):
            for j, i in enumerate(idxs):
                m = {k: v[w][j] for k, v in out[kind].items()}
                p = points[i]
                rows[i] = {
                    "system": p.name(), "system_kind": p.system,
                    "engine": engine, "lease_seconds": p.lease_seconds,
                    "completed_jobs": int(round(float(m["completed_jobs"]))),
                    "avg_turnaround": float(m["avg_turnaround"]),
                    "avg_execution": float(m["avg_execution"]),
                    "node_hours": float(m["node_hours"]),
                    "peak_nodes": int(round(float(m["peak_nodes"]))),
                    "adjust_events": int(round(float(m["adjust_events"]))),
                    "pbj_adjust_events": int(round(float(
                        m["pbj_adjust_events"]))),
                    "kills": int(round(float(m["kills"]))),
                    "window_overflow": int(round(float(
                        m["window_overflow"]))),
                }
                for k in _DIAG_KEYS[1:]:
                    if k in m:
                        rows[i][k] = int(round(float(m[k])))
        per_workload.append(rows)                 # type: ignore[arg-type]
    return per_workload                           # type: ignore[return-value]


def _warn_diagnostics(per_workload: List[List[Dict]], engine: str,
                      stacklevel: int = 3) -> None:
    """Surface lane diagnostics: a backlog that outgrew the job window
    (results silently degrade — jobs start late or never) or a lane
    that exhausted its round budget. Callers also get both per row.

    ``stacklevel`` must resolve to the frame OUTSIDE the sweep library —
    the entry points thread the extra wrapper depth through
    ``warn_stacklevel`` / ``_stack_offset`` so ``-W error`` reports and
    warning filters name the caller's file, not this module."""
    overflowed = [r["system"] for rows in per_workload for r in rows
                  if r is not None and r.get("window_overflow", 0) > 0]
    if overflowed:
        warnings.warn(
            f"{engine} sweep: job backlog outgrew the lane window on "
            f"{len(overflowed)} row(s) ({', '.join(sorted(set(overflowed)))}"
            f"); metrics under-report queued work — raise "
            f"ScanOptions.window", RuntimeWarning, stacklevel=stacklevel)
    truncated = [r["system"] for rows in per_workload for r in rows
                 if r is not None and r.get("truncated", 0) > 0]
    if truncated:
        warnings.warn(
            f"{engine} sweep: round budget exhausted before the horizon "
            f"on {len(truncated)} row(s) "
            f"({', '.join(sorted(set(truncated)))})", RuntimeWarning,
            stacklevel=stacklevel)


def _pack_scan(points: List[SweepPoint],
               workloads: Sequence[Tuple[Sequence[Job],
                                         Sequence[Tuple[float, int]]]],
               duration: float, options: ScanOptions):
    """Host-side setup stage of the scan path: trace packing + grid
    construction. Factored out of :func:`_sweep_scan` so
    ``benchmarks/run.py`` can time setup separately from compile/run
    (the ``setup_s`` ledger column)."""
    fb_idx = [i for i, p in enumerate(points) if p.system == "fb"]
    flb_idx = [i for i, p in enumerate(points) if p.system == "flb_nub"]
    ws_traces = [ws for _, ws in workloads]

    fb = flb = fb_packed = flb_packed = fb_spec = flb_spec = None
    if fb_idx:
        fb_spec = options.resolve(
            "fb", [points[i].lease_seconds for i in fb_idx], duration)
        fb_packed, _ = scanlib.pack_workloads(
            workloads, duration, fb_spec.dt, window=fb_spec.window,
            chunk_len=fb_spec.chunk_len, dtype=options.dtype)
        fb = _fb_grid(points, fb_idx, fb_packed.ws.dtype)
    if flb_idx:
        flb_spec = options.resolve(
            "flb_nub", [points[i].lease_seconds for i in flb_idx], duration,
            ws_traces=ws_traces)
        flb_packed, _ = scanlib.pack_workloads(
            workloads, duration, flb_spec.dt, window=flb_spec.window,
            chunk_len=flb_spec.chunk_len, dtype=options.dtype)
        flb = _flb_grid(points, flb_idx, flb_packed.ws.dtype)
    return fb_idx, flb_idx, fb, flb, fb_packed, flb_packed, fb_spec, flb_spec


def _sweep_scan(points: List[SweepPoint],
                workloads: Sequence[Tuple[Sequence[Job],
                                          Sequence[Tuple[float, int]]]],
                duration: float,
                options: ScanOptions,
                warn_stacklevel: int = 3) -> List[List[Dict]]:
    """FB and FLB-NUB points through the batched ``lax.scan`` fast path.

    Returns one row list per workload, each aligned with ``points``
    (which must all be scan-eligible systems). The whole
    (policy, point, workload) grid is one jitted XLA program.
    """
    assert all(p.system in _SCANNABLE for p in points)
    _reject_preempt(points, "scan")
    (fb_idx, flb_idx, fb, flb, fb_packed, flb_packed,
     fb_spec, flb_spec) = _pack_scan(points, workloads, duration, options)

    out = scanlib.scan_grids(fb, flb, fb_packed, flb_packed,
                             fb_spec=fb_spec, flb_spec=flb_spec,
                             devices=options.devices)
    out = jax.tree_util.tree_map(np.asarray, out)
    rows = _assemble_rows(points, fb_idx, flb_idx, out, len(workloads),
                          "scan")
    _warn_diagnostics(rows, "scan", stacklevel=warn_stacklevel)
    return rows


def _pack_rounds(points: List[SweepPoint],
                 workloads: Sequence[Tuple[Sequence[Job],
                                           Sequence[Tuple[float, int]]]],
                 duration: float, options: ScanOptions):
    """Host-side setup stage of the rounds path: event packing + fold
    tables + grid construction (see :func:`_pack_scan`)."""
    fb_idx = [i for i, p in enumerate(points) if p.system == "fb"]
    flb_idx = [i for i, p in enumerate(points) if p.system == "flb_nub"]
    max_jobs = max(len(jobs) for jobs, _ in workloads)
    n_ws = max(len(ws) for _, ws in workloads)

    fb = flb = fb_packs = flb_packs = fb_spec = flb_spec = None
    if fb_idx:
        leases = [points[i].lease_seconds for i in fb_idx]
        fb_spec = options.resolve_rounds("fb", leases, duration,
                                         max_jobs, n_ws)
        fb_packs = roundslib.pack_event_workloads(
            workloads, duration, fb_spec.window, "fb", leases,
            [float(points[i].capacity) for i in fb_idx],
            dtype=options.dtype, split=True)
        fb = _fb_grid(points, fb_idx, fb_packs[0].submit.dtype)
    if flb_idx:
        leases = [points[i].lease_seconds for i in flb_idx]
        flb_spec = options.resolve_rounds("flb_nub", leases, duration,
                                          max_jobs, n_ws)
        flb_packs = roundslib.pack_event_workloads(
            workloads, duration, flb_spec.window, "flb_nub", leases,
            [float(points[i].lb_ws) for i in flb_idx],
            dtype=options.dtype, split=True)
        flb = _flb_grid(points, flb_idx, flb_packs[0].submit.dtype)
    return fb_idx, flb_idx, fb, flb, fb_packs, flb_packs, fb_spec, flb_spec


def _sweep_rounds(points: List[SweepPoint],
                  workloads: Sequence[Tuple[Sequence[Job],
                                            Sequence[Tuple[float, int]]]],
                  duration: float,
                  options: ScanOptions,
                  warn_stacklevel: int = 3) -> List[List[Dict]]:
    """FB and FLB-NUB points through the event-round fast path
    (``repro.sim.rounds``): adaptive jump-to-next-event steps with
    exact completions, batched over sweep points like the scan.

    Workload traces run as *separate* invocations of the same compiled
    program (the packs share one shape, so there is exactly one compile
    per policy): unlike the scan's fixed grid, event-round lane lengths
    differ per trace, and one big batch would run every lane to the
    slowest lane's round count while blowing the cache footprint —
    splitting the trace axis is measurably faster than vmapping it.
    With ``devices`` set, each invocation shards its (point) lanes
    across the devices.
    """
    assert all(p.system in _SCANNABLE for p in points)
    _reject_preempt(points, "rounds")
    (fb_idx, flb_idx, fb, flb, fb_packs, flb_packs,
     fb_spec, flb_spec) = _pack_rounds(points, workloads, duration, options)

    outs = [roundslib.rounds_grids(
        fb, flb,
        fb_packs[w] if fb_packs is not None else None,
        flb_packs[w] if flb_packs is not None else None,
        fb_spec=fb_spec, flb_spec=flb_spec, devices=options.devices)
        for w in range(len(workloads))]
    outs = jax.tree_util.tree_map(np.asarray, outs)
    out = {kind: {k: np.concatenate([o[kind][k] for o in outs])
                  for k in outs[0][kind]}
           for kind in outs[0]}
    rows = _assemble_rows(points, fb_idx, flb_idx, out, len(workloads),
                          "rounds")
    _warn_diagnostics(rows, "rounds", stacklevel=warn_stacklevel)
    return rows


def _pack_scenarios_grids(points: List[SweepPoint], grid,
                          synth, options: ScanOptions):
    """Setup stage of the generated-scenario path: one
    :func:`repro.sim.scenarios.pack_scenarios` per policy (job tables,
    rise compression and the batched (W, P) fold tables are all array
    ops — no per-lane host loop)."""
    from repro.sim import scenarios as scenarioslib
    fb_idx = [i for i, p in enumerate(points) if p.system == "fb"]
    flb_idx = [i for i, p in enumerate(points) if p.system == "flb_nub"]
    duration = float(grid.duration)
    changes = synth.ws_values[:, 1:] != synth.ws_values[:, :-1]
    n_ws = int(changes.sum(axis=1).max()) + 1

    fb = flb = fb_packed = flb_packed = fb_spec = flb_spec = None
    if fb_idx:
        leases = [points[i].lease_seconds for i in fb_idx]
        fb_spec = options.resolve_rounds("fb", leases, duration,
                                         grid.max_jobs, n_ws)
        fb_packed = scenarioslib.pack_scenarios(
            synth, fb_spec.window, "fb", leases,
            [float(points[i].capacity) for i in fb_idx],
            dtype=options.dtype)
        fb = _fb_grid(points, fb_idx, fb_packed.submit.dtype)
    if flb_idx:
        leases = [points[i].lease_seconds for i in flb_idx]
        flb_spec = options.resolve_rounds("flb_nub", leases, duration,
                                          grid.max_jobs, n_ws)
        flb_packed = scenarioslib.pack_scenarios(
            synth, flb_spec.window, "flb_nub", leases,
            [float(points[i].lb_ws) for i in flb_idx],
            dtype=options.dtype)
        flb = _flb_grid(points, flb_idx, flb_packed.submit.dtype)
    return (fb_idx, flb_idx, fb, flb, fb_packed, flb_packed, fb_spec,
            flb_spec)


def _sweep_rounds_generated(points: List[SweepPoint], grid,
                            options: ScanOptions,
                            synth=None,
                            warn_stacklevel: int = 3) -> List[List[Dict]]:
    """FB / FLB-NUB points over a generated scenario batch
    (:class:`repro.sim.scenarios.ScenarioGrid`) through the event-round
    engine. Unlike :func:`_sweep_rounds`'s per-trace invocations (2-3
    hand-built traces with wildly different event densities), generated
    lanes share one dense WS grid and one job-table height, so the
    whole (W × P) batch runs as ONE program — nested vmap on a single
    device, ``sharded_grid_map`` across ``options.devices``.
    """
    from repro.sim import scenarios as scenarioslib
    assert all(p.system in _SCANNABLE for p in points)
    _reject_preempt(points, "rounds")
    if synth is None:
        synth = scenarioslib.synthesize(grid)
    (fb_idx, flb_idx, fb, flb, fb_packed, flb_packed, fb_spec,
     flb_spec) = _pack_scenarios_grids(points, grid, synth, options)
    out = roundslib.rounds_grids(fb, flb, fb_packed, flb_packed,
                                 fb_spec=fb_spec, flb_spec=flb_spec,
                                 devices=options.devices)
    out = jax.tree_util.tree_map(np.asarray, out)
    rows = _assemble_rows(points, fb_idx, flb_idx, out, grid.n_lanes,
                          "rounds")
    _warn_diagnostics(rows, "rounds", stacklevel=warn_stacklevel)
    return rows


# --------------------------------------------------------------- the sweep

def _resolve_mode(mode: Optional[str], vectorize: bool) -> str:
    if mode is None:
        return "auto" if vectorize else "event"
    if mode not in MODES:
        raise ValueError(f"unknown mode {mode!r}; expected one of {MODES}")
    return mode


def run_sweep(points: Sequence[SweepPoint], jobs: Sequence[Job],
              ws_trace: Sequence[Tuple[float, int]],
              duration: Optional[float] = None,
              vectorize: bool = True,
              mode: Optional[str] = None,
              scan_options: ScanOptions = ScanOptions(),
              devices: compat.Devices = None) -> List[Dict]:
    """Evaluate every sweep point on the same (jobs, ws_trace) workload.

    Returns one row dict per point, in input order, each tagged with
    ``engine`` = ``"vectorized"`` (exact batched jnp fast path),
    ``"rounds"`` (event-round fast path for FB / FLB-NUB),
    ``"scan"`` (fixed-dt lax.scan fast path, mode ``"scan"`` only) or
    ``"event"`` (per-point discrete-event run).

    ``mode`` selects the execution paths (see module docstring):
    ``"auto"`` (default) vectorizes DCS/EC2 and batches FB / FLB-NUB
    through the event-round engine (``repro.sim.rounds`` — completed
    jobs exact, node-hours/peak within 5 %), falling back to the event
    engine for points the fast path rejects (FB with
    ``checkpoint_preempt``); ``"rounds"`` is the same but *fails* on
    such points; ``"scan"`` batches FB / FLB-NUB through the fixed-dt
    ``repro.sim.scan`` instead; ``"event"`` runs everything on the
    event engine — the cross-validation reference used by
    tests/test_sweep.py. The legacy ``vectorize=False`` flag is
    equivalent to ``mode="event"``.

    ``devices`` (shorthand for ``scan_options.devices``) shards the
    fast path's (point × trace) lanes across that many host devices —
    see :class:`ScanOptions`. It affects modes ``"auto"``, ``"scan"``
    and ``"rounds"``.

    Vectorized DCS rows carry cost/peak metrics only (use ``.get`` or
    ``mode="event"`` when job metrics are needed for a DCS point);
    scan/rounds rows carry the full metric set plus lane diagnostics
    (``window_overflow``, and ``truncated`` for rounds) — a nonzero
    diagnostic also raises a ``RuntimeWarning``.
    """
    return run_sweep_workloads(points, [(jobs, ws_trace)], duration,
                               vectorize=vectorize, mode=mode,
                               scan_options=scan_options,
                               devices=devices, _stack_offset=1)[0]


def run_sweep_workloads(points: Sequence[SweepPoint],
                        workloads: Sequence[Tuple[Sequence[Job],
                                                  Sequence[Tuple[float, int]]]],
                        duration: Optional[float] = None,
                        vectorize: bool = True,
                        mode: Optional[str] = None,
                        scan_options: ScanOptions = ScanOptions(),
                        devices: compat.Devices = None,
                        _stack_offset: int = 0
                        ) -> List[List[Dict]]:
    """Evaluate a sweep grid over SEVERAL workload traces at once.

    Returns ``rows[w][i]`` — one row list per workload, aligned with
    ``points``. In the batched modes the FB / FLB-NUB points of ALL
    workloads batch through a single jitted program (the trace axis is
    a second ``vmap`` axis); DCS / EC2 points run the exact vectorized
    path per workload, and the event fallback runs per (point, workload)
    pair. All workloads share one measurement horizon ``duration``
    (§6.1) — the default is the latest horizon any workload implies.
    ``devices`` overrides ``scan_options.devices`` (see
    :class:`ScanOptions`).

    ``workloads`` may instead be a
    :class:`repro.sim.scenarios.ScenarioGrid` — a generated scenario
    batch (per-lane PRNG seeds + parameter grids). The lanes then
    synthesize on device, pack as ONE batch and run the event-round
    engine as a single (W × P) program (sharded across
    ``devices`` when set); only FB / FLB-NUB points are supported and
    the grid fixes the horizon (``duration`` must stay ``None``).

    ``_stack_offset`` (private) is the number of wrapper frames between
    the user's call site and this function; diagnostic
    ``RuntimeWarning``\\ s use it to attribute the warning to the
    caller's file instead of the sweep internals. Wrappers that forward
    here (``run_sweep``, ``warmup_sweep``, the capacity query layer)
    each add their own frame count.
    """
    mode = _resolve_mode(mode, vectorize)
    # warnings.warn stack depth from inside _warn_diagnostics:
    # 1 = _warn_diagnostics, 2 = _sweep_*, 3 = this function,
    # 4 = our caller — plus any wrapper frames above us.
    warn_stacklevel = 4 + _stack_offset
    if devices is not None:
        scan_options = dataclasses.replace(scan_options, devices=devices)
    from repro.sim import scenarios as scenarioslib
    if isinstance(workloads, scenarioslib.ScenarioGrid):
        # Generated scenario batches (keys + param grids, not
        # List[Job]) flow the event-round engine only: the lanes share
        # one dense WS grid and job-table height, so the whole (W × P)
        # batch is one program. The grid carries its own horizon.
        if mode not in ("auto", "rounds"):
            raise ValueError(
                f"generated scenario batches run the rounds engine only "
                f"(mode 'auto'/'rounds', got {mode!r})")
        if duration is not None and duration != workloads.duration:
            raise ValueError(
                "duration is fixed by ScenarioGrid.duration — pass None")
        bad = sorted({p.system for p in points
                      if p.system not in _SCANNABLE})
        if bad:
            raise ValueError(
                f"generated scenario batches support FB / FLB-NUB points "
                f"only, got {bad}; evaluate DCS/EC2 baselines on "
                f"sampled lanes (repro.sim.scenarios.sample_workloads)")
        return _sweep_rounds_generated(list(points), workloads,
                                       scan_options,
                                       warn_stacklevel=warn_stacklevel)
    if duration is None:
        duration = max(default_duration(jobs, ws) for jobs, ws in workloads)
    rows: List[List[Optional[Dict]]] = [
        [None] * len(points) for _ in workloads]

    if mode != "event":
        dcs_idx = [i for i, p in enumerate(points) if p.system == "dcs"]
        ec2_idx = [i for i, p in enumerate(points) if p.system == "ec2"]
        for w, (jobs, ws_trace) in enumerate(workloads):
            if dcs_idx:
                for i, row in zip(dcs_idx,
                                  _sweep_dcs([points[i] for i in dcs_idx],
                                             duration)):
                    rows[w][i] = row
            if ec2_idx:
                for i, row in zip(ec2_idx,
                                  _sweep_ec2([points[i] for i in ec2_idx],
                                             jobs, ws_trace, duration)):
                    rows[w][i] = row

    if mode in ("auto", "scan", "rounds"):
        batch_idx = [i for i, p in enumerate(points)
                     if p.system in _SCANNABLE]
        if mode == "auto":
            # The event-round engine is the default scan-family mode;
            # points it rejects (FB checkpoint_preempt) quietly take
            # the per-point event path below instead of failing.
            batch_idx = [i for i in batch_idx
                         if not (points[i].system == "fb"
                                 and points[i].params.checkpoint_preempt)]
        fast = _sweep_scan if mode == "scan" else _sweep_rounds
        if batch_idx:
            fast_rows = fast([points[i] for i in batch_idx],
                             workloads, duration, scan_options,
                             warn_stacklevel=warn_stacklevel)
            for w in range(len(workloads)):
                for j, i in enumerate(batch_idx):
                    rows[w][i] = fast_rows[w][j]

    for w, (jobs, ws_trace) in enumerate(workloads):
        for i, p in enumerate(points):
            if rows[w][i] is not None:
                continue
            r = run_sim(_build(p), clone_jobs(jobs), ws_trace, duration,
                        name=p.name())
            row = r.row()
            row.update(system_kind=p.system, engine="event",
                       lease_seconds=p.lease_seconds)
            rows[w][i] = row
    return rows                                   # type: ignore[return-value]


def warmup_sweep(points: Sequence[SweepPoint],
                 workloads: Sequence[Tuple[Sequence[Job],
                                           Sequence[Tuple[float, int]]]],
                 duration: Optional[float] = None, *, mode: str = "rounds",
                 scan_options: ScanOptions = ScanOptions(),
                 devices: compat.Devices = None) -> float:
    """Prime every jit cache one (grid, workloads, mode, options)
    configuration touches and return the priming call's wall seconds —
    the compile cost the steady-state path then never pays again.

    The fast paths' programs are cached on ``(policy, spec)`` keys that
    include the rounds ``kernel`` backend and, for the sharded backend,
    the device mesh (``rounds._rounds_lane`` / ``scan._sharded_lanes``),
    so warming one configuration never evicts or aliases another. The
    helper is ``jax.clear_caches()``-safe: nothing is memoized on wall
    time or call order, so after a cache clear the next call simply
    recompiles and re-primes — callers that need a cold-compile
    measurement (``benchmarks/run.py sweep``'s ``compile_s`` column)
    call ``jax.clear_caches()`` first and take this helper's return
    value; live paths call it once at startup and pay ~0 afterwards.
    """
    t0 = time.time()
    run_sweep_workloads(points, workloads, duration, mode=mode,
                        scan_options=scan_options, devices=devices,
                        _stack_offset=1)
    return time.time() - t0


# ------------------------------------------------------------- paper grids

def paper_grid(prc_pbj: int, prc_ws: int = 128,
               capacity_fracs: Sequence[float] = (0.5, 0.6, 0.75, 0.9, 1.0),
               B_values: Sequence[int] = (13, 25, 51, 102, 154),
               lease_minutes: Sequence[int] = (15, 30, 60, 120, 240),
               fig18_B: int = 25, lb_ws: int = 12,
               params: PBJPolicyParams = PBJPolicyParams()
               ) -> List[SweepPoint]:
    """The Fig. 13 + Fig. 14 + Fig. 18 grids as one sweep (21 points).

    Fig. 13: FB capacity C as a fraction of the DCS configuration size
    (plus the DCS reference). Fig. 14: FLB-NUB pool size B. Fig. 18:
    lease unit L for both FLB-NUB and the EC2+RightScale baseline.
    """
    dcs_size = prc_pbj + prc_ws
    pts = [SweepPoint("dcs", prc_pbj=prc_pbj, prc_ws=prc_ws,
                      label=f"DCS({dcs_size})")]
    for f in capacity_fracs:
        c = int(round(dcs_size * f))
        pts.append(SweepPoint("fb", capacity=c, params=params,
                              label=f"FB(C={c})"))
    for B in B_values:
        w = min(lb_ws, B - 1)
        pts.append(SweepPoint("flb_nub", lb_pbj=B - w, lb_ws=w,
                              params=params, label=f"FLB-NUB(B={B})"))
    for m in lease_minutes:
        w = min(lb_ws, fig18_B - 1)
        pts.append(SweepPoint("flb_nub", lb_pbj=fig18_B - w, lb_ws=w,
                              lease_seconds=60.0 * m, params=params,
                              label=f"FLB-NUB(L={m}min)"))
        pts.append(SweepPoint("ec2", lease_seconds=60.0 * m,
                              label=f"EC2(L={m}min)"))
    return pts
