"""Deterministic fault-injection schedules — the chaos tier's input.

A :class:`FaultSchedule` is a fixed-shape FAIL/REPAIR event table:
sorted times plus signed node deltas (+k = k nodes fail at t, -k =
k nodes repaired). Schedules are generated **up front** from a PRNG key
(``np.random.PCG64``), never sampled during simulation, so the same
schedule replays bit-identically through all three execution paths:

* the event engine (``run_sim(..., faults=...)`` → ``EventPump.add_faults``
  → ``ProvisioningSystem.on_fail/on_repair``),
* the rounds engine (``pack_event_workloads(..., faults=...)`` folds the
  fault instants into the jump-to-next-event horizon and turns the
  scalar capacity C into the time-varying ``max(C - failed(t), 0)``),
* the live bridge (``LiveCloud.inject_faults`` pushes the same events
  into the shared pump).

Three generator families cover the MTBF models the reliability surveys
treat as standard: per-node exponential renewal, per-node Weibull
(aging hardware — increasing hazard for shape > 1), and correlated
bursts (a rack/switch domain taking k nodes down at once).

numpy-only on purpose: importable wherever the event engine is.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterator, List, Optional, Tuple

import numpy as np

__all__ = ["FaultSchedule", "exponential_schedule", "weibull_schedule",
           "burst_schedule", "merge_schedules"]


@dataclasses.dataclass(frozen=True, eq=False)
class FaultSchedule:
    """Fixed-shape failure event table.

    ``times``  — (E,) float64, sorted ascending, all > 0;
    ``deltas`` — (E,) int64, +k nodes fail / -k nodes repaired; the
    running sum (concurrently-failed count) never goes negative.
    Repairs may land beyond any measurement horizon (a node that dies
    near the end simply stays down); consumers clamp to their own
    duration.
    """

    times: np.ndarray
    deltas: np.ndarray

    def __post_init__(self):
        t = np.asarray(self.times, dtype=np.float64).reshape(-1)
        d = np.asarray(self.deltas, dtype=np.int64).reshape(-1)
        if t.shape != d.shape:
            raise ValueError(f"times {t.shape} / deltas {d.shape} mismatch")
        if t.size and np.any(np.diff(t) < 0):
            raise ValueError("fault times must be sorted ascending")
        if np.any(t <= 0):
            raise ValueError("fault events must have t > 0")
        if np.any(d == 0):
            raise ValueError("fault deltas must be nonzero")
        if t.size and np.any(np.cumsum(d) < 0):
            raise ValueError("repairs exceed concurrent failures")
        object.__setattr__(self, "times", t)
        object.__setattr__(self, "deltas", d)

    def __len__(self) -> int:
        return int(self.times.size)

    def events(self) -> Iterator[Tuple[float, int]]:
        """Iterate ``(t, delta)`` pairs in time order (pump format)."""
        for t, d in zip(self.times, self.deltas):
            yield float(t), int(d)

    def failed_series(self) -> Tuple[np.ndarray, np.ndarray]:
        """``(times, failed_after)``: the concurrently-failed count in
        effect immediately *after* each event time (a right-continuous
        step series starting at 0 before the first event)."""
        return self.times, np.cumsum(self.deltas)

    def max_concurrent(self) -> int:
        if not len(self):
            return 0
        return int(max(0, np.max(np.cumsum(self.deltas))))

    def clamp(self, capacity: int) -> "FaultSchedule":
        """Replay the site ledger's clamp (``Cluster.fail_nodes`` /
        ``repair_nodes``): at most ``capacity`` nodes can be down at
        once, and a repair only revives actually-failed nodes. Returns
        the schedule of *effective* deltas — the series the event engine
        applies — with zero-effect events dropped (the event engine
        treats those as no-ops too)."""
        times: List[float] = []
        deltas: List[int] = []
        failed = 0
        for t, d in self.events():
            eff = min(d, capacity - failed) if d > 0 else -min(-d, failed)
            if eff:
                failed += eff
                times.append(t)
                deltas.append(eff)
        return FaultSchedule(np.asarray(times, np.float64),
                             np.asarray(deltas, np.int64))


# ------------------------------------------------------------ generators


def _renewal(rng: np.random.Generator, duration: float,
             draw_up: Callable[[], float],
             draw_down: Callable[[], float]) -> List[Tuple[float, int]]:
    """One node's alternating up/down renewal process as (t, ±1) events.
    The repair paired with a failure inside the horizon is kept even if
    it lands beyond it (the node is simply still down at the end)."""
    events: List[Tuple[float, int]] = []
    t = draw_up()
    while t < duration:
        events.append((t, +1))
        r = t + max(draw_down(), 1e-6)
        events.append((r, -1))
        t = r + max(draw_up(), 1e-6)
    return events


def _finish(events: List[Tuple[float, int]]) -> FaultSchedule:
    if not events:
        return FaultSchedule(np.zeros(0), np.zeros(0, dtype=np.int64))
    events.sort(key=lambda e: e[0])
    times = np.array([t for t, _ in events], dtype=np.float64)
    deltas = np.array([d for _, d in events], dtype=np.int64)
    return FaultSchedule(times, deltas)


def exponential_schedule(seed: int, n_nodes: int, mtbf: float,
                         mttr: float, duration: float) -> FaultSchedule:
    """Per-node exponential MTBF/MTTR renewal schedule (memoryless
    hazard — the classic availability model)."""
    if mtbf <= 0 or mttr <= 0:
        raise ValueError("mtbf and mttr must be > 0")
    rng = np.random.Generator(np.random.PCG64(seed))
    events: List[Tuple[float, int]] = []
    for _ in range(n_nodes):
        events += _renewal(rng, duration,
                           lambda: rng.exponential(mtbf),
                           lambda: rng.exponential(mttr))
    return _finish(events)


def weibull_schedule(seed: int, n_nodes: int, mtbf: float, mttr: float,
                     duration: float, shape: float = 1.5) -> FaultSchedule:
    """Per-node Weibull time-between-failures (scale chosen so the mean
    equals ``mtbf``; shape > 1 models aging hardware with increasing
    hazard), exponential repair."""
    if mtbf <= 0 or mttr <= 0 or shape <= 0:
        raise ValueError("mtbf, mttr and shape must be > 0")
    scale = mtbf / math.gamma(1.0 + 1.0 / shape)
    rng = np.random.Generator(np.random.PCG64(seed))
    events: List[Tuple[float, int]] = []
    for _ in range(n_nodes):
        events += _renewal(rng, duration,
                           lambda: scale * rng.weibull(shape),
                           lambda: rng.exponential(mttr))
    return _finish(events)


def burst_schedule(seed: int, k: int, mtbf: float, mttr: float,
                   duration: float) -> FaultSchedule:
    """Correlated bursts: ``k`` nodes fail at once (a shared failure
    domain — rack power, top-of-rack switch) at exponential inter-burst
    times, all repaired together after an exponential outage. Bursts
    never overlap: the next inter-burst time starts at the previous
    repair."""
    if k <= 0:
        raise ValueError("burst size k must be > 0")
    if mtbf <= 0 or mttr <= 0:
        raise ValueError("mtbf and mttr must be > 0")
    rng = np.random.Generator(np.random.PCG64(seed))
    events: List[Tuple[float, int]] = []
    t = rng.exponential(mtbf)
    while t < duration:
        events.append((t, +k))
        r = t + max(rng.exponential(mttr), 1e-6)
        events.append((r, -k))
        t = r + max(rng.exponential(mtbf), 1e-6)
    return _finish(events)


def merge_schedules(*schedules: Optional[FaultSchedule]) -> FaultSchedule:
    """Merge schedules (e.g. per-node exponential + correlated bursts)
    into one sorted table; ``None`` entries are skipped."""
    events: List[Tuple[float, int]] = []
    for s in schedules:
        if s is not None:
            events += list(s.events())
    return _finish(events)
