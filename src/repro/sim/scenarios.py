"""On-device scenario synthesis: vmapped generator families for PBJ job
tables and WS demand series, parameterized far beyond the three paper
traces.

``repro.sim.traces`` synthesizes exactly the paper's three workloads in
host-side numpy — fine for the 45-eval paper grids, but the batched
engines only pay off at lane widths where host tracegen becomes the
floor. This module ports the synthesis recipes into JAX as per-lane-PRNG
generator families:

* :func:`synth_pbj` — parallel-batch-job tables (bursty diurnal
  arrivals, power-of-two size classes, heavy-tailed lognormal runtimes,
  exact-utilization rescale), parameterized by utilization, job count,
  runtime/size coupling ``alpha``, size-class probabilities, diurnal
  depth, weekend factor and burst fraction;
* :func:`synth_ws` — web-server VM-demand step series (diurnal base +
  noise + flash-crowd trapezoid surges, exact integer peak),
  parameterized by peak, base level, diurnal amplitude, noise and the
  surge ratio/length the load-balancing surveys call out.

The numpy generators stay as the fidelity reference: the paper traces
are re-expressible as parameter points (:data:`NASA_IPSC_PBJ`,
:data:`SDSC_BLUE_PBJ`, :data:`WORLDCUP_WS`) whose moments property-tests
match against the ``TraceSpec`` targets. The *microstructure* deliberately
differs where numpy idioms don't vectorize: arrivals sample an
inverse-CDF of the binned diurnal intensity instead of rejection
thinning (rejection is shape-dynamic, unusable under jit/vmap), burst
membership is per-job Bernoulli over a fixed episode pool instead of a
multinomial, and the iPSC nightly full-machine snap is dropped (an
archive-specific quirk, not a moment the paper uses).

Batch plumbing: :class:`ScenarioGrid` names a (seeds × params) lane
batch, :func:`synthesize` runs one jitted vmap over all lanes and pulls
the arrays host-side in one transfer, :func:`pack_scenarios` turns the
batch into a :class:`repro.sim.rounds.PackedEventWorkloads` (job-table
padding + change-point compression + ONE
:func:`~repro.sim.rounds.ws_fold_tables_batch` call for all (W, P)
lanes), and :func:`sample_workloads` materializes chosen lanes as
``(List[Job], ws_trace)`` for the event-engine differential harness.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.compat import resolve_pack_dtype
from repro.core.jobs import Job
from repro.sim.rounds import PackedEventWorkloads, ws_fold_tables_batch
from repro.sim.traces import TWO_WEEKS

__all__ = [
    "PBJParams", "WSParams", "ScenarioGrid", "SynthesizedBatch",
    "NASA_IPSC_PBJ", "SDSC_BLUE_PBJ", "WORLDCUP_WS",
    "synth_pbj", "synth_ws", "lane_keys", "synthesize",
    "pack_scenarios", "sample_workloads",
]

_ARR_BINS = 2048        # arrival-intensity CDF resolution (~10 min bins)
_BURST_EPISODES = 32    # flash-burst episode pool per lane
_BURST_TAU = 180.0      # burst intra-episode spread (s), like the numpy gen
_WS_SURGES = 12         # flash-crowd surge pool (12 matches in the paper)
_N_SIZE_CLASSES = 8     # power-of-two size classes 1 .. 128


@dataclasses.dataclass(frozen=True)
class PBJParams:
    """Generator parameters for one PBJ lane (all leaves float — scalars
    broadcast across a :class:`ScenarioGrid`, per-lane ``(W,)`` arrays
    sweep the axis)."""

    nodes: object = 128.0          # cluster size == size cap
    utilization: object = 0.466    # pinned exactly by the rescale
    n_jobs: object = 2603.0        # completed-job count (exact)
    alpha: object = 0.68           # mean runtime ∝ size^alpha
    sigma: object = 1.0            # lognormal runtime spread
    diurnal_depth: object = 0.95   # arrival-rate day/night swing (0..1)
    weekend_factor: object = 0.35  # weekend arrival-rate multiplier
    burst_frac: object = 0.12      # fraction of jobs arriving in bursts
    size_probs: object = (.20, .15, .13, .12, .12, .12, .13, .03)


@dataclasses.dataclass(frozen=True)
class WSParams:
    """Generator parameters for one WS demand lane."""

    peak: object = 64.0            # exact integer peak after rescale
    base_mean: object = 10.0       # diurnal base level (VMs)
    diurnal_amp: object = 0.6      # base swings base_mean·(1 ± amp)
    noise_std: object = 0.8        # per-step jitter (VMs)
    surge_ratio: object = 4.0      # surge amplitude / base_mean
    surge_hours: object = 2.5      # nominal surge length (hours)


for _cls, _fields in ((PBJParams, [f.name for f in
                                   dataclasses.fields(PBJParams)]),
                      (WSParams, [f.name for f in
                                  dataclasses.fields(WSParams)])):
    jax.tree_util.register_dataclass(_cls, data_fields=_fields,
                                     meta_fields=[])

# The paper traces as parameter points (moment targets in
# repro.sim.traces: NASA_IPSC / SDSC_BLUE TraceSpecs, worldcup98).
NASA_IPSC_PBJ = PBJParams()
SDSC_BLUE_PBJ = PBJParams(nodes=144.0, utilization=0.762, n_jobs=2657.0,
                          alpha=0.15)
WORLDCUP_WS = WSParams(surge_ratio=4.0, surge_hours=2.5)


# ------------------------------------------------------------- generators

def _arrival_cdf(duration: float, depth, weekend_factor) -> jnp.ndarray:
    """CDF of the binned diurnal×weekend arrival intensity — the same
    shape the numpy generator realizes by rejection thinning:
    ``rate ∝ max(1 + depth·sin(work-day phase), 0)``, weekends damped."""
    t = (jnp.arange(_ARR_BINS) + 0.5) * (duration / _ARR_BINS)
    phase = 2 * jnp.pi * ((t % 86400.0) / 86400.0 - 0.375)
    rate = jnp.maximum(1.0 + depth * jnp.sin(phase), 0.0)
    weekend = ((t // 86400.0).astype(jnp.int32) % 7) >= 5
    rate = jnp.where(weekend, rate * weekend_factor, rate) + 1e-9
    cdf = jnp.cumsum(rate)
    return cdf / cdf[-1]


def _inv_cdf(u: jnp.ndarray, cdf: jnp.ndarray,
             duration: float) -> jnp.ndarray:
    """Inverse-CDF sample: bin by binary search, uniform within bin."""
    idx = jnp.minimum(jnp.searchsorted(cdf, u, side="left"), _ARR_BINS - 1)
    lo = jnp.where(idx > 0, cdf[jnp.maximum(idx - 1, 0)], 0.0)
    frac = jnp.clip((u - lo) / jnp.maximum(cdf[idx] - lo, 1e-12), 0.0, 1.0)
    return (idx + frac) * (duration / _ARR_BINS)


def synth_pbj(key: jax.Array, params: PBJParams, *, max_jobs: int,
              duration: float = TWO_WEEKS
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One lane's PBJ job table, on device.

    Returns arrival-sorted ``(submit, size, runtime, n_jobs)`` of fixed
    shape ``(max_jobs,)`` — rows past ``n_jobs`` carry the pack padding
    convention (``submit=+inf``, size/runtime 0), so the output drops
    straight into a job-table pack. Deterministic per ``key``; designed
    to be vmapped over ``(keys, params)`` lanes.
    """
    kt, kb, ke, kd, kf, ks, kr = jax.random.split(key, 7)
    cdf = _arrival_cdf(duration, params.diurnal_depth,
                       params.weekend_factor)
    base_t = _inv_cdf(jax.random.uniform(kt, (max_jobs,)), cdf, duration)
    centers = _inv_cdf(jax.random.uniform(kb, (_BURST_EPISODES,)), cdf,
                       duration)
    episode = jax.random.randint(ke, (max_jobs,), 0, _BURST_EPISODES)
    delay = _BURST_TAU * jax.random.exponential(kd, (max_jobs,))
    burst = jax.random.uniform(kf, (max_jobs,)) < params.burst_frac
    submit = jnp.clip(jnp.where(burst, centers[episode] + delay, base_t),
                      0.0, duration - 1.0)
    probs = jnp.asarray(params.size_probs)
    exps = jax.random.categorical(ks, jnp.log(probs + 1e-12),
                                  shape=(max_jobs,))
    size = jnp.minimum(2.0 ** exps, params.nodes)
    # Lognormal runtimes, mean ∝ size^alpha; one global rescale pins
    # utilization exactly (Σ size·rt over real jobs = util·nodes·T),
    # like the numpy generator.
    mu = params.alpha * jnp.log(size) - params.sigma ** 2 / 2
    rt = jnp.exp(mu + params.sigma * jax.random.normal(kr, (max_jobs,)))
    valid = jnp.arange(max_jobs) < params.n_jobs
    target = params.utilization * params.nodes * duration
    rt = rt * (target / jnp.sum(jnp.where(valid, size * rt, 0.0)))
    rt = jnp.maximum(rt, 1.0)
    submit = jnp.where(valid, submit, jnp.inf)
    order = jnp.argsort(submit)
    size = jnp.where(valid, size, 0.0)[order].astype(jnp.int32)
    runtime = jnp.where(valid, rt, 0.0)[order]
    return (submit[order], size, runtime,
            jnp.asarray(params.n_jobs, jnp.int32))


def synth_ws(key: jax.Array, params: WSParams, *, n_steps: int,
             step_seconds: float = 300.0) -> jnp.ndarray:
    """One lane's WS VM-demand series on the dense step grid
    ``t_i = i·step_seconds``: diurnal base + noise + flash-crowd
    trapezoid surges, rescaled so the peak is exactly ``params.peak``
    (integer) and the floor is 1 VM. Returns ``(n_steps,)`` demands."""
    kn, kday, kh, kl, ka = jax.random.split(key, 5)
    t = jnp.arange(n_steps) * step_seconds
    day = (t % 86400.0) / 86400.0
    base = params.base_mean * (
        1.0 + params.diurnal_amp * jnp.sin(2 * jnp.pi * (day - 0.3)))
    base = base + params.noise_std * jax.random.normal(kn, (n_steps,))
    n_days = max(int(n_steps * step_seconds // 86400.0), 2)
    days = jax.random.randint(kday, (_WS_SURGES,), 1, n_days)
    start = days * 86400.0 + 3600.0 * jax.random.uniform(
        kh, (_WS_SURGES,), minval=12.0, maxval=20.0)
    length = 3600.0 * params.surge_hours * jax.random.uniform(
        kl, (_WS_SURGES,), minval=0.6, maxval=1.4)
    amp = params.surge_ratio * params.base_mean * jax.random.uniform(
        ka, (_WS_SURGES,), minval=0.5, maxval=1.0)
    ramp = 0.22 * length
    rel = t[None, :] - start[:, None]
    up = jnp.clip(rel / ramp[:, None], 0.0, 1.0)
    down = jnp.clip((length[:, None] - rel) / ramp[:, None], 0.0, 1.0)
    demand = jnp.maximum(base + jnp.sum(amp[:, None] *
                                        jnp.minimum(up, down), axis=0), 1.0)
    # Exact integer peak: the max maps to peak·(1 ± ulp), every other
    # point strictly below, so round() pins max(demand) == peak.
    demand = demand * (params.peak / jnp.max(demand))
    return jnp.maximum(jnp.round(demand), 1.0)


# ----------------------------------------------------------- batch plumbing

@dataclasses.dataclass(frozen=True)
class ScenarioGrid:
    """A (seeds × params) lane batch: lane ``w`` draws from
    ``seeds[w]`` with the ``w``-th slice of each parameter axis
    (scalar params broadcast). ``max_jobs`` fixes the job-table height;
    ``ws_step`` the WS demand grid (300 s, like worldcup98)."""

    seeds: Tuple[int, ...]
    pbj: PBJParams = NASA_IPSC_PBJ
    ws: WSParams = WORLDCUP_WS
    duration: float = TWO_WEEKS
    max_jobs: int = 3000
    ws_step: float = 300.0

    @property
    def n_lanes(self) -> int:
        return len(self.seeds)

    @property
    def n_ws_steps(self) -> int:
        return int(np.ceil(self.duration / self.ws_step))


@dataclasses.dataclass(frozen=True)
class SynthesizedBatch:
    """Host-side arrays for W generated lanes (one device transfer)."""

    submit: np.ndarray      # (W, max_jobs) arrival-sorted, +inf padded
    size: np.ndarray        # (W, max_jobs) int32
    runtime: np.ndarray     # (W, max_jobs)
    n_jobs: np.ndarray      # (W,) int32
    ws_times: np.ndarray    # (S,) dense step grid, shared by all lanes
    ws_values: np.ndarray   # (W, S) integer demands
    duration: float


_PARAM_BASE_NDIM = {"size_probs": 1}


def _broadcast_params(params, n_lanes: int):
    """Broadcast each scalar leaf to ``(W,)`` (``size_probs`` to
    ``(W, 8)``) so one ``in_axes=0`` vmap sweeps every axis; per-lane
    arrays pass through after a width check."""
    def one(name: str, leaf):
        base = _PARAM_BASE_NDIM.get(name, 0)
        a = np.asarray(leaf, np.float32)
        if a.ndim == base:
            a = np.broadcast_to(a, (n_lanes,) + a.shape)
        elif a.shape[0] != n_lanes:
            raise ValueError(
                f"param {name!r} has leading dim {a.shape[0]}, expected "
                f"scalar or {n_lanes} lanes")
        return jnp.asarray(a)

    return type(params)(**{f.name: one(f.name, getattr(params, f.name))
                           for f in dataclasses.fields(params)})


def lane_keys(seeds: Sequence[int]) -> jnp.ndarray:
    """Per-lane (pbj, ws) key pairs, ``(W, 2)`` stacked — lane ``w`` is
    exactly ``jax.random.split(PRNGKey(seeds[w]))``, so K vmapped lanes
    bit-match K single-key generator calls."""
    return jax.vmap(lambda s: jax.random.split(jax.random.PRNGKey(s)))(
        jnp.asarray(list(seeds), jnp.uint32))


@functools.partial(jax.jit,
                   static_argnames=("max_jobs", "n_steps", "duration",
                                    "ws_step"))
def _synth_batch(keys, pbj, ws, *, max_jobs, n_steps, duration, ws_step):
    submit, size, runtime, n_jobs = jax.vmap(
        lambda k, p: synth_pbj(k, p, max_jobs=max_jobs,
                               duration=duration))(keys[:, 0], pbj)
    ws_vals = jax.vmap(
        lambda k, p: synth_ws(k, p, n_steps=n_steps,
                              step_seconds=ws_step))(keys[:, 1], ws)
    return submit, size, runtime, n_jobs, ws_vals


def synthesize(grid: ScenarioGrid) -> SynthesizedBatch:
    """Generate every lane of ``grid`` in one jitted vmap and pull the
    batch host-side in a single transfer."""
    W = grid.n_lanes
    out = _synth_batch(lane_keys(grid.seeds),
                       _broadcast_params(grid.pbj, W),
                       _broadcast_params(grid.ws, W),
                       max_jobs=grid.max_jobs, n_steps=grid.n_ws_steps,
                       duration=float(grid.duration),
                       ws_step=float(grid.ws_step))
    submit, size, runtime, n_jobs, ws_vals = jax.device_get(out)
    ws_times = np.arange(grid.n_ws_steps, dtype=np.float64) * grid.ws_step
    return SynthesizedBatch(submit=submit, size=size, runtime=runtime,
                            n_jobs=n_jobs, ws_times=ws_times,
                            ws_values=ws_vals,
                            duration=float(grid.duration))


def pack_scenarios(synth: SynthesizedBatch, window: int, policy: str,
                   leases: Sequence[float], levels: Sequence[float],
                   dtype=None) -> PackedEventWorkloads:
    """Pack a synthesized batch for one policy's sweep points — the
    generated-lane counterpart of
    :func:`repro.sim.rounds.pack_event_workloads`, with every
    per-workload host loop replaced by array ops: job tables append the
    window padding block, rise stops compress by an argsort of the
    masked dense grid, and the WS fold tables build in ONE
    :func:`~repro.sim.rounds.ws_fold_tables_batch` call over all
    (W, P) lanes."""
    dtype = resolve_pack_dtype(dtype)
    W, J = synth.submit.shape
    pad = np.full((W, window), np.inf, dtype)
    zpad = np.zeros((W, window), dtype)
    submit = np.concatenate([synth.submit.astype(dtype), pad], axis=1)
    size = np.concatenate([synth.size.astype(dtype), zpad], axis=1)
    runtime = np.concatenate([synth.runtime.astype(dtype), zpad], axis=1)
    times = synth.ws_times.astype(np.float64)
    vals = synth.ws_values.astype(np.float64)
    ws0 = vals[:, 0]
    changed = vals[:, 1:] != vals[:, :-1]
    ws_adjusts = changed.sum(axis=1) + (vals[:, 0] > 0)
    up = np.zeros(vals.shape, bool)
    up[:, 1:] = vals[:, 1:] > vals[:, :-1]
    nr = int(up.sum(axis=1).max()) + 1        # +inf sentinel
    masked_t = np.where(up, times[None, :], np.inf)
    order = np.argsort(masked_t, axis=1)[:, :nr]
    rise_times = np.take_along_axis(masked_t, order, axis=1)
    rise_vals = np.where(np.take_along_axis(up, order, axis=1),
                         np.take_along_axis(vals, order, axis=1), 0.0)
    # The dense grid's no-op points are value-identical for the fold
    # tables (equal adjacent segments merge in the integral, maxima and
    # boundary gathers are unchanged), so no per-lane compression pass.
    integral, winmax, at_tick = ws_fold_tables_batch(
        times, vals, synth.duration, policy,
        np.asarray(leases, np.float64), np.asarray(levels, np.float64))
    return PackedEventWorkloads(
        submit=jnp.asarray(submit), size=jnp.asarray(size),
        runtime=jnp.asarray(runtime),
        ws0=jnp.asarray(ws0.astype(dtype)),
        ws_adjusts=jnp.asarray(ws_adjusts.astype(dtype)),
        rise_times=jnp.asarray(rise_times.astype(dtype)),
        rise_vals=jnp.asarray(rise_vals.astype(dtype)),
        ws_integral=jnp.asarray(integral.astype(dtype)),
        ws_winmax=jnp.asarray(winmax.astype(dtype)),
        ws_at_tick=jnp.asarray(at_tick.astype(dtype)),
        n_jobs=jnp.asarray(synth.n_jobs.astype(np.int32)))


def sample_workloads(synth: SynthesizedBatch,
                     indices: Sequence[int]
                     ) -> List[Tuple[List[Job], List[Tuple[float, int]]]]:
    """Materialize chosen lanes as ``(List[Job], ws_trace)`` for the
    event-engine differential harness — float32 values round-trip
    exactly through Python floats, so the event engine sees the very
    numbers the packed batch carries."""
    out = []
    for w in indices:
        n = int(synth.n_jobs[w])
        jobs = [Job(jid=i, submit=float(synth.submit[w, i]),
                    size=int(synth.size[w, i]),
                    runtime=float(synth.runtime[w, i]))
                for i in range(n)]
        vals = synth.ws_values[w]
        trace: List[Tuple[float, int]] = [(0.0, int(vals[0]))]
        for i in range(1, len(vals)):
            d = int(vals[i])
            if d != trace[-1][1]:
                trace.append((float(synth.ws_times[i]), d))
        out.append((jobs, trace))
    return out
