"""Per-engine fidelity contracts — the single source of truth.

Every fast path of the sweep engine is validated against the
discrete-event reference (``repro.sim.engine.run_sim``), each with its
own tolerance band:

* the fixed-``dt`` **scan** is approximate by time discretization —
  completed jobs within 2 %, node-hours and peak within 15 %;
* the event-round **rounds** engine (coalesced or not) replays events
  at exact times — completed jobs must match EXACTLY (and completion
  times bit-match in float64), node-hours and peak within 5 % (the
  residue is first-fit pass convergence and §5.1 kill tie-breaking,
  not discretization);
* the **vectorized** DCS/EC2 baselines are closed-form — exact to
  round-off (integer metrics equal, node-hours to ~1e-9 relative).

Both the test suite (tests/test_engine_differential.py) and the CI
benchmark gate (``benchmarks/run.py sweep --check-fidelity``) import
THIS table, so the gate and the tests cannot drift apart: a contract
change is one edit, reviewed once, enforced everywhere.
"""

from __future__ import annotations

import dataclasses

__all__ = ["EngineContract", "SCAN_CONTRACT", "ROUNDS_CONTRACT",
           "VECTORIZED_CONTRACT", "CONTRACTS", "check_fidelity"]


@dataclasses.dataclass(frozen=True)
class EngineContract:
    """Tolerances of one engine vs the event reference: relative drift
    bounds per metric, plus whether completed-job counts must be exact
    (a stronger statement than ``completed_rel == 0`` — it is asserted
    on the integer counts, with no epsilon)."""

    completed_rel: float
    node_hours_rel: float
    peak_rel: float
    completed_exact: bool = False

    def check_row(self, fast: dict, event: dict) -> list:
        """Compare one sweep row against its event-engine reference.
        Returns a list of violation strings (empty = within contract).
        """
        violations = []
        ev_jobs = event["completed_jobs"]
        dj = abs(fast["completed_jobs"] - ev_jobs) / max(1, ev_jobs)
        if self.completed_exact:
            if fast["completed_jobs"] != ev_jobs:
                violations.append(
                    f"completed_jobs {fast['completed_jobs']} != "
                    f"{ev_jobs} (exact contract)")
        elif dj > self.completed_rel:
            violations.append(
                f"completed_jobs drift {dj:.4f} > {self.completed_rel}")
        dn = abs(fast["node_hours"] - event["node_hours"]) \
            / max(1e-9, event["node_hours"])
        if dn > self.node_hours_rel:
            violations.append(
                f"node_hours drift {dn:.4f} > {self.node_hours_rel}")
        dp = abs(fast["peak_nodes"] - event["peak_nodes"]) \
            / max(1, event["peak_nodes"])
        if dp > self.peak_rel:
            violations.append(
                f"peak_nodes drift {dp:.4f} > {self.peak_rel}")
        return violations


SCAN_CONTRACT = EngineContract(completed_rel=0.02, node_hours_rel=0.15,
                               peak_rel=0.15)
ROUNDS_CONTRACT = EngineContract(completed_rel=0.0, node_hours_rel=0.05,
                                 peak_rel=0.05, completed_exact=True)
VECTORIZED_CONTRACT = EngineContract(completed_rel=0.0,
                                     node_hours_rel=1e-9, peak_rel=0.0,
                                     completed_exact=True)

# Keyed by the ``engine`` tag run_sweep puts on each row.
CONTRACTS = {
    "scan": SCAN_CONTRACT,
    "rounds": ROUNDS_CONTRACT,
    "vectorized": VECTORIZED_CONTRACT,
}


def check_fidelity(fast_rows, event_rows) -> list:
    """Check aligned row lists (same sweep points, same order) against
    each fast row's engine contract. ``event`` rows are skipped (the
    reference cannot drift from itself). Returns violation strings
    tagged with the offending system."""
    violations = []
    for fast, ev in zip(fast_rows, event_rows):
        if fast is None or fast["engine"] == "event":
            continue
        contract = CONTRACTS[fast["engine"]]
        for v in contract.check_row(fast, ev):
            violations.append(f"{fast['system']}: {v}")
    return violations
