"""Per-engine fidelity contracts — the single source of truth.

Every fast path of the sweep engine is validated against the
discrete-event reference (``repro.sim.engine.run_sim``), each with its
own tolerance band:

* the fixed-``dt`` **scan** is approximate by time discretization —
  completed jobs within 2 %, node-hours and peak within 15 %;
* the event-round **rounds** engine (coalesced or not) replays events
  at exact times — completed jobs must match EXACTLY (and completion
  times bit-match in float64), node-hours and peak within 5 % (the
  residue is first-fit pass convergence and §5.1 kill tie-breaking,
  not discretization);
* the **vectorized** DCS/EC2 baselines are closed-form — exact to
  round-off (integer metrics equal, node-hours to ~1e-9 relative);
* the **live** serving stack replayed over a trace
  (``repro.serving.replay``) shares the event pump with the reference,
  so completions are exact; its extra degrees of freedom — the §6.4
  autoscaler deriving demand from traffic instead of reading the trace
  — are bounded by :func:`demand_drift` (``LiveContract``);
* the **faults** chaos tier (``repro.sim.faults``) replays one fault
  schedule through all three paths. Event-vs-live is exact (same pump).
  Event-vs-rounds keeps node-hours and peak in tight bands but allows
  ±``completed_abs`` completed jobs: at a fault instant both engines
  free the same node count, but kill-victim tie-breaking can requeue
  different jobs and shift which ones finish inside the horizon
  (measured: node-hours/peak exact, completions ±1–2 on heavily
  contended workloads). ``FaultContract`` also states the recovery
  invariant itself: :func:`no_lost_jobs` — every submitted job is
  either completed or still tracked (queued/running), never dropped by
  a failure.

Both the test suite (tests/test_engine_differential.py) and the CI
benchmark gate (``benchmarks/run.py sweep --check-fidelity``) import
THIS table, so the gate and the tests cannot drift apart: a contract
change is one edit, reviewed once, enforced everywhere.
"""

from __future__ import annotations

import dataclasses

__all__ = ["EngineContract", "LiveContract", "FaultContract",
           "HeadlineContract", "SCAN_CONTRACT", "ROUNDS_CONTRACT",
           "VECTORIZED_CONTRACT", "LIVE_CONTRACT", "FAULT_CONTRACT",
           "HEADLINE_CONTRACT", "CONTRACTS",
           "check_fidelity", "demand_drift", "no_lost_jobs"]


@dataclasses.dataclass(frozen=True)
class EngineContract:
    """Tolerances of one engine vs the event reference: relative drift
    bounds per metric, plus whether completed-job counts must be exact
    (a stronger statement than ``completed_rel == 0`` — it is asserted
    on the integer counts, with no epsilon)."""

    completed_rel: float
    node_hours_rel: float
    peak_rel: float
    completed_exact: bool = False

    def check_row(self, fast: dict, event: dict) -> list:
        """Compare one sweep row against its event-engine reference.
        Returns a list of violation strings (empty = within contract).
        """
        violations = []
        ev_jobs = event["completed_jobs"]
        dj = abs(fast["completed_jobs"] - ev_jobs) / max(1, ev_jobs)
        if self.completed_exact:
            if fast["completed_jobs"] != ev_jobs:
                violations.append(
                    f"completed_jobs {fast['completed_jobs']} != "
                    f"{ev_jobs} (exact contract)")
        elif dj > self.completed_rel:
            violations.append(
                f"completed_jobs drift {dj:.4f} > {self.completed_rel}")
        dn = abs(fast["node_hours"] - event["node_hours"]) \
            / max(1e-9, event["node_hours"])
        if dn > self.node_hours_rel:
            violations.append(
                f"node_hours drift {dn:.4f} > {self.node_hours_rel}")
        dp = abs(fast["peak_nodes"] - event["peak_nodes"]) \
            / max(1, event["peak_nodes"])
        if dp > self.peak_rel:
            violations.append(
                f"peak_nodes drift {dp:.4f} > {self.peak_rel}")
        return violations


def demand_drift(live: list, ref: list, duration: float) -> tuple:
    """Time-weighted drift between two step series ``[(t, value), ...]``
    (each value holds from its breakpoint to the next). Returns
    ``(mae_rel, peak_rel)``: the integral of ``|live - ref|`` over the
    union of breakpoints, normalized by the reference's own integral,
    and the relative error of the peaks. This is the §6.4 question
    stated as a number: how closely does utilization-driven instance
    adjustment re-derive the demand trace it is serving?"""

    def value_at(series, t):
        v = 0
        for bt, bv in series:
            if bt <= t:
                v = bv
            else:
                break
        return v

    live = sorted(live)
    ref = sorted(ref)
    points = sorted({0.0, duration}
                    | {t for t, _ in live if t < duration}
                    | {t for t, _ in ref if t < duration})
    abs_area = 0.0
    ref_area = 0.0
    for t0, t1 in zip(points, points[1:]):
        dt = t1 - t0
        abs_area += abs(value_at(live, t0) - value_at(ref, t0)) * dt
        ref_area += value_at(ref, t0) * dt
    mae_rel = abs_area / max(1e-9, ref_area)
    peak_live = max((v for _, v in live), default=0)
    peak_ref = max((v for _, v in ref), default=0)
    peak_rel = abs(peak_live - peak_ref) / max(1, peak_ref)
    return mae_rel, peak_rel


@dataclasses.dataclass(frozen=True)
class LiveContract(EngineContract):
    """The live-stack contract: the row tolerances of EngineContract
    plus bounds on the autoscaler-derived demand curve vs the replayed
    trace. ``demand_mae_rel`` absorbs the adjustment lag (one sampling
    window per demand step) and the ±1 flap when utilization sits at
    the calibrated ~0.78 equilibrium just under the 80 % threshold;
    ``demand_peak_rel`` bounds transient overshoot at surge ramps."""

    demand_mae_rel: float = 0.25
    demand_peak_rel: float = 0.25

    def check_live(self, live: dict, event: dict, live_demand: list,
                   ref_demand: list, duration: float) -> list:
        violations = self.check_row(live, event)
        mae, peak = demand_drift(live_demand, ref_demand, duration)
        if mae > self.demand_mae_rel:
            violations.append(
                f"demand MAE drift {mae:.4f} > {self.demand_mae_rel}")
        if peak > self.demand_peak_rel:
            violations.append(
                f"demand peak drift {peak:.4f} > {self.demand_peak_rel}")
        return violations


@dataclasses.dataclass(frozen=True)
class FaultContract(EngineContract):
    """The chaos tier's rounds-vs-event contract. Node-hours and peak
    stay in the tight rounds bands (failures change *capacity*, which
    both engines account identically), but completed jobs get an
    absolute ±``completed_abs`` allowance on top of the relative band:
    kill-victim tie-breaking at fault instants frees the same node
    count either way yet can requeue different jobs, shifting which
    ones finish inside the horizon. A row passes the completion check
    if it is within EITHER bound — the absolute slack covers tiny
    heavily-contended workloads where one job is a large fraction."""

    completed_abs: int = 2

    def check_row(self, fast: dict, event: dict) -> list:
        violations = []
        ev_jobs = event["completed_jobs"]
        dj_abs = abs(fast["completed_jobs"] - ev_jobs)
        dj_rel = dj_abs / max(1, ev_jobs)
        if dj_abs > self.completed_abs and dj_rel > self.completed_rel:
            violations.append(
                f"completed_jobs drift {dj_abs} jobs ({dj_rel:.4f} rel) "
                f"> max({self.completed_abs} abs, "
                f"{self.completed_rel} rel)")
        dn = abs(fast["node_hours"] - event["node_hours"]) \
            / max(1e-9, event["node_hours"])
        if dn > self.node_hours_rel:
            violations.append(
                f"node_hours drift {dn:.4f} > {self.node_hours_rel}")
        dp = abs(fast["peak_nodes"] - event["peak_nodes"]) \
            / max(1, event["peak_nodes"])
        if dp > self.peak_rel:
            violations.append(
                f"peak_nodes drift {dp:.4f} > {self.peak_rel}")
        return violations


def no_lost_jobs(jobs, system) -> list:
    """The recovery invariant of the chaos tier: after a run with
    failures, every submitted job is either completed or still tracked
    by the PBJ manager (queued or running) — a node failure may delay a
    job arbitrarily, but may never *drop* it. Returns violation strings
    (empty = invariant holds)."""
    tracked = {j.jid for j in system.pbj.queue}
    tracked |= {j.jid for j in system.pbj.running.jobs()}
    violations = []
    for j in jobs:
        if not j.completed and j.jid not in tracked:
            violations.append(
                f"job {j.jid} lost: not completed, not queued, "
                f"not running (kills={j.kills})")
    return violations


@dataclasses.dataclass(frozen=True)
class HeadlineContract:
    """Bands for the §6 headline numbers *as query outputs* — the
    capacity layer (``repro.sim.capacity.headline_queries``) answers the
    paper's two claims as optimization queries and this contract states
    how far the answers may sit from the paper.

    ``config_reduction``: §6.5.3 / Fig. 13 — the private-cloud FB system
    needs a ≈40 % smaller cluster configuration than DCS at the same
    completed-job throughput. The reproduction's moment-matched
    iPSC/860 + WorldCup'98 pair measures 0.473 (min feasible C = 135 vs
    the DCS size 256). The floor is the paper's own claim — the query
    must demonstrate AT LEAST the 40 % saving — and the ceiling guards
    against a degenerate workload making the query trivially easy.

    ``peak_reduction``: §6.6.3 — FLB-NUB's peak resource consumption is
    "up to 31 %" lower than the EC2+RightScale baseline. Measured 0.386
    on the iPSC pair and 0.337 on NASA BLUE. The floor is the paper's
    31 % minus the rounds engine's 5 % peak band (0.31 · 0.95 ≈ 0.29,
    rounded down to 0.28); the ceiling is a sanity bound.
    """

    config_reduction_lo: float = 0.40
    config_reduction_hi: float = 0.55
    peak_reduction_lo: float = 0.28
    peak_reduction_hi: float = 0.45

    def check(self, config_reduction: float,
              peak_reduction: float) -> list:
        """Returns violation strings (empty = both §6 numbers land in
        band)."""
        violations = []
        if not (self.config_reduction_lo <= config_reduction
                <= self.config_reduction_hi):
            violations.append(
                f"config_reduction {config_reduction:.4f} outside "
                f"[{self.config_reduction_lo}, {self.config_reduction_hi}]"
                f" (§6.5.3 claims ≈40 %)")
        if not (self.peak_reduction_lo <= peak_reduction
                <= self.peak_reduction_hi):
            violations.append(
                f"peak_reduction {peak_reduction:.4f} outside "
                f"[{self.peak_reduction_lo}, {self.peak_reduction_hi}]"
                f" (§6.6.3 claims up to 31 %)")
        return violations


SCAN_CONTRACT = EngineContract(completed_rel=0.02, node_hours_rel=0.15,
                               peak_rel=0.15)
ROUNDS_CONTRACT = EngineContract(completed_rel=0.0, node_hours_rel=0.05,
                                 peak_rel=0.05, completed_exact=True)
VECTORIZED_CONTRACT = EngineContract(completed_rel=0.0,
                                     node_hours_rel=1e-9, peak_rel=0.0,
                                     completed_exact=True)
# Live replay vs the event simulator on one trace: both run the same
# heap/clock/ProvisioningSystem (the pump), so job completions must
# match exactly; node-hours/peak drift only through the autoscaler's
# demand lag (measured ≤2 % node-hours and 2.5–17 % demand MAE across
# the BENCH_live lanes — the band leaves headroom for trace-shaped
# transients).
LIVE_CONTRACT = LiveContract(completed_rel=0.0, node_hours_rel=0.10,
                             peak_rel=0.10, completed_exact=True,
                             demand_mae_rel=0.25, demand_peak_rel=0.25)
# Chaos tier, rounds-vs-event: node-hours/peak measured exact across
# the randomized differential (the pack clamps nominal failures to the
# ledger's per-capacity clamp), banded at 2 % for float headroom;
# completions allow ±2 jobs or 2 % for kill-victim tie-breaking.
# Event-vs-LIVE under the same schedule shares the pump and stays under
# LIVE_CONTRACT's exact-completion check — no separate band.
FAULT_CONTRACT = FaultContract(completed_rel=0.02, node_hours_rel=0.02,
                               peak_rel=0.02, completed_abs=2)
# The §6 headline numbers as capacity-query outputs — gated by
# tests/test_capacity.py and ``benchmarks.run capacity``.
HEADLINE_CONTRACT = HeadlineContract()

# Keyed by the ``engine`` tag run_sweep puts on each row; "queries"
# keys the capacity layer's headline gate.
CONTRACTS = {
    "scan": SCAN_CONTRACT,
    "rounds": ROUNDS_CONTRACT,
    "vectorized": VECTORIZED_CONTRACT,
    "live": LIVE_CONTRACT,
    "faults": FAULT_CONTRACT,
    "queries": HEADLINE_CONTRACT,
}


def check_fidelity(fast_rows, event_rows) -> list:
    """Check aligned row lists (same sweep points, same order) against
    each fast row's engine contract. ``event`` rows are skipped (the
    reference cannot drift from itself). Returns violation strings
    tagged with the offending system."""
    violations = []
    for fast, ev in zip(fast_rows, event_rows):
        if fast is None or fast["engine"] == "event":
            continue
        contract = CONTRACTS[fast["engine"]]
        for v in contract.check_row(fast, ev):
            violations.append(f"{fast['system']}: {v}")
    return violations
