"""Discrete-event engine driving any ``ProvisioningSystem`` (§6.3, §6.5).

The engine is a plain event heap (submit / finish / ws-demand / lease
tick) over the five-event lifecycle protocol of
:class:`repro.core.system.ProvisioningSystem` — it is policy-free and
knows nothing about any concrete system. All metrics are measured over
the trace duration, exactly as §6.1 prescribes ("all performance metrics
are obtained in the same period that is the duration of workload
traces").

The four paper systems (§6.3, §6.5, §6.6) are constructed by the
``build_*`` helpers:

  * DCS                — static partition (``core.baselines.DCSSystem``)
  * PhoenixCloud FB    — §5.1 (``core.provision.FBProvisionService``)
  * PhoenixCloud FLB-NUB — §5.2 (``core.provision.FLBNUBProvisionService``)
  * EC2+RightScale     — §6.6.1 (``core.baselines.EC2RightScaleSystem``)

Parameter *sweeps* over grids of systems live in ``repro.sim.sweep``,
which batches the stateless systems as exact vectorized JAX programs,
offers a batched ``lax.scan`` fast path (``repro.sim.scan``) for the
stateful PhoenixCloud policies, and uses this engine as the per-point
reference path (``mode="event"``) that every fast path is
cross-validated against.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.baselines import DCSSystem, EC2RightScaleSystem
from repro.core.jobs import Job
from repro.core.pbj_manager import PBJManager, PBJPolicyParams, Started
from repro.core.provision import FBProvisionService, FLBNUBProvisionService
from repro.core.system import ProvisioningSystem
from repro.core.ws_manager import WSManager
from repro.sim.pump import DecisionLedger, EventPump

# Relative event order for simultaneous times (ws-demand changes apply
# before lease ticks, ticks before submits). The authoritative ordering
# now lives in repro.sim.pump (which adds a CALL kind for the live
# bridge); these legacy codes are the fold-table encoding the sweep
# engine packs into its device tables, kept for that packed format.
_WS, _TICK, _SUBMIT, _FINISH = 0, 1, 2, 3

# The paper's comparison matrix (§6.3, §6.5, §6.6) — the single source of
# truth for valid system names, shared with the sweep engine's
# ``SweepPoint`` validation.
SYSTEMS = ("dcs", "fb", "flb_nub", "ec2")


@dataclasses.dataclass
class SimResult:
    system: str
    duration: float
    completed_jobs: int
    avg_turnaround: float
    avg_execution: float
    node_hours: float
    peak_nodes: int
    adjust_events: int       # all ledger events (incl. WS demand changes)
    pbj_adjust_events: int   # the paper's Fig-18 metric: PBJ TRE only
    kills: int
    jobs: List[Job]

    def row(self) -> dict:
        return {k: getattr(self, k) for k in
                ("system", "completed_jobs", "avg_turnaround",
                 "avg_execution", "node_hours", "peak_nodes",
                 "adjust_events", "pbj_adjust_events", "kills")}


def clone_jobs(jobs: Sequence[Job]) -> List[Job]:
    """Fresh copies — Job carries mutable per-run state, so each system
    must simulate its own copy of the trace."""
    return [Job(jid=j.jid, submit=j.submit, size=j.size, runtime=j.runtime,
                arch=j.arch, min_size=j.min_size) for j in jobs]


# ------------------------------------------------------------ system builders

def build_dcs(prc_pbj: int, prc_ws: int,
              lease_seconds: float = 3600.0) -> DCSSystem:
    return DCSSystem(prc_pbj, prc_ws, PBJManager(), WSManager(),
                     lease_seconds)


def build_fb(capacity: int, lease_seconds: float = 3600.0,
             params: PBJPolicyParams = PBJPolicyParams()) -> FBProvisionService:
    return FBProvisionService(capacity, PBJManager(params=params),
                              WSManager(), lease_seconds)


def build_flb_nub(lb_pbj: int, lb_ws: int, lease_seconds: float = 3600.0,
                  params: PBJPolicyParams = PBJPolicyParams()
                  ) -> FLBNUBProvisionService:
    return FLBNUBProvisionService(lb_pbj, lb_ws, PBJManager(params=params),
                                  WSManager(), lease_seconds)


def build_ec2_rightscale(lease_seconds: float = 3600.0) -> EC2RightScaleSystem:
    return EC2RightScaleSystem(PBJManager(), WSManager(), lease_seconds)


# ----------------------------------------------------------------- the engine

def default_duration(jobs: Sequence[Job],
                     ws_trace: Sequence[Tuple[float, int]]) -> float:
    """§6.1 measurement horizon when none is given: just past the last
    trace event (shared by ``run_sim`` and the sweep engine)."""
    return max([j.submit for j in jobs] + [t for t, _ in ws_trace]) + 1


def run_sim(system: ProvisioningSystem, jobs: Sequence[Job],
            ws_trace: Sequence[Tuple[float, int]],
            duration: Optional[float] = None, name: str = "",
            lease_seconds: Optional[float] = None,
            ledger: Optional[DecisionLedger] = None,
            faults=None) -> SimResult:
    """Drive ``system`` through the trace on the shared event pump.

    ``ledger``, when given, receives one :class:`~repro.sim.pump
    .LedgerEntry` per provisioning event — the structured decision
    record the live-vs-sim differential harness diffs against the live
    bridge's ledger (``CONTRACTS["live"]``).

    ``faults``, when given, is a :class:`repro.sim.faults.FaultSchedule`
    injected as FAIL/REPAIR events (the chaos tier); the system must
    implement ``on_fail``/``on_repair``. ``None`` leaves the event
    stream byte-identical to the pre-fault engine.
    """
    lease = lease_seconds if lease_seconds is not None else system.lease_seconds
    if duration is None:
        duration = default_duration(jobs, ws_trace)
    pump = EventPump(system, duration, ledger=ledger)
    # Push order (jobs, ws, ticks, faults, then startup) fixes the
    # sequence numbers that break within-kind ties — identical to the
    # old monolithic loop, so rows reproduce bit for bit.
    pump.add_jobs(jobs)
    ws_initial = pump.add_ws_trace(ws_trace)
    pump.add_lease_ticks(lease)
    if faults is not None:
        pump.add_faults(faults)
    pump.startup(ws_initial=ws_initial)
    pump.run()
    return summarize(system, jobs, duration, name)


def summarize(system: ProvisioningSystem, jobs: Sequence[Job],
              duration: float, name: str = "") -> SimResult:
    """Finalize the site ledger and measure the §6.1 metrics — shared by
    ``run_sim`` and the live replay harness (``repro.serving.replay``),
    so both paths' rows are built by the same accounting."""
    system.cluster.finalize(duration)
    done = [j for j in jobs if j.completed]
    return SimResult(
        system=name or type(system).__name__,
        duration=duration,
        completed_jobs=len(done),
        avg_turnaround=(sum(j.turnaround for j in done) / len(done)) if done else 0.0,
        avg_execution=(sum(j.execution for j in done) / len(done)) if done else 0.0,
        node_hours=system.cluster.node_hours,
        peak_nodes=system.cluster.peak,
        adjust_events=system.cluster.adjust_events(),
        pbj_adjust_events=system.cluster.adjust_events(system.pbj.name),
        kills=system.pbj.kill_count,
        jobs=list(jobs),
    )
