"""Synthetic workload traces, moment-matched to the paper's inputs (§6.2).

The original archives (Parallel Workloads Archive NASA-iPSC-1993 /
SDSC-BLUE-2000, HP World Cup '98) are not redistributable in this offline
container, so we synthesize traces that match every statistic the paper
reports and uses:

  * **NASA iPSC**: 128-node cluster, two weeks, 46.6 % utilization,
    ~2603 completed jobs (Table 1 DCS row), mean execution ≈ 573 s,
    power-of-two job sizes (iPSC/860 hypercube), bursty diurnal arrivals.
  * **SDSC BLUE**: 144 nodes (the paper divides the 8-CPU nodes by 8),
    two weeks, 76.2 % utilization, ~2657 jobs, mean execution ≈ 1975 s.
  * **World Cup '98**: a two-week VM-demand series with peak 64 VMs
    (the paper's Fig. 10 resource-consumption trace), strong diurnal
    pattern plus match-window surges (high peak/normal ratio — the
    property §6.2 highlights).

Utilization is matched *exactly* by rescaling runtimes after sampling so
that Σ size·runtime = util · nodes · duration; all other moments are
matched to within sampling noise. Every generator is deterministic given
``seed``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.core.jobs import Job

TWO_WEEKS = 14 * 24 * 3600.0


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    name: str
    nodes: int              # original cluster size == PRC of the raw trace
    utilization: float
    n_jobs: int
    mean_runtime: float
    duration: float = TWO_WEEKS


NASA_IPSC = TraceSpec("nasa_ipsc", nodes=128, utilization=0.466,
                      n_jobs=2603, mean_runtime=573.0)
SDSC_BLUE = TraceSpec("sdsc_blue", nodes=144, utilization=0.762,
                      n_jobs=2657, mean_runtime=1975.0)


def _arrivals(rng: np.random.Generator, n: int, duration: float) -> np.ndarray:
    """Bursty diurnal arrival process with deep night/weekend troughs.

    Real archive traces (and the paper's near-zero DCS queueing at 46.6 %
    utilization) imply the queue fully drains at night: arrivals collapse
    outside working hours. A moderate fraction of jobs arrives in short
    bursts (parameter sweeps) — bursts are what make the EC2 baseline's
    peak consumption several times PhoenixCloud's (§6.7: "two or three in
    our experiments"), since on EC2 every submitted job runs immediately.
    """
    n_burst = int(0.12 * n)
    n_base = n - n_burst
    # Diurnal thinning: rate ∝ 1 + 0.95·sin(work-day phase), ~35 % on
    # weekends — nights and weekends nearly drain the queue.
    t = rng.uniform(0, duration, size=6 * n_base)
    day_phase = 2 * np.pi * ((t % 86400.0) / 86400.0 - 0.375)
    keep_p = (1 + 0.95 * np.sin(day_phase)) / 1.95
    weekend = ((t // 86400.0).astype(int) % 7) >= 5
    keep_p = np.where(weekend, keep_p * 0.35, keep_p)
    t = t[rng.uniform(size=t.shape) < keep_p][:n_base]
    # Bursts: ~30 small episodes (parameter sweeps), daytime-weighted.
    episodes = int(rng.integers(24, 40))
    centers = rng.uniform(0, duration, size=3 * episodes)
    c_phase = 2 * np.pi * ((centers % 86400.0) / 86400.0 - 0.375)
    centers = centers[np.sin(c_phase) > -0.2][:episodes]
    weights = rng.dirichlet(np.ones(len(centers)))
    counts = rng.multinomial(n_burst, weights)
    bursts = [c + rng.exponential(180.0, size=k)
              for c, k in zip(centers, counts)]
    out = np.concatenate([t] + bursts)
    out = np.clip(out, 0, duration - 1.0)
    out.sort()
    return out[:n]


_SIZE_EXPS = np.arange(8)           # 1 .. 128, powers of two


def _sample_jobs(spec: TraceSpec, size_probs: np.ndarray, alpha: float,
                 sigma: float, seed: int,
                 arch_pool: Tuple[str, ...] = ()) -> List[Job]:
    """Sample jobs with archive-like structure.

    Real archive traces are dominated by *small* jobs, while runtimes grow
    with job size (big parallel runs are long runs): mean runtime ∝
    size^alpha. ``alpha`` is calibrated so that E[size·rt]/E[rt] matches
    util·nodes·duration / (n_jobs·mean_rt) — i.e. both the paper's
    utilization and its mean execution time hold simultaneously. A final
    global rescale pins utilization exactly.
    """
    rng = np.random.default_rng(seed)
    n = spec.n_jobs
    submit = _arrivals(rng, n, spec.duration)
    n = len(submit)
    sizes = 2 ** rng.choice(_SIZE_EXPS, size=n, p=size_probs)
    sizes = np.minimum(sizes, spec.nodes)
    # Lognormal runtimes, mean growing with size^alpha.
    mean_rt = sizes.astype(float) ** alpha
    mu = np.log(mean_rt) - sigma ** 2 / 2
    runtimes = rng.lognormal(mu, sigma)
    # Exact utilization match: one global rescale.
    target = spec.utilization * spec.nodes * spec.duration
    runtimes *= target / float(np.sum(sizes * runtimes))
    runtimes = np.maximum(runtimes, 1.0)
    # Full-machine jobs run in the nightly dedicated window (a documented
    # property of the iPSC archive: full-cube runs were queued for night
    # slots). Snap their submissions to ~02:00 ± 2 h.
    full = sizes >= spec.nodes
    if np.any(full):
        day = (submit[full] // 86400.0) * 86400.0
        submit = submit.copy()
        submit[full] = day + 2 * 3600.0 + rng.uniform(-7200, 7200,
                                                      size=int(full.sum()))
        submit = np.clip(submit, 0, spec.duration - 1.0)
        order = np.argsort(submit)
        submit, sizes, runtimes = submit[order], sizes[order], runtimes[order]
    archs = (list(arch_pool) * (n // max(1, len(arch_pool)) + 1))[:n] \
        if arch_pool else [None] * n
    return [Job(jid=i, submit=float(submit[i]), size=int(sizes[i]),
                runtime=float(runtimes[i]), arch=archs[i])
            for i in range(n)]


def nasa_ipsc(seed: int = 0, arch_pool: Tuple[str, ...] = ()) -> List[Job]:
    """~46.6 % utilization, low-load trace (mean rt ≈ 573 s; ~3 % of jobs
    use the full 128 nodes, matching the ~50 jobs that never complete in
    the paper's PhoenixCloud(128) row of Table 1)."""
    probs = np.array([.20, .15, .13, .12, .12, .12, .13, .03])
    return _sample_jobs(NASA_IPSC, probs, alpha=0.68, sigma=1.0, seed=seed,
                        arch_pool=arch_pool)


def sdsc_blue(seed: int = 0, arch_pool: Tuple[str, ...] = ()) -> List[Job]:
    """~76.2 % utilization, high-load trace (mean rt ≈ 1975 s)."""
    probs = np.array([.20, .15, .13, .12, .12, .12, .13, .03])
    return _sample_jobs(SDSC_BLUE, probs, alpha=0.15, sigma=1.0, seed=seed,
                        arch_pool=arch_pool)


def _scale_count(d: int, prc: int, prc0: int) -> int:
    """``max(1, round(d · prc / prc0))`` in exact integer arithmetic
    (round half up). Exactness makes scaling involutive for upscales:
    with ``f = prc/prc0 > 1``, ``|d' − d·f| ≤ 1/2`` implies
    ``|d'/f − d| < 1/2`` strictly, so scaling to ``prc`` and back to
    ``prc0`` reproduces ``d`` under ANY nearest rounding — and distinct
    demands stay distinct (``(d2 − d1)·f > 1``), so ``scale_ws``'s
    duplicate-merge drops nothing on the way up. The float
    ``int(round(d * (prc/prc0)))`` this replaces drifts on the way back
    when ``d·prc/prc0`` lands within an ulp of a half-integer."""
    return max(1, (2 * d * prc + prc0) // (2 * prc0))


def scale_jobs(jobs: List[Job], prc: int, prc0: int) -> List[Job]:
    """§6.3 'synthetic heterogeneous workloads': scale a PBJ trace so its
    peak resource demand is ``prc`` instead of ``prc0`` (constant factor on
    job sizes). Upscale round trips exactly: ``scale_jobs(scale_jobs(jobs,
    prc, prc0), prc0, prc)`` reproduces the original sizes for
    ``prc >= prc0`` (see :func:`_scale_count`)."""
    return [Job(jid=j.jid, submit=j.submit,
                size=_scale_count(j.size, prc, prc0), runtime=j.runtime,
                arch=j.arch)
            for j in jobs]


# --------------------------------------------------------------------- WS

def worldcup98(seed: int = 0, peak_vms: int = 64,
               step_seconds: float = 300.0,
               duration: float = TWO_WEEKS) -> List[Tuple[float, int]]:
    """VM-demand step series shaped like the paper's Fig. 10.

    Diurnal base load plus match-window surges; peak is exactly
    ``peak_vms``. Returns a list of (time, demand) change points starting
    at t=0.
    """
    rng = np.random.default_rng(seed + 7)
    t = np.arange(0.0, duration, step_seconds)
    day = (t % 86400.0) / 86400.0
    base = 10 + 6 * np.sin(2 * np.pi * (day - 0.3))          # diurnal 4..16
    base += rng.normal(0, 0.8, size=t.shape)                 # jitter
    surge = np.zeros_like(t)
    n_matches = 12
    match_days = rng.choice(np.arange(1, 14), size=n_matches, replace=True)
    for d in match_days:
        start = d * 86400.0 + rng.uniform(12, 20) * 3600.0   # afternoon/evening
        length = rng.uniform(1.5, 3.5) * 3600.0
        amp = rng.uniform(22, 55)
        ramp = rng.uniform(0.15, 0.3) * length
        rel = t - start
        up = np.clip(rel / ramp, 0, 1)
        down = np.clip((length - rel) / ramp, 0, 1)
        surge += amp * np.clip(np.minimum(up, down), 0, 1)
    demand = np.maximum(base + surge, 1.0)
    demand *= peak_vms / demand.max()                        # exact peak
    demand = np.maximum(np.round(demand).astype(int), 1)
    # Compress to change points.
    out: List[Tuple[float, int]] = [(0.0, int(demand[0]))]
    for i in range(1, len(t)):
        if demand[i] != out[-1][1]:
            out.append((float(t[i]), int(demand[i])))
    return out


def scale_ws(trace: List[Tuple[float, int]], prc: int,
             prc0: int = 64) -> List[Tuple[float, int]]:
    """Scale a WS demand trace to peak ``prc`` (constant factor, §6.3).
    Upscale round trips exactly: ``scale_ws(scale_ws(tr, prc, prc0),
    prc0, prc)`` reproduces the original series for ``prc >= prc0``
    (distinct demands stay distinct, so no change points merge — see
    :func:`_scale_count`)."""
    out: List[Tuple[float, int]] = []
    for t, d in trace:
        nd = _scale_count(d, prc, prc0)
        if not out or nd != out[-1][1]:
            out.append((t, nd))
    return out


# On-device generator family (JAX) — lazily forwarded so this module
# stays importable with numpy alone (repro.sim promises traces-without-
# jax); the generators live in repro.sim.scenarios.
_SCENARIO_NAMES = ("PBJParams", "WSParams", "ScenarioGrid",
                   "SynthesizedBatch", "NASA_IPSC_PBJ", "SDSC_BLUE_PBJ",
                   "WORLDCUP_WS", "synth_pbj", "synth_ws", "lane_keys",
                   "synthesize", "pack_scenarios", "sample_workloads")


def __getattr__(name: str):
    if name in _SCENARIO_NAMES:
        from repro.sim import scenarios
        return getattr(scenarios, name)
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
