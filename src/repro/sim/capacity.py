"""Capacity-planning query layer — from evaluation to optimization.

The paper's headline results are *optimization* answers stated over the
very grids the sweep engines batch: §6.5.3's "a ≈40 % smaller cluster
configuration at the same throughput" is the argmin of capacity C
subject to a completion SLO, and §6.6.3's "up to 31 % lower peak than
EC2+RightScale" compares the optima of two systems. This module turns
``run_sweep_workloads`` into the query engine for such questions:

* :func:`min_capacity` — vectorized bisection for "the minimum capacity
  meeting a throughput/completion SLO". Every bisection iteration runs
  as ONE ``run_sweep_workloads`` batch over all still-active
  (template × workload) lanes: the candidate midpoints of every
  unconverged lane are packed into a single point list (converged lanes
  contribute nothing — they are masked out of the batch), so a grid of
  K templates over W workloads converges in ~log2(hi − lo) batched
  calls instead of (hi − lo) · K · W single evaluations. Composes with
  ``mode="rounds"`` (the batched event-round engine) and
  ``ScanOptions.devices`` sharding like any other sweep.

* :func:`pareto_front` — the non-dominated set of a (C, B, L,
  kill-threshold) policy grid under a configurable objective tuple
  (default: minimize node-hours and peak nodes, maximize completed
  jobs), with the dominating policy recorded for every dominated point.

* :class:`CostModel` / :class:`CostEstimate` — a multi-cloud cost lens:
  per-provider $/node-hour plus a per-adjustment request cost (every
  ``adjust_events`` ledger entry is one provisioning-API round-trip —
  see :func:`repro.core.baselines.billable_requests`), seeded with an
  EC2-on-demand-shaped default. Prices any sweep row, workload mix or
  Pareto frontier and answers "cheapest provider for this mix".

* :func:`headline_queries` — the paper's two §6 numbers reproduced *as
  query outputs* and gated against
  ``repro.sim.contracts.HEADLINE_CONTRACT``.

Monotonicity caveat: bisection assumes SLO feasibility is monotone in
the capacity knob — true at the thresholds the paper sweeps, but the
raw ``completed_jobs`` curve is not perfectly monotone (kill
tie-breaking can cost a job as C grows: FB(133) completes 2528 of the
iPSC trace, FB(134) completes 2527). The guarantee :func:`min_capacity`
makes — and tests/test_capacity.py asserts — is therefore the local
one: the returned capacity is feasible AND its predecessor is
infeasible. Where the feasibility curve has multiple crossings the
query returns one valid crossing, exactly like scalar ``bisect`` on a
non-sorted list.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.baselines import billable_requests
from repro.core.jobs import Job
from repro.sim.sweep import (ScanOptions, SweepPoint, run_sweep_workloads)

__all__ = ["CapacitySLO", "CapacityResult", "CapacityReport",
           "min_capacity", "ParetoPoint", "ParetoFront", "pareto_front",
           "ProviderRate", "CostEstimate", "CostModel",
           "DEFAULT_PROVIDERS", "headline_queries"]


# ------------------------------------------------------------------ SLOs

@dataclasses.dataclass(frozen=True)
class CapacitySLO:
    """A service-level objective a capacity must meet.

    At least one criterion is required; all given criteria must hold
    simultaneously. ``min_completed`` is an absolute completed-job
    floor, ``min_completed_frac`` a fraction of the workload's job
    count (both are throughput statements — completed jobs over the
    shared §6.1 horizon), ``max_avg_turnaround`` an average-turnaround
    ceiling in seconds (J1 of §6.3).
    """

    min_completed: Optional[int] = None
    min_completed_frac: Optional[float] = None
    max_avg_turnaround: Optional[float] = None

    def __post_init__(self):
        if (self.min_completed is None and self.min_completed_frac is None
                and self.max_avg_turnaround is None):
            raise ValueError("empty SLO: set min_completed, "
                             "min_completed_frac or max_avg_turnaround")
        if (self.min_completed_frac is not None
                and not 0.0 < self.min_completed_frac <= 1.0):
            raise ValueError(
                f"min_completed_frac must be in (0, 1], got "
                f"{self.min_completed_frac}")

    def target_completed(self, n_jobs: int) -> Optional[int]:
        """The effective completed-job floor for a workload of
        ``n_jobs`` jobs (the max of both throughput criteria)."""
        targets = []
        if self.min_completed is not None:
            targets.append(int(self.min_completed))
        if self.min_completed_frac is not None:
            targets.append(int(math.ceil(self.min_completed_frac * n_jobs)))
        return max(targets) if targets else None

    def satisfied(self, row: Dict, n_jobs: int) -> bool:
        """Does a sweep row meet every criterion?"""
        target = self.target_completed(n_jobs)
        if target is not None:
            if "completed_jobs" not in row:
                raise ValueError(
                    f"row for {row.get('system', '?')} carries no "
                    f"completed_jobs (vectorized DCS rows are cost/peak "
                    f"only) — evaluate DCS templates with mode='event'")
            if int(row["completed_jobs"]) < target:
                return False
        if self.max_avg_turnaround is not None:
            if "avg_turnaround" not in row:
                raise ValueError(
                    f"row for {row.get('system', '?')} carries no "
                    f"avg_turnaround — use mode='event' for this "
                    f"template")
            if float(row["avg_turnaround"]) > self.max_avg_turnaround:
                return False
        return True

    def describe(self, n_jobs: int) -> str:
        parts = []
        target = self.target_completed(n_jobs)
        if target is not None:
            parts.append(f"completed_jobs >= {target}")
        if self.max_avg_turnaround is not None:
            parts.append(f"avg_turnaround <= {self.max_avg_turnaround}")
        return " and ".join(parts)


# ------------------------------------------------- the capacity knob

def _with_capacity(template: SweepPoint, c: int) -> SweepPoint:
    """The template at capacity-knob value ``c``: FB's cluster size C,
    FLB-NUB's total pool B = lb_pbj + lb_ws (the template's ``lb_ws``
    caps the WS share, clamped to keep lb_pbj >= 1 — mirroring
    ``paper_grid``'s ``min(lb_ws, B - 1)``), DCS's batch partition
    PRC_PBJ (the web partition stays the template's)."""
    c = int(c)
    if template.system == "fb":
        return dataclasses.replace(template, capacity=c, label="")
    if template.system == "flb_nub":
        w = min(template.lb_ws, max(c - 1, 0))
        return dataclasses.replace(template, lb_pbj=c - w, lb_ws=w,
                                   label="")
    if template.system == "dcs":
        return dataclasses.replace(template, prc_pbj=c, label="")
    raise ValueError(
        f"system {template.system!r} has no capacity knob to bisect "
        f"(EC2+RightScale sizes itself from demand — compare it as a "
        f"baseline row instead)")


def _validate_templates(templates: Sequence[SweepPoint], mode: str):
    for t in templates:
        if t.system == "ec2":
            _with_capacity(t, 1)        # raises with the explanation
        if t.system == "dcs" and mode != "event":
            raise ValueError(
                "DCS templates need mode='event': the vectorized DCS "
                "path computes cost/peak only, and an SLO query needs "
                "completed_jobs")


# ----------------------------------------------------------- bisection

@dataclasses.dataclass(frozen=True)
class CapacityResult:
    """One lane's answer: the minimal feasible capacity-knob value."""

    template: SweepPoint
    template_index: int
    workload: int
    capacity: int                     # minimal feasible knob value
    point: SweepPoint                 # template at that capacity
    row: Dict                         # sweep row at that capacity
    at_grid_edge: bool                # True when capacity == lo (the
    #                                   predecessor was never probed)


@dataclasses.dataclass(frozen=True)
class CapacityReport:
    """A :func:`min_capacity` answer plus its evaluation ledger.

    ``results`` holds one :class:`CapacityResult` per
    (template × workload) lane, workload-major. ``rows_evaluated``
    counts every (point × workload) sweep row the query computed across
    its batches; ``brute_force_rows`` is what a full grid scan of the
    same interval would have cost — the ratio is the query's win and
    the ``benchmarks.run capacity`` ledger records both.
    """

    slo: CapacitySLO
    lo: int
    hi: int
    results: List[CapacityResult]
    iterations: int                   # batched sweep calls issued
    rows_evaluated: int
    brute_force_rows: int

    def result(self, template_index: int = 0,
               workload: int = 0) -> CapacityResult:
        for r in self.results:
            if (r.template_index == template_index
                    and r.workload == workload):
                return r
        raise KeyError((template_index, workload))


def _normalize_workloads(workloads):
    """Accept either one ``(jobs, ws_trace)`` pair or a sequence of
    them (the ``run_sweep_workloads`` shape)."""
    if (len(workloads) == 2 and workloads[0] is not None
            and all(isinstance(j, Job) for j in workloads[0])
            and not isinstance(workloads[1], Job)):
        return [(list(workloads[0]), list(workloads[1]))]
    return [(list(jobs), list(ws)) for jobs, ws in workloads]


def _ws_peak(ws_trace) -> int:
    return max((int(d) for _, d in ws_trace), default=0)


def min_capacity(templates: Union[SweepPoint, Sequence[SweepPoint]],
                 workloads, slo: CapacitySLO, *,
                 lo: int = 1, hi: int,
                 duration: Optional[float] = None,
                 mode: str = "rounds",
                 scan_options: ScanOptions = ScanOptions(),
                 devices=None, _stack_offset: int = 0) -> CapacityReport:
    """Minimum capacity meeting ``slo``, for every (template × workload)
    lane at once, by batched bisection over the knob interval
    ``[lo, hi]``.

    ``templates`` are :class:`SweepPoint`\\ s whose capacity knob the
    query owns (FB's C, FLB-NUB's pool B, DCS's PRC_PBJ — see
    :func:`_with_capacity`); every other field (lease, U/V/G policy
    params, the DCS web partition) is held fixed, so passing several
    templates sweeps (policy × lease) lanes jointly. ``workloads`` is
    one ``(jobs, ws_trace)`` pair or a list of them.

    The first batch probes ``lo`` and ``hi`` for every lane. A lane
    infeasible at ``hi`` has an *empty* bisection interval — the SLO
    cannot be met on this grid — and raises :class:`ValueError`
    immediately (naming the lane, the shortfall, and the WS-trace peak
    when ``hi`` sits below it: a pool smaller than the web demand peak
    saturates silently and no capacity in the interval can win it
    back). A lane already feasible at ``lo`` returns the grid edge
    (``at_grid_edge=True`` — the predecessor was never probed). Every
    following iteration packs the unconverged lanes' midpoints into one
    ``run_sweep_workloads`` call; converged lanes drop out of the
    batch. Returns a :class:`CapacityReport` whose per-lane results
    satisfy: ``row`` feasible, and capacity−1 infeasible (unless at the
    grid edge).
    """
    if isinstance(templates, SweepPoint):
        templates = [templates]
    templates = list(templates)
    if not templates:
        raise ValueError("min_capacity needs at least one template")
    lo, hi = int(lo), int(hi)
    if lo < 1:
        raise ValueError(f"lo must be >= 1, got {lo}")
    if hi < lo:
        raise ValueError(f"empty capacity interval: hi={hi} < lo={lo}")
    _validate_templates(templates, mode)
    wls = _normalize_workloads(workloads)
    n_jobs = [len(jobs) for jobs, _ in wls]
    W, T = len(wls), len(templates)

    cache: Dict[Tuple[int, int], Dict] = {}   # (ti, c) -> rows per wl
    ledger = {"batches": 0, "rows": 0}

    def evaluate(caps_by_t: Dict[int, set]):
        """ONE sweep batch for all (template, capacity) pairs not yet
        cached; rows land in ``cache`` keyed (ti, c) -> [row per
        workload]."""
        pts, index = [], []
        for ti in sorted(caps_by_t):
            for c in sorted(caps_by_t[ti]):
                if (ti, c) not in cache:
                    pts.append(_with_capacity(templates[ti], c))
                    index.append((ti, c))
        if not pts:
            return
        # 2 frames here (this closure + min_capacity itself), plus any
        # wrappers above us — diagnostics name the user's call site.
        rows = run_sweep_workloads(pts, wls, duration, mode=mode,
                                   scan_options=scan_options,
                                   devices=devices,
                                   _stack_offset=2 + _stack_offset)
        ledger["batches"] += 1
        ledger["rows"] += len(pts) * W
        for k, key in enumerate(index):
            cache[key] = [rows[w][k] for w in range(W)]

    def feasible(ti: int, wi: int, c: int) -> bool:
        return slo.satisfied(cache[(ti, c)][wi], n_jobs[wi])

    # Bracket batch: lo and hi for every template, all lanes at once.
    evaluate({ti: {lo, hi} for ti in range(T)})

    infeasible_lanes = []
    # Per-lane bisection state: None once converged, else
    # (known_bad, known_good) with known_bad infeasible, known_good
    # feasible, answer in (known_bad, known_good].
    state: Dict[Tuple[int, int], Optional[Tuple[int, int]]] = {}
    answer: Dict[Tuple[int, int], int] = {}
    for ti in range(T):
        for wi in range(W):
            if not feasible(ti, wi, hi):
                row = cache[(ti, hi)][wi]
                got = row.get("completed_jobs")
                peak = _ws_peak(wls[wi][1])
                hint = (f"; note hi={hi} is below the WS trace peak "
                        f"{peak} — the web lane saturates and no "
                        f"capacity in the interval can meet the SLO"
                        if hi < peak else "")
                infeasible_lanes.append(
                    f"{_with_capacity(templates[ti], hi).name()} × "
                    f"workload {wi}: "
                    f"completed {got} at capacity {hi}, SLO needs "
                    f"{slo.describe(n_jobs[wi])}{hint}")
            elif feasible(ti, wi, lo):
                answer[(ti, wi)] = lo
                state[(ti, wi)] = None
            else:
                state[(ti, wi)] = (lo, hi)
    if infeasible_lanes:
        raise ValueError(
            "SLO infeasible at the top of the capacity interval "
            "(empty bisection interval) on "
            f"{len(infeasible_lanes)} lane(s):\n  "
            + "\n  ".join(infeasible_lanes)
            + "\nRaise hi or relax the SLO.")

    # Bisection: one batched sweep per iteration over the union of
    # active lanes' midpoints (converged lanes contribute nothing).
    while True:
        mids: Dict[int, set] = {}
        lane_mid = {}
        for lane, st in state.items():
            if st is None:
                continue
            bad, good = st
            if good - bad <= 1:
                answer[lane] = good
                state[lane] = None
                continue
            mid = (bad + good) // 2
            lane_mid[lane] = mid
            mids.setdefault(lane[0], set()).add(mid)
        if not lane_mid:
            break
        evaluate(mids)
        for lane, mid in lane_mid.items():
            bad, good = state[lane]
            if feasible(lane[0], lane[1], mid):
                state[lane] = (bad, mid)
            else:
                state[lane] = (mid, good)

    results = [CapacityResult(
        template=templates[ti], template_index=ti, workload=wi,
        capacity=answer[(ti, wi)],
        point=_with_capacity(templates[ti], answer[(ti, wi)]),
        row=cache[(ti, answer[(ti, wi)])][wi],
        at_grid_edge=answer[(ti, wi)] == lo)
        for wi in range(W) for ti in range(T)]
    return CapacityReport(
        slo=slo, lo=lo, hi=hi, results=results,
        iterations=ledger["batches"], rows_evaluated=ledger["rows"],
        brute_force_rows=(hi - lo + 1) * T * W)


# ------------------------------------------------------- Pareto front

# Optimization sense per objective: +1 minimizes, -1 maximizes.
_SENSES = {"node_hours": 1.0, "peak_nodes": 1.0, "avg_turnaround": 1.0,
           "avg_execution": 1.0, "adjust_events": 1.0, "kills": 1.0,
           "completed_jobs": -1.0, "throughput": -1.0}


@dataclasses.dataclass(frozen=True)
class ParetoPoint:
    """One grid point of a :class:`ParetoFront`. ``dominated_by`` is
    the index of a frontier point that dominates it (the first such in
    frontier order), or ``None`` when the point is itself on the
    frontier."""

    index: int
    point: Optional[SweepPoint]
    row: Dict
    dominated_by: Optional[int]

    @property
    def on_frontier(self) -> bool:
        return self.dominated_by is None


@dataclasses.dataclass(frozen=True)
class ParetoFront:
    """The non-dominated set of a policy grid under ``objectives``."""

    objectives: Tuple[str, ...]
    points: List[ParetoPoint]
    frontier: Tuple[int, ...]         # indices into ``points``

    def frontier_points(self) -> List[ParetoPoint]:
        return [self.points[i] for i in self.frontier]

    def frontier_rows(self) -> List[Dict]:
        return [self.points[i].row for i in self.frontier]


def pareto_front(points: Optional[Sequence[SweepPoint]] = None,
                 jobs: Optional[Sequence[Job]] = None,
                 ws_trace=None, *,
                 rows: Optional[Sequence[Dict]] = None,
                 objectives: Sequence[str] = ("node_hours", "peak_nodes",
                                              "completed_jobs"),
                 duration: Optional[float] = None,
                 mode: Optional[str] = None,
                 scan_options: ScanOptions = ScanOptions(),
                 devices=None) -> ParetoFront:
    """Non-dominated set of a policy grid.

    Either pass ``points`` + ``jobs`` + ``ws_trace`` (the grid is
    evaluated through :func:`run_sweep_workloads` — one batch) or
    pre-computed ``rows`` (any row dicts, e.g. a sweep already paid
    for; ``points`` then just labels them). ``objectives`` picks the
    metric tuple; senses come from the metric's meaning (node-hours,
    peak, turnaround, kills and adjust-events minimize; completed jobs
    / throughput maximize). A point dominates another when it is no
    worse on every objective and strictly better on at least one; ties
    on all objectives leave both points on the frontier.
    """
    objectives = tuple(objectives)
    for m in objectives:
        if m not in _SENSES:
            raise ValueError(
                f"unknown objective {m!r}; known: {sorted(_SENSES)}")
    if rows is None:
        if points is None or jobs is None or ws_trace is None:
            raise ValueError(
                "pass either rows=... or points + jobs + ws_trace")
        rows = run_sweep_workloads(list(points), [(jobs, ws_trace)],
                                   duration, mode=mode,
                                   scan_options=scan_options,
                                   devices=devices, _stack_offset=1)[0]
    rows = list(rows)
    if not rows:
        raise ValueError("empty grid")
    pts = list(points) if points is not None else [None] * len(rows)
    if len(pts) != len(rows):
        raise ValueError(f"{len(pts)} points vs {len(rows)} rows")

    key = "completed_jobs" if "throughput" in objectives else None
    mat = np.empty((len(rows), len(objectives)))
    for i, row in enumerate(rows):
        for j, m in enumerate(objectives):
            k = key if m == "throughput" else m
            if k not in row:
                raise ValueError(
                    f"row {i} ({row.get('system', '?')}) has no {k!r} "
                    f"metric — vectorized DCS rows are cost/peak only; "
                    f"evaluate that point with mode='event'")
            mat[i, j] = _SENSES[m] * float(row[k])

    # i dominates j: <= everywhere and < somewhere (minimizing view).
    le = (mat[:, None, :] <= mat[None, :, :]).all(axis=-1)
    lt = (mat[:, None, :] < mat[None, :, :]).any(axis=-1)
    dominates = le & lt
    dominated = dominates.any(axis=0)
    frontier = tuple(int(i) for i in np.flatnonzero(~dominated))

    out = []
    for j in range(len(rows)):
        dom_by = None
        if dominated[j]:
            for i in frontier:
                if dominates[i, j]:
                    dom_by = i
                    break
        out.append(ParetoPoint(index=j, point=pts[j], row=rows[j],
                               dominated_by=dom_by))
    return ParetoFront(objectives=objectives, points=out,
                       frontier=frontier)


# ----------------------------------------------------------- cost lens

@dataclasses.dataclass(frozen=True)
class ProviderRate:
    """One provider's pricing: $/node-hour plus $ per provisioning-API
    request (each ``adjust_events`` ledger entry is one request)."""

    name: str
    node_hour_usd: float
    request_usd: float = 0.0

    def __post_init__(self):
        if self.node_hour_usd < 0 or self.request_usd < 0:
            raise ValueError(f"negative rate for {self.name!r}")


@dataclasses.dataclass(frozen=True)
class CostEstimate:
    """Priced usage: ``total_usd = node_hours·node_hour_usd +
    requests·request_usd``. Estimates for the same provider add
    (workload mixes sum their usage)."""

    provider: str
    node_hours: float
    requests: int
    node_hour_usd: float
    request_usd: float

    @property
    def node_cost_usd(self) -> float:
        return self.node_hours * self.node_hour_usd

    @property
    def request_cost_usd(self) -> float:
        return self.requests * self.request_usd

    @property
    def total_usd(self) -> float:
        return self.node_cost_usd + self.request_cost_usd

    def __add__(self, other: "CostEstimate") -> "CostEstimate":
        if not isinstance(other, CostEstimate):
            return NotImplemented
        if (other.provider != self.provider
                or other.node_hour_usd != self.node_hour_usd
                or other.request_usd != self.request_usd):
            raise ValueError(
                f"cannot add estimates priced under different rates "
                f"({self.provider!r} vs {other.provider!r})")
        return dataclasses.replace(
            self, node_hours=self.node_hours + other.node_hours,
            requests=self.requests + other.requests)


# Stylized 2010-era list-price shapes (the paper's EC2 baseline era:
# an m1.small was $0.085/h on demand, ~$0.031/h effective 3-yr
# reserved). Illustrative defaults, not quotes — pass your own
# ProviderRate tuple for real pricing.
DEFAULT_PROVIDERS: Tuple[ProviderRate, ...] = (
    ProviderRate("ec2-on-demand", node_hour_usd=0.085,
                 request_usd=0.0005),
    ProviderRate("ec2-reserved", node_hour_usd=0.031,
                 request_usd=0.0005),
    ProviderRate("azure-classic", node_hour_usd=0.096,
                 request_usd=0.0),
    ProviderRate("gogrid", node_hour_usd=0.19, request_usd=0.0),
    ProviderRate("private-amortized", node_hour_usd=0.045,
                 request_usd=0.0),
)


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Multi-cloud cost lens over sweep rows.

    ``estimate`` prices one row under one provider; ``estimate_mix``
    sums a workload mix; ``compare`` prices the same usage under every
    provider, cheapest first, so ``compare(...)[0]`` answers "cheapest
    provider for this workload mix"; ``price_frontier`` prices every
    point of a :class:`ParetoFront`'s frontier.
    """

    providers: Tuple[ProviderRate, ...] = DEFAULT_PROVIDERS

    def __post_init__(self):
        if not self.providers:
            raise ValueError("CostModel needs at least one provider")
        names = [p.name for p in self.providers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate provider names in {names}")

    def rate(self, provider: Optional[str] = None) -> ProviderRate:
        if provider is None:
            return self.providers[0]
        for p in self.providers:
            if p.name == provider:
                return p
        raise ValueError(
            f"unknown provider {provider!r}; have "
            f"{[p.name for p in self.providers]}")

    @staticmethod
    def _usage(row) -> Tuple[float, int]:
        nh = float(row["node_hours"]) if isinstance(row, dict) \
            else float(getattr(row, "node_hours"))
        return nh, billable_requests(row)

    def estimate(self, row,
                 provider: Optional[str] = None) -> CostEstimate:
        r = self.rate(provider)
        nh, req = self._usage(row)
        return CostEstimate(provider=r.name, node_hours=nh,
                            requests=req, node_hour_usd=r.node_hour_usd,
                            request_usd=r.request_usd)

    def estimate_mix(self, rows,
                     provider: Optional[str] = None) -> CostEstimate:
        r = self.rate(provider)
        est = CostEstimate(provider=r.name, node_hours=0.0, requests=0,
                           node_hour_usd=r.node_hour_usd,
                           request_usd=r.request_usd)
        for row in rows:
            est = est + self.estimate(row, r.name)
        return est

    def compare(self, rows) -> List[CostEstimate]:
        """Price the same usage under every provider, cheapest first
        (ties keep provider-table order). ``rows`` is one row or a
        mix."""
        if isinstance(rows, dict) or hasattr(rows, "node_hours"):
            rows = [rows]
        ests = [self.estimate_mix(rows, p.name) for p in self.providers]
        return sorted(ests, key=lambda e: e.total_usd)

    def cheapest(self, rows) -> CostEstimate:
        return self.compare(rows)[0]

    def price_frontier(self, front: ParetoFront,
                       provider: Optional[str] = None
                       ) -> List[Tuple[int, CostEstimate]]:
        return [(i, self.estimate(front.points[i].row, provider))
                for i in front.frontier]


# ----------------------------------------------------- headline queries

def headline_queries(*, tiny: bool = False, mode: str = "rounds",
                     scan_options: ScanOptions = ScanOptions(),
                     devices=None) -> Dict:
    """The paper's two §6 claims answered as capacity queries.

    **Private cloud (§6.5.3 / Fig. 13):** how much smaller a cluster
    does the FB PhoenixCloud system need than the dedicated DCS
    partition, at the *same* completed-job throughput? Computed as
    ``1 − min_capacity(FB, SLO=DCS throughput) / DCS size`` on the
    moment-matched iPSC/860 + WorldCup'98 pair. Paper: ≈40 %.

    **Public cloud (§6.6.3):** how much lower is FLB-NUB's peak
    resource consumption than the EC2+RightScale baseline on the same
    workload? Computed as ``1 − peak(FLB-NUB) / peak(EC2)``. Paper: up
    to 31 %.

    Full-size numbers are gated against
    ``repro.sim.contracts.HEADLINE_CONTRACT`` (violations land in the
    returned dict, they do not raise). ``tiny=True`` shrinks to the CI
    two-day slice — the query plumbing runs end-to-end but the horizon
    is far off §6.1's two weeks, so the band gate is skipped and
    ``gate['checked']`` is False.
    """
    from repro.sim import traces
    from repro.sim.contracts import HEADLINE_CONTRACT

    if tiny:
        horizon = 2 * 24 * 3600.0
        peak_vms = 64
        prc_pbj = prc_ws = 64
        jobs = [j for j in traces.nasa_ipsc(seed=0) if j.submit < horizon]
        ws = [(t, d) for t, d in traces.worldcup98(seed=0,
                                                   peak_vms=peak_vms)
              if t < horizon]
        flb_B, ec2_lease = 25, 3600.0
    else:
        horizon = traces.TWO_WEEKS
        prc_pbj = prc_ws = 128
        jobs = traces.nasa_ipsc(seed=0)
        ws = traces.worldcup98(seed=0, peak_vms=128)
        flb_B, ec2_lease = 25, 3600.0

    dcs_size = prc_pbj + prc_ws

    # Private cloud: DCS reference throughput needs completed_jobs, so
    # the single DCS row runs the event engine; the FB bisection lanes
    # batch through the requested fast path.
    dcs_row = run_sweep_workloads(
        [SweepPoint("dcs", prc_pbj=prc_pbj, prc_ws=prc_ws)],
        [(jobs, ws)], horizon, mode="event", _stack_offset=1)[0][0]
    target = int(dcs_row["completed_jobs"])
    report = min_capacity(
        SweepPoint("fb"), (jobs, ws),
        CapacitySLO(min_completed=target),
        lo=1, hi=dcs_size, duration=horizon, mode=mode,
        scan_options=scan_options, devices=devices, _stack_offset=1)
    fb = report.results[0]
    config_reduction = 1.0 - fb.capacity / dcs_size

    # Public cloud: FLB-NUB vs the EC2+RightScale baseline at the
    # paper's Fig. 14 pool size; EC2 rows ride the exact vectorized
    # path in every non-event mode.
    w = min(12, flb_B - 1)
    flb_row, ec2_row = run_sweep_workloads(
        [SweepPoint("flb_nub", lb_pbj=flb_B - w, lb_ws=w),
         SweepPoint("ec2", lease_seconds=ec2_lease)],
        [(jobs, ws)], horizon, mode=mode, scan_options=scan_options,
        devices=devices, _stack_offset=1)[0]
    peak_reduction = 1.0 - (float(flb_row["peak_nodes"])
                            / float(ec2_row["peak_nodes"]))

    violations = [] if tiny else HEADLINE_CONTRACT.check(
        config_reduction, peak_reduction)
    return {
        "tiny": tiny,
        "private": {
            "dcs_size": dcs_size,
            "dcs_completed": target,
            "min_fb_capacity": fb.capacity,
            "fb_completed": int(fb.row["completed_jobs"]),
            "config_reduction": round(config_reduction, 4),
            "iterations": report.iterations,
            "rows_evaluated": report.rows_evaluated,
            "brute_force_rows": report.brute_force_rows,
        },
        "public": {
            "flb_B": flb_B,
            "flb_peak": int(flb_row["peak_nodes"]),
            "ec2_peak": int(ec2_row["peak_nodes"]),
            "peak_reduction": round(peak_reduction, 4),
        },
        "gate": {
            "checked": not tiny,
            "contract": dataclasses.asdict(HEADLINE_CONTRACT),
            "violations": violations,
            "ok": not violations,
        },
    }
