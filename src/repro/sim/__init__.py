from repro.sim.simulator import (SimResult, build_dcs, build_ec2_rightscale,
                                 build_fb, build_flb_nub, run_sim)
from repro.sim.traces import (TraceSpec, nasa_ipsc, scale_jobs, sdsc_blue,
                              worldcup98)

__all__ = [
    "SimResult", "run_sim", "build_dcs", "build_fb", "build_flb_nub",
    "build_ec2_rightscale", "TraceSpec", "nasa_ipsc", "sdsc_blue",
    "worldcup98", "scale_jobs",
]
