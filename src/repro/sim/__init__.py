from repro.sim.engine import (SimResult, build_dcs, build_ec2_rightscale,
                              build_fb, build_flb_nub, clone_jobs, run_sim)
from repro.sim.traces import (TraceSpec, nasa_ipsc, scale_jobs, sdsc_blue,
                              worldcup98)

__all__ = [
    "SimResult", "run_sim", "clone_jobs", "build_dcs", "build_fb",
    "build_flb_nub", "build_ec2_rightscale", "SweepPoint", "ScanOptions",
    "run_sweep", "run_sweep_workloads", "paper_grid", "TraceSpec",
    "nasa_ipsc", "sdsc_blue", "worldcup98", "scale_jobs",
    "CapacitySLO", "CapacityReport", "min_capacity", "pareto_front",
    "ParetoFront", "CostModel", "CostEstimate", "ProviderRate",
    "headline_queries",
]

_SWEEP_NAMES = ("SweepPoint", "ScanOptions", "run_sweep",
                "run_sweep_workloads", "paper_grid")
_CAPACITY_NAMES = ("CapacitySLO", "CapacityResult", "CapacityReport",
                   "min_capacity", "ParetoPoint", "ParetoFront",
                   "pareto_front", "ProviderRate", "CostEstimate",
                   "CostModel", "DEFAULT_PROVIDERS", "headline_queries")


def __getattr__(name):
    # Lazy: the sweep engine (and the capacity query layer on top of
    # it) pulls in jax; the event engine and traces stay importable
    # with numpy alone.
    if name in _SWEEP_NAMES:
        from repro.sim import sweep
        return getattr(sweep, name)
    if name in _CAPACITY_NAMES:
        from repro.sim import capacity
        return getattr(capacity, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
