"""Event-round fast path: jump-to-next-event steps for the stateful
PhoenixCloud policies.

The fixed-``dt`` scan (``repro.sim.scan``) advances every lane by the
same substep whether or not anything happens in it, and rounds
completions to the nearest substep. This module replaces the time grid
with *event rounds*: each step of the jitted loop computes the next
event horizon per lane —

    ``b = min(next submit, earliest completion among running lanes,
              next WS change the policy can react to,
              next lease boundary L·(⌊t/L⌋+1))``

— advances straight to it, and fires the policy tick only when the step
lands on a lease boundary (the lease axis L stays *traced*, so Fig. 18
sweeps it inside the batch). Completions happen at their exact times
(``start + runtime``, no nearest-substep rounding) and every allocation
interval integrates exactly, so the scan's 15 % fidelity contract
collapses to the policy-approximation residue alone (first-fit pass
convergence and FB kill tie-breaking): completed jobs match the event
engine *exactly* and node-hours/peak stay within 5 % on the paper
grids.

What counts as an event (the step-count economics)
--------------------------------------------------

A naive event list (every submit, completion and WS change) is *denser*
than the scan's substep grid on the paper traces — the World Cup demand
profile alone changes ~2.8k times in two weeks. The engine therefore
jumps over every event whose effect is computable without stopping:

* **WS demand changes** never stop a lane. The WS share of the
  allocation is policy-independent, so its node-hour integral and its
  per-lease-window maxima are precomputed host-side per sweep point
  (``∫min(ws, C)`` for FB, ``∫max(ws − lb_ws, 0)`` and per-tick-window
  maxima for FLB-NUB's peak), and the loop samples the instantaneous
  demand with one binary search when a round needs it (FB reclaim, the
  FLB pool flow at ticks). Only FB demand *rises* remain stops — §5.1
  rule 3 reclaims (and kills) the moment demand grows — which also
  keeps the between-stops demand monotone falling, making the per-stop
  peak probe exact.
* **Submits** skip whenever they provably start on time: if the queue
  is empty and the summed size of every submit in the horizon fits in
  the currently free capacity (a conservative bound — completions
  inside the horizon only add slack), each submitting lane starts
  *retroactively* at its exact submit time. Contended submits fall back
  to one round per event.
* **Completions** stop a lane only while the queue is non-empty (a
  finish can then start queued jobs); with an empty queue they fold
  retroactively at the next round, at their exact times.

What remains is one round per lease tick plus the contended stretches —
on the paper grids ~3-6× fewer steps than the scan's substep count, and
each round is cheaper (no per-substep WS profile, a smaller window).
On demand traces finer than the scan's ``FLB_MIN_DT`` floor the gap
widens by another order of magnitude.

The contended-stretch coalescer (``ScanOptions(coalesce=k)``)
----------------------------------------------------------------

Long queued periods drain one completion per round above — the
dominant remaining round count on capacity-bound grids. With
coalescing enabled, one round absorbs up to ``k`` such events via a
loop-free bulk section: the next ``k`` distinct completion instants
among running lanes are extracted as iterated masked mins (a sorted
masked top-k; a ``lax.top_k`` sort probe measured ~6× the whole
section's cost on XLA:CPU), queue admissions at each instant resolve
through a prefix-sum feasibility test (arrival order is lane order, so
a pending job starts at the first instant whose cumulative freed mass
covers the pending jobs ahead of it plus itself, or at its own submit
time), and the policy-owned allocation integral needs no per-instant
work at all (the share is constant across a stretch — FB reclaims only
at rises, which bound the horizon; FLB adjusts only at ticks). The
closed form is proven exact per round or abandoned mid-round: a
possible first-fit leapfrog (an unstarted pending job that fits a
conservatively over-estimated free capacity at a replayed instant or
at its own arrival), a chain event (a batch-started job completing
inside the round), or the ``k`` cap each end the round exactly AT the
first such instant, where the ordinary tail replays it with the full
``ff_passes`` first-fit and the §5.1 kill machinery — so coalesced
results carry the SAME fidelity contract as uncoalesced rounds (the
differential suite pins bit-equality of the job metrics).

Honest perf ledger: the bulk work is masked, not branched — vmapped
point-lanes run in lockstep, so every round pays it whether or not a
stretch is underway. On the 2-core CI box that tax exceeds what the
saved rounds return on the paper-density grids (max rounds/lane drops
6258 → 4047 yet wall-clock roughly doubles at k = 8 — see the
``rounds_coalesced`` column of results/BENCH_sweep.json), which is
why ``DEFAULT_BATCH = 1`` leaves the coalescer OFF unless requested.
The reduction in *rounds* — the lockstep depth — is the real asset:
it pays where per-round cost is dominated by the lane width (wide
accelerator batches) or where traces make event rounds sparse and
stretches long.

The queue/kill machinery is shared with the scan engine: the same
fixed-size job window with status lanes, vectorized first-fit and §5.1
size-class kill selection (``repro.sim.scan.fb_actions`` /
``flb_actions``), with lanes carrying an absolute ``end_t`` instead of
a decremented remaining time — what makes completions exact and FB
kill-restarts trivially correct (a restart rewrites ``end_t``).

Loop structure: an outer ``while_loop`` step compacts the window (one
stacked lane gather — the only data-movement op, amortized) and admits
fresh job-table rows as contiguous ``dynamic_slice`` reads; an inner
unrolled block runs ``compact_every`` event rounds of pure elementwise/
reduction work. Lanes that reach the horizon self-mask (``b = t``) and
the outer loop exits once every lane is done.

Tie order at one timestamp replays the event engine's kinds (WS demand
→ lease tick → submit → finish) except for exact-float coincidences of
a completion or a skipped submit with a tick, which fold before the
tick's policy actions instead of around them — a measure-zero
coincidence on real-valued traces.

With ``devices`` set, the flattened (point × trace) lane axis shards
across host devices exactly like the scan path (the shared
``sharded_grid_map``); each lane runs the identical per-lane program,
so sharded rows are bit-identical to single-device rows.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.core.jobs import Job
from repro.core.profiles import step_points
from repro.sim.scan import (FBGrid, FLBGrid, _prm_tree, _size_classes,
                            fb_actions, flb_actions, pack_job_table,
                            resolve_pack_dtype, sharded_grid_map,
                            stable_compact)

__all__ = [
    "PackedEventWorkloads", "RoundsSpec", "pack_event_workloads",
    "rounds_grids", "round_budget", "ws_fold_tables_batch",
    "fb_rounds_row",
    "fold_table_cache_info", "fold_table_cache_clear",
    "FB_ROUNDS_WINDOW", "FLB_ROUNDS_WINDOW", "ROUNDS_FF_PASSES",
    "COMPACT_EVERY", "COALESCE_BATCH", "DEFAULT_BATCH",
]

# Windows are sized to the measured unfinished-job backlog on the §6.2
# traces (FB is capacity-bound — ≤ 158 unfinished at the Fig-13
# capacities on SDSC BLUE; FLB-NUB leases elastically — ≤ 55) plus
# slack: between compactions completed lanes linger and freshly
# submitting jobs must already be admitted.
FB_ROUNDS_WINDOW = 192
FLB_ROUNDS_WINDOW = 96
# The scan's pass count. PR 4 spent a third pass because a pass-
# convergence miss at an exact event time is a start-time error; the
# paper-grid contract was RE-MEASURED at two passes (completed jobs
# exact on all 45 evals, node-hours <= 3.8 %, peak <= 1.3 % — identical
# to the 3-pass ledger) and the random-trace contract tests hold, so
# the default aligns with the scan's validated setting. With the
# coalescer enabled the contended instants are additionally exact by
# construction (one proven-or-deferred pass per replayed instant).
ROUNDS_FF_PASSES = 2
# Rounds between window compactions. Compaction is the one data-movement
# op of the loop (a stacked lane gather); amortizing it every few rounds
# keeps the per-round cost at reduction-dispatch level. The inner block
# is unrolled, so this also bounds the compiled body size.
COMPACT_EVERY = 8
# Contended-stretch coalescing batch: with ``ScanOptions(coalesce=k)``
# one round absorbs up to k queued-period completions (and the arrivals
# riding the same stretch), each replayed at its exact instant by the
# bulk section of ``round_body``. COALESCE_BATCH is the recommended
# opt-in batch; the ENGINE default is 1 (coalescing off) because the
# bulk's fixed vector work executes every round whether or not a
# stretch is underway (vmapped lanes run in lockstep, so it cannot be
# branched away), and on CPU-class hosts that tax measurably exceeds
# the rounds it saves on the paper-density grids — the structural
# step-count reduction pays off where per-round lockstep cost
# dominates instead (wide accelerator batches). Re-measured under the
# fused Pallas round-step kernel (kernel="pallas", coalesce=8, the
# 45-eval paper grids): still a net loss on CPU — 8.7 s vs 4.0 s
# plain-fused despite max rounds dropping 6258 -> 4047, because the
# bulk's lockstep vector work runs inside the kernel too and interpret
# mode executes it per-op per-lane. The verdict stands until a
# compiled-kernel accelerator measurement says otherwise, so
# DEFAULT_BATCH stays 1. See the honest-perf note in the module
# docstring and README's engine table.
COALESCE_BATCH = 8
DEFAULT_BATCH = 1


@dataclasses.dataclass(frozen=True)
class RoundsSpec:
    """Static (hashable) execution parameters of one policy's
    event-round program: the measurement horizon, the safety cap on
    rounds (the loop exits when every lane reaches the horizon — the
    cap only stops a runaway lane, see :func:`round_budget`), the job
    window, the first-fit passes per round, the compaction cadence and
    the contended-stretch coalescing batch (completions absorbed per
    round while a queue exists; 1 disables coalescing).

    ``kernel`` selects the round-step backend: ``"xla"`` (default) runs
    the outer-loop body as plain traced jnp ops; ``"pallas"`` fuses the
    whole body — compaction, admission, size classes and the unrolled
    ``compact_every`` rounds — into one Pallas kernel per lane
    (``repro.kernels.round_step``), with interpret mode auto-selected
    off-TPU. Both backends execute the SAME ``_chunk_core`` math, so
    their rows are bit-identical (tests/test_round_step_kernel.py).
    The field is part of the spec hash, so the jit caches key on
    ``(policy, spec-incl-kernel)`` and switching backends never reuses
    a stale compiled program."""

    duration: float
    max_rounds: int
    window: int
    ff_passes: int = ROUNDS_FF_PASSES
    compact_every: int = COMPACT_EVERY
    batch: int = DEFAULT_BATCH
    kernel: str = "xla"

    def __post_init__(self):
        if self.kernel not in ("xla", "pallas"):
            raise ValueError(
                f"unknown rounds kernel {self.kernel!r}; expected "
                f"\"xla\" or \"pallas\"")


@dataclasses.dataclass(frozen=True)
class PackedEventWorkloads:
    """Fixed-size event arrays for W workloads and one policy's P sweep
    points: the arrival-sorted job tables of the scan pack plus the WS
    demand change points (value changes only, +inf sentinel padding)
    and the host-precomputed WS fold tables (see the module docstring —
    the loop never stops at a WS change, it reads these instead)."""

    submit: jnp.ndarray       # (W, J + K) — padded past the table end
    size: jnp.ndarray         # (W, J + K)
    runtime: jnp.ndarray      # (W, J + K)
    ws0: jnp.ndarray          # (W,) demand at t = 0
    ws_adjusts: jnp.ndarray   # (W,) ledgered WS events (startup + changes)
    rise_times: jnp.ndarray   # (W, NR) demand-rise times (FB stops), +inf
    rise_vals: jnp.ndarray    # (W, NR) demand value after each rise
    ws_integral: jnp.ndarray  # (W, P) ∫ policy's WS allocation share
    ws_winmax: jnp.ndarray    # (W, P, NT) per-lease-window max of the
    #                           policy's WS share (peak folding)
    ws_at_tick: jnp.ndarray   # (W, P, NT) demand at each lease boundary
    n_jobs: jnp.ndarray       # (W,) real (unpadded) job counts
    # Chaos tier (repro.sim.faults), FB only. None (the default) leaves
    # the pack structurally identical to the pre-fault format: a None
    # data field flattens to an empty pytree, so vmap axes, buffer
    # donation and every existing construction site are untouched.
    fault_times: Optional[jnp.ndarray] = None   # (W, NF) stop times, +inf
    fault_failed: Optional[jnp.ndarray] = None  # (W, NF) failed count
    #                                             in effect AFTER each stop
    fault_wsv: Optional[jnp.ndarray] = None     # (W, NF) raw WS demand at
    #                                             each stop (reclaim level)


jax.tree_util.register_dataclass(
    PackedEventWorkloads,
    data_fields=["submit", "size", "runtime", "ws0", "ws_adjusts",
                 "rise_times", "rise_vals", "ws_integral", "ws_winmax",
                 "ws_at_tick", "n_jobs", "fault_times", "fault_failed",
                 "fault_wsv"],
    meta_fields=[])


# ------------------------------------------------------------------ packing

def _ws_fold_tables_ref(times: np.ndarray, values: np.ndarray,
                        duration: float, policy: str, leases: np.ndarray,
                        levels: np.ndarray
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Reference fold-table build: the original per-point Python loop
    (``np.union1d`` + ``searchsorted`` + grouped max per lease window).
    Kept as the correctness oracle for :func:`ws_fold_tables_batch`
    (tests pin exact equality) and as the host-loop baseline the
    ``benchmarks.run scenarios`` setup comparison measures against —
    NOT called on any production path.
    """
    edges = np.minimum(np.append(times[1:], duration), duration)
    widths = np.maximum(edges - np.minimum(times, duration), 0.0)
    P = len(leases)
    if policy == "fb":
        share = np.minimum(values[None, :], levels[:, None])   # (P, NWS)
    else:
        share = np.maximum(values[None, :] - levels[:, None], 0.0)
    integral = share @ widths
    # One entry past the last full window: when the horizon is an exact
    # lease multiple a tick fires AT the horizon and probes the
    # degenerate window starting there — it must read the horizon-time
    # demand, not zero padding.
    nt = max(int(np.ceil(duration / leases.min())), 1) + 1
    winmax = np.zeros((P, nt))
    at_tick = np.zeros((P, nt))
    for p in range(P):
        n_win = max(int(np.ceil(duration / leases[p])), 1)
        # Merge the demand change points with the window edges, so each
        # merged cell lies in exactly one window and carries one share
        # value; a grouped max per window then covers segments that
        # span window boundaries.
        win_edges = np.arange(n_win) * leases[p]
        merged = np.union1d(times, win_edges)
        merged = merged[merged < duration]
        vals = share[p][np.searchsorted(times, merged, "right") - 1]
        starts = np.searchsorted(merged, win_edges, "left")
        winmax[p, :n_win] = np.maximum.reduceat(vals, starts)
        at_tick[p, :n_win] = values[
            np.searchsorted(times, win_edges, "right") - 1]
        end_idx = np.searchsorted(times, n_win * leases[p], "right") - 1
        winmax[p, n_win] = share[p][end_idx]
        at_tick[p, n_win] = values[end_idx]
    return integral, winmax, at_tick


def ws_fold_tables_batch(times: np.ndarray, values: np.ndarray,
                         duration: float, policy: str, leases: np.ndarray,
                         levels: np.ndarray,
                         failed: Optional[np.ndarray] = None
                         ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized WS fold tables over all (W, P) lanes at once.

    ``times`` is ONE sorted change-point axis (N,) shared by every lane
    (each entry < ``duration``; generated scenario batches share a
    dense grid, single-trace callers pass that trace's points), and
    ``values`` the per-lane demand rows (W, N) — a 1-D ``values`` is
    treated as one lane. Returns ``(integral (W, P), winmax (W, P, NT),
    at_tick (W, P, NT))``, elementwise equal to the reference per-point
    loop (:func:`_ws_fold_tables_ref`, pinned by tests):

    * ``integral`` — exact node-second integral of the policy's WS
      allocation share (``min(ws, C)`` for FB, ``max(ws − lb_ws, 0)``
      for FLB-NUB), one stacked GEMV over the segment widths;
    * ``winmax`` — the share's max over every lease window
      ``[kL, (k+1)L)``: the max of the *boundary* value (the segment
      covering ``kL``, one batched ``searchsorted`` gather) and a
      segment-max of the change points grouped by window index. The
      groups are contiguous runs of the sorted time axis, so ONE
      flattened ``maximum.reduceat`` over the (P·N) composite grouping
      covers every point at once — no per-point loop;
    * ``at_tick`` — the demand at every lease boundary (same gather).

    Windows past a point's horizon (``k > ceil(duration / L_p)``) are
    zero, exactly like the reference.

    ``failed``, when given, is the concurrently-failed node count as a
    step series on the SAME time axis (N,), shared by every lane — the
    chaos tier's time-varying capacity. The FB share line becomes
    ``min(ws, max(C - failed, 0))`` (the §5.1 WS-priority invariant the
    event engine's ``on_fail`` maintains), which keeps the integral and
    the window maxima exact under failures. FLB-NUB satisfies WS
    elastically regardless of pool failures, so ``failed`` is rejected
    there.
    """
    times = np.asarray(times, np.float64)
    values = np.asarray(values, np.float64)
    if values.ndim == 1:
        values = values[None]
    leases = np.asarray(leases, np.float64)
    levels = np.asarray(levels, np.float64)
    W, N = values.shape
    P = len(leases)
    edges = np.minimum(np.append(times[1:], duration), duration)
    widths = np.maximum(edges - np.minimum(times, duration), 0.0)   # (N,)
    if failed is not None and policy != "fb":
        raise ValueError("time-varying failed capacity is FB-only "
                         "(FLB-NUB's WS share is elastic)")
    if policy == "fb":
        cap = levels[None, :, None]
        if failed is not None:
            failed = np.asarray(failed, np.float64)
            cap = np.maximum(cap - failed[None, None, :], 0.0)
        share = np.minimum(values[:, None, :], cap)
    else:
        share = np.maximum(values[:, None, :] - levels[None, :, None],
                           0.0)                                 # (W, P, N)
    # (W, P, N) @ (N,) runs the same (P, N) GEMV per lane as the
    # reference loop, keeping the integral bit-identical for every W.
    integral = share @ widths
    nt = max(int(np.ceil(duration / leases.min())), 1) + 1
    n_win = np.maximum(np.ceil(duration / leases).astype(np.int64), 1)
    win_edges = np.arange(nt)[None, :] * leases[:, None]        # (P, NT)
    # The segment covering each window boundary (right-continuous).
    bidx = (np.searchsorted(times, win_edges.ravel(), "right")
            .reshape(P, nt) - 1)
    at_tick = values[:, bidx]                                   # (W, P, NT)
    winmax = np.take_along_axis(
        share, np.broadcast_to(bidx, (W, P, nt)), axis=2).copy()
    # Segment max of the interior change points, grouped by window
    # index. For a fixed p the groups are contiguous runs of the sorted
    # time axis; flattening (p, window) into one composite, strictly
    # sorted grouping makes them contiguous runs of the (P·N) axis too,
    # so one reduceat covers all points. reduceat's empty-segment quirk
    # (it returns the start element) is masked off via the run lengths.
    interior = times < duration
    ii = np.nonzero(interior)[0]
    if ii.size:
        M = ii.size
        widx = np.minimum((times[ii][None, :]
                           // leases[:, None]).astype(np.int64),
                          nt - 1)                               # (P, M)
        flat_groups = (np.arange(P)[:, None] * nt + widx).ravel()
        starts = np.searchsorted(flat_groups, np.arange(P * nt), "left")
        counts = np.append(np.diff(starts), P * M - starts[-1])
        # A trailing -inf sentinel keeps every start index valid
        # (trailing empty groups have starts == P*M; clipping instead
        # would truncate the last non-empty group's segment end).
        share_flat = np.concatenate(
            [share[:, :, ii].reshape(W, P * M),
             np.full((W, 1), -np.inf)], axis=1)
        seg = np.maximum.reduceat(share_flat, starts, axis=1)
        seg = np.where(counts[None, :] > 0, seg, -np.inf)
        winmax = np.maximum(winmax, seg.reshape(W, P, nt))
    # A point's windows end at n_win = ceil(duration / L): entry n_win
    # is the degenerate horizon-boundary probe (boundary value only —
    # every interior point lies strictly below duration <= n_win·L),
    # entries past it stay zero like the reference's.
    live = np.arange(nt)[None, :] <= n_win[:, None]             # (P, NT)
    winmax = np.where(live[None], winmax, 0.0)
    at_tick = np.where(live[None], at_tick, 0.0)
    return integral, winmax, at_tick


@functools.lru_cache(maxsize=256)
def _fold_tables_cached(times_b: bytes, values_b: bytes, duration: float,
                        policy: str, leases_b: bytes, levels_b: bytes,
                        failed_b: bytes = b""
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """One workload's fold tables, memoized on the trace identity (the
    raw change-point bytes), the policy and the grid's (leases, levels)
    — the differential harness and the multi-engine benchmark re-pack
    identical workloads once per engine column, and the tables are the
    dominant pack cost. Cached arrays are marked read-only; consumers
    copy via ``astype`` before mutating/stacking."""
    times = np.frombuffer(times_b, np.float64)
    values = np.frombuffer(values_b, np.float64)
    leases = np.frombuffer(leases_b, np.float64)
    levels = np.frombuffer(levels_b, np.float64)
    failed = np.frombuffer(failed_b, np.float64) if failed_b else None
    integral, winmax, at_tick = ws_fold_tables_batch(
        times, values, duration, policy, leases, levels, failed)
    out = (integral[0], winmax[0], at_tick[0])
    for a in out:
        a.flags.writeable = False
    return out


def fold_table_cache_info():
    """``lru_cache`` statistics of the per-workload fold-table cache —
    the ``benchmarks.run scenarios`` CI leg gates on the hit count."""
    return _fold_tables_cached.cache_info()


def fold_table_cache_clear() -> None:
    _fold_tables_cached.cache_clear()


def pack_event_workloads(workloads: Sequence[Tuple[Sequence[Job],
                                                   Sequence[Tuple[float,
                                                                  int]]]],
                         duration: float, window: int, policy: str,
                         leases: Sequence[float], levels: Sequence[float],
                         dtype: Optional[np.dtype] = None,
                         split: bool = False, faults=None):
    """Pack ``(jobs, ws_trace)`` workloads into event-round arrays for
    one policy's sweep points.

    ``levels`` is the per-point WS fold level — the capacity C for FB,
    the WS lower bound for FLB-NUB (integers; the fold tables are exact
    for the values given). WS change points collapse to actual value
    changes within the horizon (the event engine ledgers nothing for a
    no-op demand event); a trailing ``+inf`` sentinel keeps gathers in
    range after the last real change. With ``split=True`` the return
    value is a LIST of single-workload packs (one per trace, identical
    shapes since they are padded together) cut on the host — the
    per-trace invocations of ``repro.sim.sweep`` consume these without
    slicing a device-resident pack per workload.

    ``faults``, when given, is a per-workload sequence of
    :class:`repro.sim.faults.FaultSchedule` (or ``None`` entries) —
    FB only. Fault instants become loop stops (``fault_times`` /
    ``fault_failed`` / ``fault_wsv``), and the fold tables are rebuilt
    on the union of demand and fault change points with the FB share
    line ``min(ws, max(C - failed(t), 0))``, so the WS integral and the
    window maxima stay exact under failures. Demand-rise stops keep
    coming from the original demand points.
    """
    dtype = resolve_pack_dtype(dtype)
    if faults is not None and any(f is not None and len(f) for f in faults):
        if policy != "fb":
            raise ValueError(
                "fault schedules are FB-only in the rounds engine; run "
                "FLB-NUB faults through the event engine")
        if len(faults) != len(workloads):
            raise ValueError(
                f"faults ({len(faults)}) must align with workloads "
                f"({len(workloads)})")
    else:
        faults = None
    submit, size, runtime, n_jobs = pack_job_table(workloads, window, dtype)
    W = len(workloads)
    leases = np.asarray(leases, np.float64)
    levels = np.asarray(levels, np.float64)
    rises: List[Tuple[np.ndarray, np.ndarray]] = []
    fault_tabs: List[Tuple[np.ndarray, np.ndarray, np.ndarray]] = []
    integrals, winmaxes, at_ticks = [], [], []
    ws0 = np.zeros(W, dtype)
    ws_adjusts = np.zeros(W, dtype)
    for w, (_, ws_trace) in enumerate(workloads):
        times, values = step_points(ws_trace, duration)
        keep = np.ones(len(times), bool)
        keep[1:] = values[1:] != values[:-1]   # drop no-op change points
        times, values = times[keep], values[keep]
        ws0[w] = values[0]
        ws_adjusts[w] = (len(times) - 1) + float(values[0] > 0)
        up = values[1:] > values[:-1]
        rises.append((times[1:][up], values[1:][up]))
        fs = faults[w] if faults is not None else None
        failed_b = b""
        if fs is not None and len(fs):
            # Mirror the site ledger's clamp (at most C nodes down at
            # once; repairs revive only actually-failed nodes). The
            # clamp recurrence depends on C, so a multi-level grid can
            # only share one fault table when the clamp never binds.
            if np.unique(levels).size == 1:
                fs = fs.clamp(int(levels[0]))
            elif fs.max_concurrent() > int(np.min(levels)):
                raise ValueError(
                    "fault schedule's concurrent failures exceed the "
                    "smallest capacity level; the ledger clamp is "
                    "per-capacity — pack one level at a time")
        if fs is not None and len(fs):
            f_t, f_n = fs.failed_series()
            # Distinct fault instants inside the horizon, with the
            # failed count in effect after all same-time events and the
            # raw demand at that instant (the loop's reclaim level).
            u_t = np.unique(f_t[f_t < duration])
            u_n = np.concatenate([[0], f_n])[
                np.searchsorted(f_t, u_t, "right")].astype(np.float64)
            u_w = values[np.searchsorted(times, u_t, "right") - 1]
            fault_tabs.append((u_t, u_n, u_w))
            # Fold axis: the union of demand and fault change points,
            # demand and failed resampled onto it.
            m_t = np.union1d(times, u_t)
            m_v = values[np.searchsorted(times, m_t, "right") - 1]
            m_f = np.concatenate([[0.0], u_n])[
                np.searchsorted(u_t, m_t, "right")]
            fold_t, fold_v = m_t, m_v
            failed_b = np.ascontiguousarray(m_f, np.float64).tobytes()
        else:
            fault_tabs.append((np.zeros(0), np.zeros(0), np.zeros(0)))
            fold_t, fold_v = times, values
        integral, winmax, at_tick = _fold_tables_cached(
            np.ascontiguousarray(fold_t, np.float64).tobytes(),
            np.ascontiguousarray(fold_v, np.float64).tobytes(),
            float(duration), policy, leases.tobytes(), levels.tobytes(),
            failed_b)
        integrals.append(integral)
        winmaxes.append(winmax)
        at_ticks.append(at_tick)
    nr = max((len(r) for r, _ in rises), default=0) + 1   # +inf sentinel
    rise_times = np.full((W, nr), np.inf, dtype)
    rise_vals = np.zeros((W, nr), dtype)
    for w, (r_t, r_v) in enumerate(rises):
        rise_times[w, :len(r_t)] = r_t
        rise_vals[w, :len(r_v)] = r_v
    arrays = dict(
        submit=submit, size=size, runtime=runtime, ws0=ws0,
        ws_adjusts=ws_adjusts, rise_times=rise_times,
        rise_vals=rise_vals,
        ws_integral=np.stack(integrals).astype(dtype),
        ws_winmax=np.stack(winmaxes).astype(dtype),
        ws_at_tick=np.stack(at_ticks).astype(dtype), n_jobs=n_jobs)
    if faults is not None:
        nf = max(len(ft) for ft, _, _ in fault_tabs) + 1  # +inf sentinel
        fault_times = np.full((W, nf), np.inf, dtype)
        fault_failed = np.zeros((W, nf), dtype)
        fault_wsv = np.zeros((W, nf), dtype)
        for w, (f_t, f_n, f_w) in enumerate(fault_tabs):
            fault_times[w, :len(f_t)] = f_t
            fault_failed[w, :len(f_n)] = f_n
            fault_wsv[w, :len(f_w)] = f_w
        arrays.update(fault_times=fault_times, fault_failed=fault_failed,
                      fault_wsv=fault_wsv)
    if split:
        return [PackedEventWorkloads(
            **{k: jnp.asarray(v[w:w + 1]) for k, v in arrays.items()})
            for w in range(W)]
    return PackedEventWorkloads(
        **{k: jnp.asarray(v) for k, v in arrays.items()})


def round_budget(max_jobs: int, n_ws: int, duration: float,
                 min_lease: float) -> int:
    """Safety cap on rounds per lane: every submit, one completion per
    job plus generous kill-restart slack (FB restarts re-enter the
    completion stream), every demand rise and every lease tick of the
    *shortest* lease in the grid. The loop exits as soon as every lane
    reaches the horizon, so the cap is free unless a lane runs away; a
    lane that exhausts it reports ``truncated`` and the sweep layer
    warns.
    """
    ticks = int(np.ceil(duration / max(min_lease, 1.0)))
    return int(n_ws + 4 * max_jobs + ticks + 64)


# ------------------------------------------------------------- the rounds core

# The loop's metric accumulators, in the FIXED order the fused kernel
# packs them into its scalar state vector (repro.kernels.round_step) —
# both backends build the acc dict from this tuple.
ACC_KEYS = ("completed", "turn_sum", "exec_sum", "kills", "node_seconds",
            "peak", "pbj_adjusts", "adjusts", "window_overflow", "rounds",
            "coalesced")


def _lane_ctx(policy: str, prm: Dict, pk: PackedEventWorkloads) -> Dict:
    """One lane's traced round-body inputs as a flat dict — the job
    table, the FB demand-rise stops, the per-point WS fold tables and
    the policy scalars. The XLA path builds it from the packed pytree;
    the fused kernel rebuilds the IDENTICAL dict from its input refs
    (``repro.kernels.round_step._ctx_from_inputs``), so both backends
    feed the same values through the same ``_chunk_core`` math."""
    f = pk.submit.dtype
    p_idx = prm["p_idx"]
    ctx = {
        "L": prm["lease"].astype(f),
        "tr_submit": pk.submit, "tr_size": pk.size,
        "tr_runtime": pk.runtime,
        "rise_times": pk.rise_times, "rise_vals": pk.rise_vals,
        "ws_winmax": pk.ws_winmax[p_idx],    # (NT,) WS-share window max
        "ws_at_tick": pk.ws_at_tick[p_idx],  # (NT,) demand at boundaries
    }
    if policy == "fb":
        ctx["C"] = prm["capacity"].astype(f)
        if pk.fault_times is not None:
            # Chaos tier: fault stop instants, the failed count after
            # each, and the raw demand at each (pack enforces FB-only).
            ctx["fault_times"] = pk.fault_times
            ctx["fault_failed"] = pk.fault_failed
            ctx["fault_wsv"] = pk.fault_wsv
    else:
        ctx["B"] = prm["B"].astype(f)
        ctx["lb_ws"] = prm["lb_ws"].astype(f)
        ctx["U"], ctx["V"], ctx["G"] = (prm[k].astype(f)
                                        for k in ("U", "V", "G"))
    return ctx


def _actions(policy: str, ctx: Dict, ff_passes: int, owned, pool_pbj,
             run, used, queued, wsv, is_tick, win, w_sz, szcls, acc):
    """The shared §5 policy step at one instant (see scan.py). The
    integrand it returns covers only the policy-owned share — the
    WS share integrates host-side (``ws_integral``) — and peaks
    fold per lease window: the policy share is constant inside one
    (FB reclaims only at demand-rise stops, which ratchet it down
    monotonically after the window's grant; FLB adjusts only at
    ticks), so combining it with the precomputed WS-share window
    max is exact without stopping at demand changes."""
    ws_winmax = ctx["ws_winmax"]
    if policy == "fb":
        C = ctx["C"]
        owned, run, starts, killed, alloc, pbj_ev = fb_actions(
            C, owned, run, used, queued, wsv, w_sz,
            *szcls, is_tick, ff_passes)
        acc["kills"] += jnp.sum(killed)
        # Window peak: owned is maximal right after the window's
        # grant, and the §5.1 ratchet owned(τ) = C − runmax(ws)
        # makes the in-window alloc max exactly min(owned + M, C).
        peak_cand = jnp.minimum(owned + ws_winmax[win], C)
        integrand = owned
    else:
        owned, pool_pbj, run, starts, alloc, pbj_ev = flb_actions(
            ctx["B"], ctx["lb_ws"], ctx["U"], ctx["V"], ctx["G"],
            owned, pool_pbj, run, used, queued, wsv, w_sz, is_tick,
            ff_passes)
        leased = ctx["B"] + jnp.maximum(owned - pool_pbj, 0.0)
        peak_cand = leased + ws_winmax[win]
        integrand = leased
    acc["peak"] = jnp.maximum(acc["peak"],
                              jnp.where(is_tick, peak_cand, -jnp.inf))
    acc["pbj_adjusts"] += pbj_ev
    acc["adjusts"] += pbj_ev
    return owned, pool_pbj, run, starts, integrand, acc


def _round_body(policy: str, ctx: Dict, spec: RoundsSpec, carry, szcls):
    """One event round over the window lanes — pure jnp on the carry,
    shared verbatim by the XLA outer loop and the fused Pallas kernel
    (see the module docstring for the event semantics)."""
    (t, owned, pool_pbj, used, has_queue, wsv, alloc_prev, rise_i,
     row_sub, w_sub, w_sz, w_rt, run, done, start_t, end_t, acc) = carry
    duration = spec.duration
    K = w_sub.shape[0]
    batch = min(spec.batch, K)      # top-k cannot exceed the window
    coalesce = batch > 1
    f = w_sub.dtype
    inf = jnp.asarray(jnp.inf, f)
    zero = jnp.zeros((), f)
    one = jnp.ones((), f)
    dur = jnp.asarray(duration, f)
    L = ctx["L"]
    rise_times, rise_vals = ctx["rise_times"], ctx["rise_vals"]
    ws_at_tick = ctx["ws_at_tick"]
    NT = ctx["ws_winmax"].shape[0]
    active = t < duration
    # --- the next event horizon. Every candidate is strictly > t,
    # so the loop always progresses; a finished lane pins b = t and
    # becomes a no-op. Completions bound the horizon only while the
    # queue is non-empty (they can then start queued work);
    # otherwise they fold retroactively below, at exact times.
    mins = jnp.min(jnp.stack([jnp.where(w_sub > t, w_sub, inf),
                              jnp.where(run, end_t, inf)]),
                   axis=-1)                      # one packed reduction
    next_sub = jnp.minimum(mins[0],
                           jnp.where(row_sub > t, row_sub, inf))
    k_next = jnp.floor(t / L) + 1.0
    t_tick = k_next * L
    b0 = jnp.minimum(t_tick,
                     jnp.minimum(jnp.where(row_sub > t, row_sub, inf),
                                 dur))
    if policy == "fb":
        b0 = jnp.minimum(b0, rise_times[rise_i])
    faulted = "fault_times" in ctx
    if faulted:
        # Chaos tier: every fault instant is a stop (capacity changes
        # there — kills and WS drains must replay at the exact time).
        # Between stops the failed count, and therefore the effective
        # capacity, is constant, which keeps the interval integration
        # and the policy share exact.
        ft = ctx["fault_times"]
        fi = jnp.searchsorted(ft, t, side="right")
        b0 = jnp.minimum(b0, ft[jnp.minimum(fi, ft.shape[0] - 1)])
    # --- submit skipping and the contended horizon. Empty queue:
    # if every submit in (t, b0] fits the currently-free capacity
    # in aggregate (free only grows inside the horizon; the
    # row_sub cap keeps every such submit inside the window), each
    # starts exactly on time — retroactively, below; otherwise
    # stop at the next submit. Non-empty queue with coalescing on
    # (batch > 1): neither completions nor submits bound the
    # horizon — the coalescer below replays a whole batch of them
    # inside (t, b) at their exact instants (and re-clamps b when
    # it has to stop early). With coalescing off the legacy
    # horizon applies: stop at the earliest running-lane
    # completion, and silently enqueue arrivals that cannot fit
    # the (then constant) free capacity.
    if not coalesce:
        b0 = jnp.minimum(b0, jnp.where(has_queue, mins[1], inf))
    fresh = (w_sub > t) & (w_sub <= b0)
    sum_new = jnp.sum(jnp.where(fresh, w_sz, zero))
    free = owned - used
    skip_ok = ~has_queue & (sum_new <= free)
    if coalesce:
        unbounded = skip_ok | has_queue
    else:
        min_new = jnp.min(jnp.where(fresh, w_sz, inf))
        unbounded = skip_ok | (has_queue & (min_new > free))
    b = jnp.where(unbounded, b0, jnp.minimum(b0, next_sub))
    b = jnp.where(active, b, t)
    # --- the contended-stretch coalescer: while a queue existed at
    # the round start, every completion and submit strictly inside
    # (t, b) is an event the engine reacts to (a finish or arrival
    # triggers the §6.5.2 first-fit), and the coalescer replays a
    # whole batch of them in ONE round of fixed vector work:
    #
    #   1. masked top-k — the next `batch` distinct completion
    #      instants among running lanes, extracted as iterated
    #      masked mins (sorted by construction; simultaneous
    #      completions collapse into one instant), with the freed
    #      node mass per instant;
    #   2. a prefix-sum feasibility test for queue admissions at
    #      each instant: under the engine's arrival-order scan a
    #      pending job q starts once the cumulative freed mass
    #      covers the pending jobs ahead of it plus itself
    #      (arrival order IS lane order, so `need` is one exclusive
    #      prefix sum), i.e. at instant τ_{i(q)} with i(q) the
    #      first index where freedcum ≥ need(q) — or at its own
    #      submit time if capacity already suffices;
    #   3. defer-on-divergence: the closed form assumes FIFO
    #      starts. Whenever the engine's first-fit could diverge —
    #      an unstarted pending job that FITS the (conservatively
    #      overestimated) free capacity at some replayed instant
    #      or at its own arrival (a leapfrog), or a batch-started
    #      job completing inside the round (a chain event the
    #      freed-mass ledger does not contain), or more than
    #      `batch` instants (the cap) — the round ends exactly AT
    #      the first such instant Θ: every extracted instant,
    #      admission and fold before Θ stays, and the tail replays
    #      Θ itself with the full `ff_passes` first-fit (and the
    #      §5.1 kill machinery when Θ is a demand rise), exactly
    #      like an uncoalesced round.
    #
    # Allocation integrals need no per-instant work at all: the
    # policy-owned share is constant across the whole stretch (FB
    # reclaims only at rises, which bound b; FLB adjusts only at
    # ticks), so each sub-interval contributes to one rectangle.
    # A lax.top_k sort probe was measured ~6x the cost of this
    # whole section on XLA:CPU — hence the iterated masked mins.
    if coalesce:
        engaged = active & has_queue
        run0, done0, used0, free0 = run, done, used, free
        # (1) masked top-k completion instants inside (t, b).
        avail = engaged & run0 & (end_t < b)
        taus, freds = [], []
        for _ in range(batch):
            v = jnp.min(jnp.where(avail, end_t, inf))
            take = avail & (end_t <= v)
            taus.append(v)
            freds.append(jnp.sum(jnp.where(take, w_sz, zero)))
            avail = avail & ~take
        frontier = jnp.min(jnp.where(avail, end_t, inf))
        tau_v = jnp.stack(taus)                        # (k,) sorted
        freedcum = jnp.cumsum(jnp.stack(freds))        # (k,)
        tau_pad = jnp.concatenate([t[None], tau_v])    # idx 0 → t
        # (2) prefix-sum admission. Pending lanes (queued now or
        # arriving inside the round) block each other in lane
        # (= arrival) order; inherited queue heads that already
        # fit free0 belong to the convergence residue of the LAST
        # round's first-fit and start retroactively at t.
        pend = engaged & ~run0 & ~done0 & (w_sub <= b)
        psz = jnp.where(pend, w_sz, zero)
        need = (jnp.cumsum(psz) - psz) + w_sz - free0
        uncov = need[:, None] > freedcum[None, :]      # (K, k)
        idx = jnp.sum(uncov.astype(jnp.int32), axis=-1)
        # idx = first slot whose cumulative mass covers `need`;
        # tau_pad maps slot j to τ_j (and a non-positive need to t:
        # capacity already sufficed, the job is last round's
        # first-fit convergence residue or starts at its arrival).
        start_i = jnp.where(need <= 0.0, 0,
                            jnp.minimum(idx + 1, batch))
        covered = pend & ((need <= 0.0) | (idx < batch))
        start_at = jnp.where(covered,
                             jnp.maximum(w_sub, tau_pad[start_i]),
                             inf)
        # A zero-runtime job starting AT the round start would
        # complete instantly — freed mass the ledger below cannot
        # carry (Θ must stay > t), which would under-estimate
        # free_at and mask a real leapfrog. Leave such a lane to
        # the tail's first-fit (the one-instant-late residue the
        # contract already carries); zero-runtime starts at later
        # instants defer naturally through the chain probe.
        start_at = jnp.where((w_rt <= 0.0) & (start_at <= t), inf,
                             start_at)
        # (3) divergence probes, all conservative (free capacity
        # only ever OVER-estimated, so every possible first-fit
        # leapfrog defers). started_at[j] counts admissions that
        # happened strictly up to τ_j.
        stsz = jnp.where(start_at < inf, w_sz, zero)
        started_by = jnp.sum(
            jnp.where(start_at[:, None] <= tau_v[None, :],
                      stsz[:, None], zero), axis=0)    # (k,)
        free_at = free0 + freedcum - started_by        # (k,)
        fits = (pend[:, None]
                & (w_sub[:, None] <= tau_v[None, :])
                & (start_at[:, None] > tau_v[None, :])
                & (w_sz[:, None] <= free_at[None, :])) # (K, k)
        leap = jnp.min(jnp.where(jnp.any(fits, axis=0), tau_v, inf))
        # ...and at each arrival instant: net freed mass before the
        # arrival, ignoring arrival-triggered consumption (an
        # overestimate), one (K,k) @ (k,) contraction.
        net = jnp.concatenate([freedcum[:1],
                               jnp.diff(freedcum)]) \
            - jnp.concatenate([started_by[:1],
                               jnp.diff(started_by)])
        free_arr = free0 + (tau_v[None, :]
                            < w_sub[:, None]).astype(f) @ net
        arr_leap = pend & (w_sub > t) & (start_at > w_sub) \
            & (w_sz <= free_arr)
        leap = jnp.minimum(leap, jnp.min(jnp.where(arr_leap, w_sub,
                                                   inf)))
        # Chain events: batch-started jobs finishing inside the
        # round free mass the ledger above does not see.
        chain = jnp.min(jnp.where(start_at < inf,
                                  start_at + w_rt, inf))
        chain = jnp.where(chain > t, chain, inf)       # 0-runtime
        theta = jnp.minimum(jnp.minimum(leap, chain), frontier)
        # (4) apply everything strictly before Θ; Θ itself (and
        # anything later) belongs to the tail / next rounds.
        cmp_c = engaged & run0 & (end_t < jnp.minimum(theta, b))
        st_c = (start_at < jnp.minimum(theta, b))
        cf = cmp_c.astype(f)
        folds_c = jnp.sum(jnp.stack([cf, cf * (end_t - w_sub),
                                     cf * (end_t - start_t),
                                     cf * w_sz,
                                     jnp.where(st_c, w_sz, zero)]),
                          axis=-1)                 # one packed reduction
        run = (run0 & ~cmp_c) | st_c
        done = done0 | cmp_c
        start_t = jnp.where(st_c, start_at, start_t)
        end_t = jnp.where(st_c, start_at + w_rt, end_t)
        used = used0 - folds_c[3] + folds_c[4]
        acc["completed"] += folds_c[0]
        acc["turn_sum"] += folds_c[1]
        acc["exec_sum"] += folds_c[2]
        acc["coalesced"] += folds_c[0]
        b = jnp.minimum(b, theta)
    # --- exact interval integration: the policy-owned share is
    # constant on (t, b] — it only ever changes at policy actions,
    # which happen at rounds (ticks, rises), never at coalesced
    # completions or starts.
    acc["node_seconds"] += alloc_prev * jnp.maximum(b - t, 0.0)
    # --- retroactive starts at exact submit times.
    starting = (w_sub > t) & (w_sub <= b) & ~run & ~done & skip_ok
    run = run | starting
    start_t = jnp.where(starting, w_sub, start_t)
    end_t = jnp.where(starting, w_sub + w_rt, end_t)
    # --- exact completions (including flash jobs that started and
    # finished inside this very horizon).
    completing = run & (end_t <= b)
    run = run & ~completing
    done = done | completing
    cmp_f = completing.astype(f)
    folds = jnp.sum(jnp.stack([cmp_f, cmp_f * (end_t - w_sub),
                               cmp_f * (end_t - start_t),
                               jnp.where(run, w_sz, zero)]),
                    axis=-1)                     # one packed reduction
    acc["completed"] += folds[0]
    acc["turn_sum"] += folds[1]
    acc["exec_sum"] += folds[2]
    used = folds[3]
    # --- policy actions at b. The tick fires only on a lease
    # boundary and reads the boundary-time demand from the host
    # table; between stops the carried demand only matters to FB,
    # whose reclaim level it tracks exactly (rises are FB stops).
    queued = (w_sub <= b) & ~run & ~done
    is_tick = t_tick <= b
    win = jnp.minimum(k_next, NT - 1.0).astype(jnp.int32)
    if policy == "fb":
        rised = rise_times[rise_i] <= b
        wsv = jnp.where(rised, rise_vals[rise_i], wsv)
        rise_i = rise_i + rised.astype(jnp.int32)
    if faulted:
        # Effective capacity at b: failed count after the last fault
        # event <= b. When the stop IS a fault instant, also sync the
        # carried demand to its packed raw value — the event engine's
        # on_fail sees the *current* demand (falls released WS nodes as
        # they happened), while the carried wsv only tracks rises and
        # ticks; without the sync a stale-high wsv would over-kill PBJ.
        ffl, fwv = ctx["fault_failed"], ctx["fault_wsv"]
        fib = jnp.searchsorted(ft, b, side="right")
        fprev = jnp.maximum(fib - 1, 0)
        failed_b = jnp.where(fib > 0, ffl[fprev], zero)
        wsv = jnp.where((fib > 0) & (ft[fprev] == b), fwv[fprev], wsv)
        ctx = dict(ctx, C=jnp.maximum(ctx["C"] - failed_b, zero))
    wsv = jnp.where(is_tick, ws_at_tick[win], wsv)
    owned, pool_pbj, run, starts, integrand, acc = _actions(
        policy, ctx, spec.ff_passes, owned, pool_pbj, run, used, queued,
        wsv, is_tick, win, w_sz, szcls, acc)
    start_t = jnp.where(starts, b, start_t)
    end_t = jnp.where(starts, b + w_rt, end_t)
    # Recompute the queue and usage from the POST-action lane state:
    # fb_actions may have killed running lanes, which re-queue
    # (run cleared, not done) and release their nodes — deriving
    # from the pre-action masks would hide a killed job from the
    # next round's completion horizon and overstate ``used`` in its
    # skip/enqueue tests.
    post = jnp.sum(jnp.stack([
        jnp.where((w_sub <= b) & ~run & ~done, one, zero),
        jnp.where(run, w_sz, zero)]),
        axis=-1)                                 # one packed reduction
    has_queue = post[0] > 0
    used = post[1]
    acc["window_overflow"] += (active & (row_sub <= b)).astype(f)
    acc["rounds"] += active.astype(f)
    return (b, owned, pool_pbj, used, has_queue, wsv, integrand,
            rise_i, row_sub, w_sub, w_sz, w_rt, run, done, start_t,
            end_t, acc)


def _chunk_core(policy: str, ctx: Dict, spec: RoundsSpec, core):
    """One outer step of the loop: window compaction, job-table
    admission, the per-chunk size classes and ``compact_every`` unrolled
    event rounds. ``core`` is the 17-tuple loop state with ``next_row``
    (the admission cursor) in the slot the inner rounds carry
    ``row_sub`` in. Shared verbatim by the XLA backend and the fused
    Pallas kernel — the kernel body IS this function applied to values
    read from its refs (repro.kernels.round_step)."""
    (t, owned, pool_pbj, used, has_queue, wsv, alloc_prev, rise_i,
     next_row, w_sub, w_sz, w_rt, run, done, start_t, end_t, acc) = core
    tr_submit = ctx["tr_submit"]
    tr_size, tr_runtime = ctx["tr_size"], ctx["tr_runtime"]
    K = w_sub.shape[0]
    Jp = tr_submit.shape[0]        # includes >= K pad rows (submit = +inf)
    f = w_sub.dtype
    inf = jnp.asarray(jnp.inf, f)
    zero = jnp.zeros((), f)
    lanes = jnp.arange(K)
    # --- compact done lanes out of the window (stacked gather) and
    # admit the next table rows into the freed tail as contiguous
    # dynamic-slice reads. When the table is exhausted the slice
    # start clamps into the +inf padding block, so admitted lanes
    # read pad rows — never a duplicate of a live row.
    (run_c, start_t, end_t, w_sub, w_sz, w_rt), n_keep = \
        stable_compact(~done, [run, start_t, end_t, w_sub, w_sz, w_rt],
                       [False, zero, zero, inf, zero, zero])
    run = run_c
    done = jnp.zeros(K, bool)
    adm_start = next_row - n_keep
    tail = lanes >= n_keep
    w_sub = jnp.where(tail, jax.lax.dynamic_slice(tr_submit,
                                                  (adm_start,), (K,)),
                      w_sub)
    w_sz = jnp.where(tail, jax.lax.dynamic_slice(tr_size,
                                                 (adm_start,), (K,)),
                     w_sz)
    w_rt = jnp.where(tail, jax.lax.dynamic_slice(tr_runtime,
                                                 (adm_start,), (K,)),
                     w_rt)
    next_row = jnp.minimum(next_row + (K - n_keep),
                           Jp).astype(jnp.int32)
    row_sub = tr_submit[jnp.minimum(next_row, Jp - 1)]
    inner = (t, owned, pool_pbj, used, has_queue, wsv, alloc_prev,
             rise_i, row_sub, w_sub, w_sz, w_rt, run, done, start_t,
             end_t, acc)
    # The FB kill size classes depend only on the window contents,
    # which change at compactions — computed once per chunk, not
    # once per round.
    szcls = _size_classes(w_sz)
    for _ in range(spec.compact_every):  # unrolled: XLA fuses the rounds
        inner = _round_body(policy, ctx, spec, inner, szcls)
    (t, owned, pool_pbj, used, has_queue, wsv, alloc_prev, rise_i,
     row_sub, w_sub, w_sz, w_rt, run, done, start_t, end_t,
     acc) = inner
    return (t, owned, pool_pbj, used, has_queue, wsv, alloc_prev,
            rise_i, next_row, w_sub, w_sz, w_rt, run, done, start_t,
            end_t, acc)


def _simulate_rounds(policy: str, prm: Dict, pk: PackedEventWorkloads,
                     spec: RoundsSpec) -> Dict[str, jnp.ndarray]:
    """One (point, workload) lane; vmapped over both axes by the caller.

    ``pk`` holds a single workload's rows; ``prm`` one sweep point's
    scalars plus its index ``p_idx`` into the packed WS fold tables;
    ``policy`` is static ("fb" | "flb_nub"). With ``spec.kernel ==
    "pallas"`` the loop body runs as the fused Pallas round-step kernel
    (``repro.kernels.round_step``) on a float-packed state; the state
    round-trips bit-exactly, and the kernel body calls the same
    ``_chunk_core``, so both backends return identical rows.
    """
    duration = spec.duration
    K = spec.window
    R = spec.compact_every
    if spec.kernel == "pallas" and pk.fault_times is not None:
        # The fused kernel's lane_inputs/ctx round-trip carries exactly
        # the pre-fault context; keeping fault keys out of it preserves
        # the kernel's bit-identity guarantee for every no-fault row.
        raise NotImplementedError(
            "fault injection is not supported by the fused pallas "
            "round step; use kernel=\"xla\"")
    ctx = _lane_ctx(policy, prm, pk)
    tr_submit = ctx["tr_submit"]
    tr_size, tr_runtime = ctx["tr_size"], ctx["tr_runtime"]
    ws0 = pk.ws0
    f = tr_submit.dtype
    zero = jnp.zeros((), f)
    ws_integral = pk.ws_integral[prm["p_idx"]]   # exact ∫ WS share
    ws_winmax = ctx["ws_winmax"]
    if policy == "fb":
        C = ctx["C"]
        owned0 = C - jnp.minimum(ws0, C)     # startup: all idle → PBJ (§5.1)
        pool0 = zero
    else:
        owned0 = jnp.maximum(ctx["B"] - ctx["lb_ws"], 1.0)  # §5.2 bound
        pool0 = owned0

    # ---- startup round at t = 0: the engine's startup() allocation
    # followed by the t = 0 submit events (no tick fires at 0), plus
    # the first lease window's peak probe (the tick-gated probe in
    # _actions starts at window 1).
    acc = {k: zero for k in ACC_KEYS}
    w_sub = tr_submit[:K]
    w_sz = tr_size[:K]
    w_rt = tr_runtime[:K]
    queued0 = w_sub <= 0.0
    owned, pool_pbj, run, starts0, alloc0, acc = _actions(
        policy, ctx, spec.ff_passes, owned0, pool0, jnp.zeros(K, bool),
        zero, queued0, ws0, jnp.asarray(False), jnp.asarray(0, jnp.int32),
        w_sz, _size_classes(w_sz), acc)
    if policy == "fb":
        acc["peak"] = jnp.maximum(acc["peak"],
                                  jnp.minimum(owned + ws_winmax[0], C))
    else:
        acc["peak"] = jnp.maximum(
            acc["peak"], ctx["B"] + jnp.maximum(owned - pool_pbj, 0.0)
            + ws_winmax[0])
    start_t = jnp.zeros(K, f)
    end_t = jnp.where(starts0, w_rt, jnp.zeros(K, f))
    used0 = jnp.sum(jnp.where(run, w_sz, zero))
    has_queue0 = jnp.sum(jnp.where(queued0 & ~run, 1.0, 0.0)) > 0

    outer_max = -(-spec.max_rounds // R)
    core0 = (zero, owned, pool_pbj, used0, has_queue0, ws0, alloc0,
             jnp.asarray(0, jnp.int32), jnp.asarray(K, jnp.int32),
             w_sub, w_sz, w_rt, run, jnp.zeros(K, bool), start_t, end_t,
             acc)

    if spec.kernel == "pallas":
        # The fused backend: pack the loop state into the kernel's
        # scalar vector + window matrix, run each outer step as ONE
        # pallas_call (vmapped lanes become the kernel grid), unpack
        # once after the loop. Imported lazily — the kernels layer is
        # optional and the import direction stays kernels -> sim.
        from repro.kernels import round_step as rsk
        jobs, rises, wstab, prmv = rsk.lane_inputs(policy, ctx)
        sc0, win0 = rsk.pack_carry(core0)

        def cond(carry):
            return (carry[0] < outer_max) & (carry[1][rsk.SC_T] < duration)

        def chunk(carry):
            i, sc, win = carry
            sc, win = rsk.chunk_step(jobs, rises, wstab, prmv, sc, win,
                                     policy=policy, spec=spec)
            return (i + 1, sc, win)

        carry = jax.lax.while_loop(
            cond, chunk, (jnp.asarray(0, jnp.int32), sc0, win0))
        core = rsk.unpack_carry(carry[1], carry[2])
        t_end, acc = core[0], core[-1]
    else:
        def cond(carry):
            i, t = carry[0], carry[1]
            return (i < outer_max) & (t < duration)

        def chunk(carry):
            return (carry[0] + 1,) + _chunk_core(policy, ctx, spec,
                                                 carry[1:])

        carry = jax.lax.while_loop(
            cond, chunk, (jnp.asarray(0, jnp.int32),) + core0)
        t_end, acc = carry[1], carry[-1]

    n_done = jnp.maximum(acc["completed"], 1.0)
    return {
        "completed_jobs": acc["completed"],
        "avg_turnaround": acc["turn_sum"] / n_done,
        "avg_execution": acc["exec_sum"] / n_done,
        "node_hours": (acc["node_seconds"] + ws_integral) / 3600.0,
        "peak_nodes": acc["peak"],
        "adjust_events": acc["adjusts"] + pk.ws_adjusts,
        "pbj_adjust_events": acc["pbj_adjusts"],
        "kills": acc["kills"],
        "window_overflow": acc["window_overflow"],
        "rounds": acc["rounds"],
        "coalesced": acc["coalesced"],
        "truncated": (t_end < duration).astype(f),
    }


def _rounds_prm_tree(policy: str, grid) -> Dict[str, jnp.ndarray]:
    """The scan parameter tree plus each point's index into the packed
    WS fold tables (``ws_integral`` / ``ws_winmax``)."""
    prm = dict(_prm_tree(policy, grid))
    prm["p_idx"] = jnp.arange(int(grid.lease.shape[0]), dtype=jnp.int32)
    return prm


@functools.lru_cache(maxsize=None)
def _rounds_lane(policy: str, spec: RoundsSpec):
    """Per-lane event-round program as a stable ``(prm, packed_row)``
    closure — the cache keys the jit caches of the batched runners."""
    def lane(prm, pk: PackedEventWorkloads):
        return _simulate_rounds(policy, prm, pk, spec)
    return lane


@functools.partial(compat.jit, static_argnames=("fb_spec", "flb_spec"),
                   donate_argnums=(2, 3))
def _rounds_grids_single(fb: Optional[FBGrid], flb: Optional[FLBGrid],
                         fb_packed: Optional[PackedEventWorkloads],
                         flb_packed: Optional[PackedEventWorkloads], *,
                         fb_spec: Optional[RoundsSpec] = None,
                         flb_spec: Optional[RoundsSpec] = None
                         ) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Single-device execution: the (trace, point) grid as nested vmaps,
    with the packed event buffers donated where the backend supports it
    (``repro.compat.jit``) — callers repack per invocation."""
    def run(policy, prm_tree, packed, spec):
        lane = _rounds_lane(policy, spec)
        over_points = jax.vmap(lane, in_axes=(0, None))
        over_traces = jax.vmap(over_points, in_axes=(None, 0))
        return over_traces(prm_tree, packed)

    out: Dict[str, Dict[str, jnp.ndarray]] = {}
    if fb_spec is not None:
        out["fb"] = run("fb", _rounds_prm_tree("fb", fb), fb_packed,
                        fb_spec)
    if flb_spec is not None:
        out["flb_nub"] = run("flb_nub", _rounds_prm_tree("flb_nub", flb),
                             flb_packed, flb_spec)
    return out


def rounds_grids(fb: Optional[FBGrid], flb: Optional[FLBGrid],
                 fb_packed: Optional[PackedEventWorkloads],
                 flb_packed: Optional[PackedEventWorkloads], *,
                 fb_spec: Optional[RoundsSpec] = None,
                 flb_spec: Optional[RoundsSpec] = None,
                 devices: compat.Devices = None
                 ) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Evaluate FB and FLB-NUB sweep grids through the event-round
    engine. Returns ``{"fb": metrics, "flb_nub": metrics}`` with
    ``(W, P_policy)`` metric arrays, like :func:`repro.sim.scan.
    scan_grids`; a policy is skipped when its spec is ``None``.

    ``devices`` selects the backend exactly as for the scan engine:
    ``None`` / one device runs the nested-vmap program, two or more
    shard the flattened (trace × point) lanes via the shared
    ``sharded_grid_map`` — bit-identical rows either way, since every
    lane runs the identical per-lane program. On backends with buffer
    donation (GPU/TPU — ``repro.compat.jit``) the packed event buffers
    are DONATED: re-pack per call rather than reusing one
    ``PackedEventWorkloads`` across calls (on CPU donation is dropped
    and reuse is safe).
    """
    devs = compat.resolve_devices(devices)
    if devs is None:
        return _rounds_grids_single(fb, flb, fb_packed, flb_packed,
                                    fb_spec=fb_spec, flb_spec=flb_spec)
    if ((fb_packed is not None and fb_packed.fault_times is not None)
            or (flb_packed is not None
                and flb_packed.fault_times is not None)):
        raise NotImplementedError(
            "fault-injected packs run single-device; the sharded lane "
            "splitter predates the optional fault tables")
    out: Dict[str, Dict[str, jnp.ndarray]] = {}
    if fb_spec is not None:
        out["fb"] = sharded_grid_map(
            _rounds_lane("fb", fb_spec), _rounds_prm_tree("fb", fb),
            fb_packed, int(fb_packed.submit.shape[0]),
            int(fb.lease.shape[0]), devs)
    if flb_spec is not None:
        out["flb_nub"] = sharded_grid_map(
            _rounds_lane("flb_nub", flb_spec),
            _rounds_prm_tree("flb_nub", flb), flb_packed,
            int(flb_packed.submit.shape[0]), int(flb.lease.shape[0]), devs)
    return out


def fb_rounds_row(jobs: Sequence[Job], ws_trace: Sequence[Tuple[float, int]],
                  capacity: int, lease_seconds: float, duration: float,
                  faults=None, kernel: str = "xla",
                  batch: int = DEFAULT_BATCH,
                  dtype: Optional[np.dtype] = None) -> Dict[str, float]:
    """One FB (capacity, lease) point through the rounds engine as a
    plain scalar row — the single-point convenience the chaos
    differential harness and ``benchmarks.run faults`` share. With
    ``faults`` set, the schedule's stops fold into the horizon and the
    effective capacity becomes ``max(C - failed(t), 0)`` (see
    :func:`pack_event_workloads`)."""
    n_faults = len(faults) if faults is not None else 0
    spec = RoundsSpec(
        duration=float(duration),
        max_rounds=round_budget(len(jobs), len(list(ws_trace)),
                                float(duration), float(lease_seconds))
        + 8 * n_faults,   # each fault stop may kill + restart jobs
        window=FB_ROUNDS_WINDOW, kernel=kernel, batch=batch)
    pk = pack_event_workloads(
        [(jobs, ws_trace)], float(duration), spec.window, "fb",
        [float(lease_seconds)], [float(capacity)], dtype=dtype,
        faults=[faults] if faults is not None else None)
    f = pk.submit.dtype
    fb = FBGrid(capacity=jnp.asarray([float(capacity)], f),
                lease=jnp.asarray([float(lease_seconds)], f))
    out = rounds_grids(fb, None, pk, None, fb_spec=spec)["fb"]
    row = {k: float(np.asarray(v)[0, 0]) for k, v in out.items()}
    for k in ("completed_jobs", "peak_nodes"):
        row[k] = int(round(row[k]))
    row["engine"] = "rounds"
    row["system"] = "fb"
    return row
