"""Production mesh construction.

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state — required because the
dry-run forces 512 host devices while tests/benches must see one.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single pod (256 chips) or 2×16×16 multi-pod (512 chips).

    Axes: 'pod' = data-parallel across pods (slow inter-pod links —
    gradient all-reduce crosses them once per step); 'data' = FSDP/batch;
    'model' = tensor/expert parallel within a pod.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    from jax.sharding import AxisType
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_local_mesh():
    """Single-device mesh with the production axis names (tests/smoke)."""
    import numpy as np
    from jax.sharding import Mesh
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
