"""Compiled-artifact analysis: roofline terms from HLO.

Sources:
  * ``compiled.cost_analysis()``  → HLO FLOPs + bytes accessed
  * ``compiled.as_text()``        → per-collective operand bytes (parsed;
    cost_analysis does not report collective traffic)
  * ``compiled.memory_analysis()`` → per-device HBM footprint

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (as specified by the assignment).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.3 = bf16[16,4096,2304]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
# tuple-result collectives:  = (bf16[..], bf16[..]) all-reduce(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_S32_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str) -> Dict[str, list]:
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _COMP_RE.match(stripped) if (
            "->" in stripped and stripped.endswith("{")) else None
        if m:
            cur = m.group(1)
            comps[cur] = []
            continue
        if cur is not None:
            if stripped == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps


def _line_coll(line: str):
    m = _OP_RE.search(line)
    if m:
        dtype, dims, kind = m.groups()
        return kind, _shape_bytes(dtype, dims)
    m = _TUPLE_RE.search(line)
    if m:
        shapes, kind = m.groups()
        return kind, sum(_shape_bytes(*dm.groups())
                         for dm in _SHAPE_RE.finditer(shapes))
    return None


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective kind, scaled by loop trip counts.

    XLA prints each while-loop body once, so collectives inside scanned
    layers would be undercounted by n_periods×. We split the module into
    computations, read each while's trip count from its condition
    computation (the s32 bound constant), and multiply body collectives
    by the product of enclosing trip counts.

    Result bytes are the wire-traffic proxy: all-gather output is the
    fully-gathered tensor, all-reduce output equals its input (convention
    noted in EXPERIMENTS.md).
    """
    comps = _split_computations(hlo_text)
    # Trip count per computation used as a while body.
    trips: Dict[str, int] = {}
    parents: Dict[str, str] = {}
    for name, lines in comps.items():
        for line in lines:
            w = _WHILE_RE.search(line)
            if w:
                cond, body = w.groups()
                consts = [int(c) for c in _S32_CONST_RE.findall(
                    "\n".join(comps.get(cond, [])))]
                trips[body] = max(consts) if consts else 1
                parents[body] = name

    def multiplier(name: str, depth: int = 0) -> int:
        if depth > 16 or name not in parents:
            return 1
        return trips.get(name, 1) * multiplier(parents[name], depth + 1)

    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for name, lines in comps.items():
        mult = multiplier(name)
        for line in lines:
            lc = _line_coll(line)
            if lc:
                kind, nbytes = lc
                out[kind] += nbytes * mult
                counts[kind] += mult
    out["_counts"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_detail: Dict[str, int]
    model_flops: Optional[float] = None
    memory_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / bound time (how close to roofline)."""
        bound = max(self.t_compute, self.t_memory, self.t_collective)
        if bound <= 0:
            return 0.0
        useful = (self.model_flops or self.hlo_flops) / \
            (self.chips * PEAK_FLOPS)
        return useful / bound

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": (self.model_flops / self.hlo_flops
                             if self.model_flops and self.hlo_flops else None),
            "roofline_fraction": self.roofline_fraction,
            "mem_per_device_gb": (self.memory_per_device / 2**30
                                  if self.memory_per_device else None),
        }


def analyze(arch: str, shape: str, lowered, compiled, chips: int,
            model_flops: Optional[float] = None,
            flops_override: Optional[float] = None,
            bytes_override: Optional[float] = None) -> Roofline:
    """``flops_override``/``bytes_override`` carry the *global* analytic
    counts from ``launch.cells.analytic_cost`` (XLA's cost_analysis is
    per-device and counts while bodies once — see that docstring); the
    raw compiled cost_analysis is kept as a cross-check in the record."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    flops = flops_override if flops_override is not None \
        else float(cost.get("flops", 0.0))
    byts = bytes_override if bytes_override is not None \
        else float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    total_coll = sum(v for k, v in coll.items() if k != "_counts")
    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = getattr(ma, "temp_size_in_bytes", 0) + \
            getattr(ma, "argument_size_in_bytes", 0) + \
            getattr(ma, "output_size_in_bytes", 0)
    except Exception:
        pass
    return Roofline(arch, shape, chips, flops, byts, total_coll, coll,
                    model_flops=model_flops, memory_per_device=mem)


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N_active·D for training; 2·N_active·D for a
    forward-only cell (prefill) and 2·N_active·B for one decode token."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch       # decode: one token / row
