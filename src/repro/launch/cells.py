"""(architecture × input-shape × mesh) cell construction.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, no device allocation); ``lower_cell``
builds the jitted entry point (train_step / prefill / serve_step) with
explicit in/out shardings and lowers it — the workhorse of the multi-pod
dry-run (deliverable e) and the roofline benchmarks (deliverable g).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeSpec, get_config
from repro.models.transformer import Model
from repro.train.optimizer import get_optimizer
from repro.train.trainer import batch_pspecs, make_train_step

SDS = jax.ShapeDtypeStruct


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str
    fn: Any                    # jitted function (with shardings)
    args: Tuple                # ShapeDtypeStruct pytrees
    skip: Optional[str] = None


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def input_specs(cfg: ArchConfig, shape: ShapeSpec,
                compute_dtype=jnp.bfloat16) -> Dict[str, SDS]:
    """ShapeDtypeStruct stand-ins for the *data* inputs of a cell."""
    gb, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {"tokens": SDS((gb, s), jnp.int32),
               "labels": SDS((gb, s), jnp.int32)}
    elif shape.kind == "prefill":
        out = {"tokens": SDS((gb, s), jnp.int32)}
    else:   # decode: one new token against a seq_len cache
        out = {"tokens": SDS((gb, 1), jnp.int32)}
    if cfg.family in ("vlm", "audio") and shape.kind != "decode":
        out["frontend"] = SDS((gb, cfg.frontend_len, cfg.d_model),
                              compute_dtype)
    return out


def build_cell(arch: str, shape_name: str, mesh,
               compute_dtype=jnp.bfloat16) -> Cell:
    cfg = get_config(arch)
    shape = cfg.shapes()[shape_name]
    if shape.skip:
        return Cell(arch, shape_name, shape.kind, None, (), skip=shape.skip)
    gb = shape.global_batch

    if shape.kind == "train":
        model = Model(cfg, mesh, compute_dtype=compute_dtype,
                      param_dtype=jnp.float32)
        opt = get_optimizer(cfg.optimizer)
        mb = min(cfg.microbatch or gb, gb)
        accum = max(1, gb // mb)
        pspecs = model.param_specs()
        step = make_train_step(model, opt, accum_steps=accum,
                               grad_pspecs=pspecs)
        params_sh = jax.eval_shape(lambda: model.init(0))
        opt_sh = jax.eval_shape(opt.init, params_sh)
        ospecs = opt.state_specs(pspecs)
        batch_sh = input_specs(cfg, shape, compute_dtype)
        bspecs = batch_pspecs(cfg, model.ax)
        if "frontend" in batch_sh and "frontend" not in bspecs:
            bspecs["frontend"] = P(model.ax.batch_axes, None, None)
        fn = jax.jit(
            step,
            in_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs),
                          _ns(mesh, bspecs), NamedSharding(mesh, P())),
            out_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs), None),
            donate_argnums=(0, 1),
        )
        args = (params_sh, opt_sh, batch_sh, SDS((), jnp.float32))
        return Cell(arch, shape_name, "train", fn, args)

    # Serving cells: bf16 params.
    model = Model(cfg, mesh, compute_dtype=compute_dtype,
                  param_dtype=jnp.bfloat16)
    params_sh = jax.eval_shape(lambda: model.init(0))
    pspecs = model.param_specs()

    ax = model.ax
    if shape.kind == "prefill":
        cache_sh = jax.eval_shape(
            lambda: model.init_cache(gb, shape.seq_len, dtype=jnp.bfloat16))
        cspecs = model.cache_pspecs(cache_sh)
        batch_sh = input_specs(cfg, shape, compute_dtype)
        bspecs = {"tokens": ax.spec((ax.batch_axes, None), (gb, shape.seq_len))}
        if "frontend" in batch_sh:
            bspecs["frontend"] = ax.spec(
                (ax.batch_axes, None, None), batch_sh["frontend"].shape)
        fn = jax.jit(
            model.prefill,
            in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs),
                          _ns(mesh, cspecs)),
            out_shardings=(None, _ns(mesh, cspecs)),
            donate_argnums=(2,),
        )
        return Cell(arch, shape_name, "prefill", fn,
                    (params_sh, batch_sh, cache_sh))

    # decode: serve_step with a filled cache of seq_len.
    cache_sh = jax.eval_shape(
        lambda: model.init_cache(gb, shape.seq_len, dtype=jnp.bfloat16))
    cspecs = model.cache_pspecs(cache_sh)
    tok_sh = {"tokens": SDS((gb, 1), jnp.int32)}
    fn = jax.jit(
        model.decode,
        in_shardings=(_ns(mesh, pspecs),
                      NamedSharding(mesh, ax.spec((ax.batch_axes, None),
                                                  (gb, 1))),
                      _ns(mesh, cspecs), NamedSharding(mesh, P())),
        out_shardings=(None, _ns(mesh, cspecs)),
        donate_argnums=(2,),
    )
    args = (params_sh, tok_sh["tokens"], cache_sh, SDS((), jnp.int32))
    return Cell(arch, shape_name, "decode", fn, args)


def lower_cell(cell: Cell):
    assert cell.fn is not None, f"cell {cell.arch}/{cell.shape} is skipped"
    return cell.fn.lower(*cell.args)


# ------------------------------------------------------- analytic cost path

def analytic_cost(arch: str, shape_name: str,
                  compute_dtype=jnp.bfloat16) -> Dict[str, float]:
    """Global FLOPs/bytes of one cell, counted honestly.

    XLA's ``cost_analysis`` reports per-device numbers and counts
    while-loop bodies ONCE, so scanned-layer models are undercounted by
    ~n_periods×. This path lowers the same math with python-unrolled
    layers on a single (abstract) device — no allocation, no while loops —
    and scales the microbatch gradient cost by the accumulation count.
    Remat recompute is included (the unrolled path keeps jax.checkpoint).
    """
    cfg = get_config(arch)
    shape = cfg.shapes()[shape_name]
    if shape.skip:
        return {}
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh()
    gb = shape.global_batch
    period = len(cfg.layer_pattern())

    def cost_of(fn, *args):
        from repro.models.attention import force_dense
        with force_dense():
            compiled = jax.jit(fn).lower(*args).compile()
        c = compiled.cost_analysis()
        if isinstance(c, (list, tuple)):
            c = c[0]
        return (float(c.get("flops", 0.0)),
                float(c.get("bytes accessed", 0.0)))

    def cell_cost(n_periods: int):
        """Cost of the cell at a reduced depth (fused, unsharded, global)."""
        small = dataclasses.replace(cfg, n_layers=n_periods * period,
                                    encoder_layers=min(
                                        cfg.encoder_layers, n_periods))
        if shape.kind == "train":
            model = Model(small, mesh, compute_dtype=compute_dtype,
                          unroll=True)
            mb = min(cfg.microbatch or gb, gb)
            params_sh = jax.eval_shape(lambda: model.init(0))
            mb_shape = dataclasses.replace(shape, global_batch=mb)
            batch_sh = input_specs(small, mb_shape, compute_dtype)

            def grad_step(p, b):
                return jax.value_and_grad(model.loss)(p, b)

            return cost_of(grad_step, params_sh, batch_sh)
        model = Model(small, mesh, compute_dtype=compute_dtype,
                      param_dtype=jnp.bfloat16, unroll=True)
        params_sh = jax.eval_shape(lambda: model.init(0))
        cache_sh = jax.eval_shape(
            lambda: model.init_cache(gb, shape.seq_len, dtype=jnp.bfloat16))
        if shape.kind == "prefill":
            batch_sh = input_specs(small, shape, compute_dtype)
            return cost_of(model.prefill, params_sh, batch_sh, cache_sh)
        return cost_of(model.decode, params_sh, SDS((gb, 1), jnp.int32),
                       cache_sh, SDS((), jnp.int32))

    # Linear extrapolation in depth: cost(N) = cost(1) + (N-1)·Δ where
    # Δ = cost(2) − cost(1). Exact for depth-uniform models (all of ours),
    # and keeps unsharded compile times flat across the 40-cell grid.
    f1, b1 = cell_cost(1)
    f2, b2 = cell_cost(2)
    n = cfg.n_periods
    flops = f1 + (f2 - f1) * (n - 1)
    byts = b1 + (b2 - b1) * (n - 1)
    if shape.kind == "train":
        mb = min(cfg.microbatch or gb, gb)
        accum = max(1, gb // mb)
        flops *= accum
        byts *= accum
        opt = get_optimizer(cfg.optimizer)
        model = Model(cfg, mesh, compute_dtype=compute_dtype)
        params_sh = jax.eval_shape(lambda: model.init(0))
        opt_sh = jax.eval_shape(opt.init, params_sh)

        def opt_step(g, s, p):
            return opt.update(g, s, p, jnp.float32(1e-4))

        f_opt, b_opt = cost_of(opt_step, params_sh, opt_sh, params_sh)
        flops += f_opt
        byts += b_opt
    return {"flops": flops, "bytes": byts}
