import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles every (architecture × input shape) cell against the
production meshes — 16×16 (one pod, 256 chips) and 2×16×16 (two pods,
512 chips) — and records memory/cost/collective analysis per cell to
``results/dryrun_<mesh>.json`` for EXPERIMENTS.md §Dry-run and the
roofline benchmarks.

The XLA_FLAGS line above MUST run before any other import (jax locks the
device count at first init); do not move it.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun [--multi-pod] \
        [--arch gemma2_2b] [--shape train_4k] [--out results/]
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, get_config
from repro.launch import hlo_analysis
from repro.launch.cells import build_cell, lower_cell
from repro.launch.mesh import make_production_mesh


def run_cell(arch: str, shape_name: str, mesh, chips: int) -> dict:
    cfg = get_config(arch)
    shape = cfg.shapes()[shape_name]
    rec = {"arch": arch, "shape": shape_name, "kind": shape.kind,
           "chips": chips, "status": None}
    t0 = time.time()
    try:
        cell = build_cell(arch, shape_name, mesh)
        if cell.skip:
            rec["status"] = "skip"
            rec["reason"] = cell.skip
            rec["seconds"] = round(time.time() - t0, 1)
            return rec
        lowered = lower_cell(cell)
        compiled = lowered.compile()
        from repro.launch.cells import analytic_cost
        try:
            ana = analytic_cost(arch, shape_name)
        except Exception as e:
            ana = {}
            rec["analytic_error"] = f"{type(e).__name__}: {e}"
        roof = hlo_analysis.analyze(
            arch, shape_name, lowered, compiled, chips,
            model_flops=hlo_analysis.model_flops_estimate(cfg, shape),
            flops_override=ana.get("flops"),
            bytes_override=ana.get("bytes"))
        cost_raw = compiled.cost_analysis()
        if isinstance(cost_raw, (list, tuple)):
            cost_raw = cost_raw[0]
        rec["xla_flops_per_device_raw"] = float(cost_raw.get("flops", 0.0))
        rec["xla_bytes_per_device_raw"] = float(
            cost_raw.get("bytes accessed", 0.0))
        rec.update(roof.row())
        rec["coll_detail"] = {k: v for k, v in roof.coll_detail.items()
                              if k != "_counts"}
        rec["coll_counts"] = roof.coll_detail.get("_counts", {})
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                "argument_size_gb": ma.argument_size_in_bytes / 2**30,
                "output_size_gb": ma.output_size_in_bytes / 2**30,
                "temp_size_gb": ma.temp_size_in_bytes / 2**30,
                "generated_code_size_mb":
                    ma.generated_code_size_in_bytes / 2**20,
            }
        except Exception as e:                       # backend-dependent
            rec["memory_analysis"] = f"unavailable: {e}"
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["seconds"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--out", default="results")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, \
        f"dry-run expects 512 placeholder devices, got {len(jax.devices())}"
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    chips = mesh.size if not args.multi_pod else mesh.size
    tag = "multipod" if args.multi_pod else "singlepod"
    # Single-pod mesh uses 256 of the 512 placeholder devices.
    chips = 512 if args.multi_pod else 256

    archs = [args.arch] if args.arch else ARCH_IDS
    shapes = [args.shape] if args.shape else \
        ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, f"dryrun_{tag}.json")
    results = []
    if os.path.exists(path):
        with open(path) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"]) for r in results if r["status"] == "ok"}

    for arch in archs:
        for shape in shapes:
            if (arch, shape) in done:
                continue
            rec = run_cell(arch, shape, mesh, chips)
            results = [r for r in results
                       if not (r["arch"] == arch and r["shape"] == shape)]
            results.append(rec)
            status = rec["status"]
            extra = rec.get("reason", rec.get("error", ""))
            print(f"[{tag}] {arch:22s} {shape:12s} {status:5s} "
                  f"{rec['seconds']:7.1f}s  {extra[:80]}", flush=True)
            with open(path, "w") as f:
                json.dump(results, f, indent=1)

    ok = sum(r["status"] == "ok" for r in results)
    skip = sum(r["status"] == "skip" for r in results)
    fail = sum(r["status"] == "fail" for r in results)
    print(f"[{tag}] done: {ok} ok / {skip} skip / {fail} fail → {path}")


if __name__ == "__main__":
    main()
