"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm_135m \
        --steps 200 --batch 8 --seq-len 128 [--reduced] \
        [--checkpoint-dir /tmp/ckpt] [--resume]

On the CPU container this trains reduced configs for real (the quickstart
path); on TPU the same launcher scales to the production mesh (mesh shape
is chosen from the available device count).
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ARCH_IDS, get_config, reduced_config
from repro.train.trainer import TrainJob, TrainJobConfig


def pick_mesh():
    devs = np.array(jax.devices())
    n = len(devs)
    model = 1
    for cand in (16, 8, 4, 2, 1):
        if n % cand == 0 and n >= cand * cand:
            model = cand
            break
    data = n // model
    return Mesh(devs.reshape(data, model), ("data", "model"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced smoke config of the arch")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--data-path", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    mesh = pick_mesh()
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")
    job = TrainJob(cfg, TrainJobConfig(
        arch=args.arch, steps=args.steps, batch=args.batch,
        seq_len=args.seq_len, lr=args.lr, accum_steps=args.accum,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
        data_path=args.data_path, seed=args.seed), mesh)
    result = job.run()
    first = job.history[0] if job.history else float("nan")
    print(json.dumps({**result, "first_loss": first}, indent=1))


if __name__ == "__main__":
    main()
