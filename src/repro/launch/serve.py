"""Serving launcher — autoscaled WS TRE with batched requests.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm_135m \
        --requests 40 --ticks 200 [--reduced]

Drives the serving engine with a synthetic Poisson request load, the
§6.4 instance-adjustment policy autoscaling replicas, and prints the
paper's WS metrics (throughput, avg response time, instance trace).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.configs.base import ARCH_IDS, get_config, reduced_config
from repro.launch.mesh import make_local_mesh
from repro.serving.autoscaler import AutoscaledService
from repro.serving.engine import Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--ticks", type=int, default=300)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch)) if args.reduced \
        else get_config(args.arch)
    mesh = make_local_mesh()
    svc = AutoscaledService(cfg, mesh, slots_per_replica=4, max_len=64)
    rng = np.random.default_rng(args.seed)
    arrivals = np.sort(rng.uniform(0, args.ticks * 0.6,
                                   size=args.requests)).tolist()
    instance_trace = []
    rid = 0
    t0 = time.time()
    for tick in range(args.ticks):
        while arrivals and arrivals[0] <= tick:
            arrivals.pop(0)
            svc.submit(Request(
                rid=rid,
                prompt=rng.integers(0, cfg.vocab,
                                    size=args.prompt_len).astype(np.int32),
                max_new_tokens=args.max_new))
            rid += 1
        svc.tick(now=float(tick))
        instance_trace.append(len(svc.replicas))
        if not arrivals and not svc.queue and \
                all(r.n_active == 0 for r in svc.replicas):
            break
    wall = time.time() - t0
    lat = [r.completed - r.submitted for r in svc.completed if r.completed]
    print(json.dumps({
        "completed": len(svc.completed),
        "throughput_tokens": sum(len(r.output or []) for r in svc.completed),
        "avg_response_s": float(np.mean(lat)) if lat else None,
        "max_instances": max(instance_trace),
        "final_instances": instance_trace[-1],
        "wall_s": round(wall, 2),
    }, indent=1))


if __name__ == "__main__":
    main()
