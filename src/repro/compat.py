"""Version-compat shims for the JAX API surface we use.

``shard_map`` moved twice across JAX releases: it lives at
``jax.experimental.shard_map.shard_map`` (with a ``check_rep`` kwarg)
up to ~0.4.x and graduates to ``jax.shard_map`` (kwarg renamed
``check_vma``) in newer releases. Import it from here so model and test
code runs on both.

``jit`` here additionally normalizes *buffer donation*: XLA only
implements input-output aliasing on some backends, and donating on the
others (plain CPU most notably) makes every jitted call emit a
"donated buffers were not usable" warning. The shim keeps
``donate_argnums`` on backends that honor it and silently drops it
elsewhere, so callers can donate their large carry/lane buffers
unconditionally.
"""

from __future__ import annotations

import functools
from typing import List, Optional, Sequence, Union

import jax

try:
    _shard_map = jax.shard_map            # jax >= 0.6 top-level API
    _CHECK_KWARG = "check_vma"
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KWARG = "check_rep"

__all__ = ["shard_map", "axis_size", "resolve_devices", "jit",
           "supports_donation", "resolve_pack_dtype"]

# Backends with working input-output aliasing. XLA:CPU parses the
# aliasing hint but does not consume it — every donated call would warn
# and nothing would be saved — so donation is gated to these platforms.
_DONATING_PLATFORMS = ("gpu", "tpu", "cuda", "rocm")


def supports_donation(platform: Optional[str] = None) -> bool:
    """True when ``donate_argnums`` buys in-place reuse on ``platform``
    (default: the default jax backend) instead of a warning per call."""
    if platform is None:
        platform = jax.default_backend()
    return platform.lower() in _DONATING_PLATFORMS


def jit(fn=None, *, donate_argnums=(), platform: Optional[str] = None,
        **kwargs):
    """``jax.jit`` with ``donate_argnums`` dropped on backends that do
    not implement buffer donation (see module docstring). All other
    keyword arguments pass through; usable as a decorator or a call.

    The backend probe is deferred to the first call: module-level
    decoration must not initialize the XLA backend, or merely importing
    a module would freeze the host device count before
    ``XLA_FLAGS=--xla_force_host_platform_device_count`` (the
    ``repro.hostdev`` flow) can take effect.

    ``platform`` overrides the backend probe (tests use it to pin the
    gate's behavior without a real accelerator).
    """
    if fn is None:
        return lambda f: jit(f, donate_argnums=donate_argnums,
                             platform=platform, **kwargs)
    if not donate_argnums:
        return jax.jit(fn, **kwargs)

    jitted: List = []

    @functools.wraps(fn)
    def wrapper(*args, **kw):
        if not jitted:
            jit_kwargs = dict(kwargs)
            if supports_donation(platform):
                jit_kwargs["donate_argnums"] = donate_argnums
            jitted.append(jax.jit(fn, **jit_kwargs))
        return jitted[0](*args, **kw)

    return wrapper

def resolve_pack_dtype(dtype=None):
    """Default a packing dtype to the active jax x64 setting; reject a
    float64 request that ``jnp.asarray`` would silently downcast. The
    one canonical copy for every pack path (``repro.sim.scan``,
    ``repro.sim.rounds``, ``repro.sim.scenarios``,
    ``repro.core.jaxsim``)."""
    import numpy as np
    if dtype is None:
        return np.float64 if jax.config.jax_enable_x64 else np.float32
    if np.dtype(dtype) == np.float64 and not jax.config.jax_enable_x64:
        raise ValueError(
            "dtype=float64 requested with jax x64 disabled — jnp.asarray "
            "would silently downcast to float32; wrap the call in "
            "jax.experimental.enable_x64()")
    return np.dtype(dtype)


# The devices argument accepted across the repo's sharded entry points:
# a device count, an explicit device sequence, or None (single-device).
Devices = Optional[Union[int, Sequence["jax.Device"]]]


def resolve_devices(devices: Devices) -> Optional[List["jax.Device"]]:
    """Normalize a ``devices`` option to a device list, or ``None``.

    ``None`` means single-device execution; an int ``n`` takes the first
    ``n`` visible devices; an explicit sequence is used as-is. A resolved
    list of fewer than two devices collapses to ``None`` — sharding over
    one device buys nothing, and single-device callers keep their plain
    (bit-identical) path. On a CPU-only host, multiple XLA devices exist
    only when ``XLA_FLAGS=--xla_force_host_platform_device_count=n`` was
    set before jax initialized — the error message says so, because that
    is the whole trick to harvesting multi-core from one process.
    """
    if devices is None:
        return None
    if isinstance(devices, int):
        if devices < 1:
            raise ValueError(f"devices must be >= 1, got {devices}")
        avail = jax.devices()
        if devices > len(avail):
            raise ValueError(
                f"devices={devices} requested but only {len(avail)} jax "
                f"device(s) visible; on CPU set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={devices} in the "
                f"environment before jax is imported")
        devs = list(avail[:devices])
    else:
        devs = list(devices)
    return devs if len(devs) > 1 else None


def axis_size(axis: str) -> int:
    """Static size of a named mesh axis, from inside ``shard_map``.

    ``jax.lax.axis_size`` only exists in newer releases; on older ones
    ``psum(1, axis)`` of a Python constant folds to a static int.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def shard_map(f, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` with the replication-check kwarg normalized to
    the new-API name (``check_vma``); ``None`` keeps the default."""
    kwargs = {} if check_vma is None else {_CHECK_KWARG: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)
