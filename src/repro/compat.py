"""Version-compat shims for the JAX API surface we use.

``shard_map`` moved twice across JAX releases: it lives at
``jax.experimental.shard_map.shard_map`` (with a ``check_rep`` kwarg)
up to ~0.4.x and graduates to ``jax.shard_map`` (kwarg renamed
``check_vma``) in newer releases. Import it from here so model and test
code runs on both.
"""

from __future__ import annotations

import jax

try:
    _shard_map = jax.shard_map            # jax >= 0.6 top-level API
    _CHECK_KWARG = "check_vma"
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KWARG = "check_rep"

__all__ = ["shard_map", "axis_size"]


def axis_size(axis: str) -> int:
    """Static size of a named mesh axis, from inside ``shard_map``.

    ``jax.lax.axis_size`` only exists in newer releases; on older ones
    ``psum(1, axis)`` of a Python constant folds to a static int.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def shard_map(f, mesh, in_specs, out_specs, check_vma=None):
    """``jax.shard_map`` with the replication-check kwarg normalized to
    the new-API name (``check_vma``); ``None`` keeps the default."""
    kwargs = {} if check_vma is None else {_CHECK_KWARG: check_vma}
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)
