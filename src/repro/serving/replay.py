"""Trace replay through the *live* serving stack (§6.5 as a live run).

The reference simulator feeds a WS demand trace straight into the
provision service. This module replays the same trace through the other
side of the repo — the serving engine — so the two paths can be diffed:

  1. the demand trace becomes a deterministic **request-arrival stream**
     (``ArrivalClock`` — fractional-carry, no RNG);
  2. requests are served by an :class:`AutoscaledService` built on
     :class:`VirtualReplica` (Replica's slot lifecycle with a fixed
     tokens-per-request latency model, no forward pass — days of trace
     in seconds of wall clock);
  3. the §6.4 instance-adjustment policy watches slot utilization and
     its ``nodes_needed`` is fed back into the shared
     :class:`~repro.core.runtime_bridge.LiveCloud` pump as WS demand —
     the same ``on_ws_demand`` path, the same ledger schema, the same
     clock as the simulator.

Arrival calibration: a request holds one slot for ``hold`` serve ticks,
so Little's law gives active-per-instance ``A = rate·hold``. We pick the
per-demand-unit rate ``rho·slots/hold`` (``rho`` just under the 80 %
threshold), which drives per-instance utilization to ``rho·d/n`` — the
policy's fixed point is ``n ≈ ceil(rho/0.8 · d) ≈ d`` instances, i.e.
the autoscaler *re-derives* the trace's node demand from traffic alone.
The live-vs-sim contract (``CONTRACTS["live"]``) bounds how far that
derived curve may drift from the replayed one.

Capacity note: the FB service caps WS grants at C, but the §6.4 policy
has no upper bound — size ``capacity`` at or above the trace peak (as
the paper's FB experiments do) or the manager's count and the granted
nodes diverge during saturation.
"""

from __future__ import annotations

import bisect
import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.jobs import Job
from repro.core.runtime_bridge import LiveCloud
from repro.core.ws_manager import InstanceAdjustmentPolicy, WSManager
from repro.serving.autoscaler import AutoscaledService, GrantBackoff
from repro.serving.engine import Request, VirtualReplica
from repro.sim.engine import SimResult, default_duration, summarize
from repro.sim.pump import CALL, WS, DecisionLedger


class ArrivalClock:
    """Deterministic arrival stream: ``rate`` arrivals per serve tick per
    demand unit, fractional remainders carried — replaying a trace twice
    yields byte-identical request streams."""

    def __init__(self, rate: float):
        self.rate = rate
        self.carry = 0.0

    def tick(self, demand: float) -> int:
        self.carry += demand * self.rate
        n = int(self.carry)
        self.carry -= n
        return n


def demand_step_series(ws_trace: Sequence[Tuple[float, int]]
                       ) -> List[Tuple[float, int]]:
    """Normalize a WS trace to a step series starting at t=0 (entries at
    t<=0 collapse to the initial value, matching the pump's startup
    collapse)."""
    entries = sorted(ws_trace, key=lambda e: e[0])
    initial = 0
    series: List[Tuple[float, int]] = []
    for t, d in entries:
        if t <= 0:
            initial = int(d)
        else:
            series.append((float(t), int(d)))
    return [(0.0, initial)] + series


@dataclasses.dataclass
class ReplayResult:
    row: SimResult                     # same shape the simulator emits
    ledger: DecisionLedger             # every grant/kill/ws/tick, timed
    trace_demand: List[Tuple[float, int]]     # the replayed step series
    derived_demand: List[Tuple[float, int]]   # what the autoscaler asked
    requests_completed: int
    peak_instances: int
    shed_requests: int = 0      # admission-throttled arrivals (chaos tier)
    grant_retries: int = 0      # backed-off demand re-posts after a
    #                             short grant (failed capacity)


class _ServeDriver:
    """The self-rescheduling serve tick: a CALL event on the LiveCloud
    pump that generates arrivals, steps the service, and re-posts WS
    demand whenever the autoscaler's node need moves."""

    def __init__(self, cloud: LiveCloud, service: AutoscaledService,
                 trace: List[Tuple[float, int]], clock: ArrivalClock,
                 hold: int, dt: float, duration: float,
                 backoff: Optional[GrantBackoff] = None):
        self.cloud = cloud
        self.service = service
        self.times = [t for t, _ in trace]
        self.values = [d for _, d in trace]
        self.clock = clock
        self.hold = hold
        self.dt = dt
        self.duration = duration
        self._rid = 0
        self._last_need = service.manager.nodes_needed
        self.peak_instances = len(service.replicas)
        # Chaos tier: when the provision service grants fewer nodes
        # than asked (failures shed the difference), re-assert the
        # demand after a bounded jittered-exponential delay instead of
        # every serve tick. None (the no-fault default) keeps the event
        # stream byte-identical to the pre-fault replay.
        self.backoff = backoff
        self._retry_at = -math.inf
        self.grant_retries = 0

    def demand_at(self, t: float) -> int:
        i = bisect.bisect_right(self.times, t) - 1
        return self.values[i] if i >= 0 else 0

    def start(self) -> None:
        self.cloud.pump.push(self.dt, CALL, self)

    def __call__(self, t: float):
        for _ in range(self.clock.tick(self.demand_at(t))):
            self.service.submit(
                Request(rid=self._rid, prompt=np.zeros(4, np.int32),
                        max_new_tokens=self.hold), now=t)
            self._rid += 1
        self.service.tick(now=t)
        self.peak_instances = max(self.peak_instances,
                                  len(self.service.replicas))
        need = self.service.manager.nodes_needed
        if need != self._last_need:
            # Same-time WS sorts ahead of the next CALL: the provision
            # service reacts before another serve tick runs.
            self._last_need = need
            self.cloud.pump.push(t, WS, need)
            if self.backoff is not None:
                self.backoff.reset()
                self._retry_at = -math.inf
        elif self.backoff is not None and t >= self._retry_at:
            # Grant shortfall (failed nodes shed part of the demand):
            # re-post the same demand after a backed-off delay — a
            # repair in between turns the retry into a real grow.
            granted = self.cloud.service.cluster.allocated(
                self.cloud.ws.name)
            if granted < self._last_need:
                delay = self.backoff.next_delay()
                if delay is not None and t + delay <= self.duration:
                    self._retry_at = t + delay
                    self.grant_retries += 1
                    self.cloud.pump.push(t + delay, WS, self._last_need)
                else:
                    self._retry_at = math.inf   # exhausted: wait for a
                    #                             real demand change
            else:
                self.backoff.reset()
        if t + self.dt <= self.duration:
            self.cloud.pump.push(t + self.dt, CALL, self)
        return []


def replay(jobs: Sequence[Job], ws_trace: Sequence[Tuple[float, int]],
           capacity: int, *, slots: int = 8, hold: int = 4,
           rho: float = 0.78, serve_dt: float = 30.0,
           lease_seconds: float = 3600.0,
           duration: Optional[float] = None,
           faults=None, max_queue: Optional[int] = None,
           backoff: Optional[GrantBackoff] = None,
           name: str = "live") -> ReplayResult:
    """Replay ``ws_trace`` as live traffic against a ``LiveCloud`` that
    is simultaneously running ``jobs`` as its PBJ workload. Returns the
    simulator-shaped result row plus both demand curves for diffing.

    Chaos tier: ``faults`` injects a
    :class:`repro.sim.faults.FaultSchedule` on the shared pump;
    ``max_queue`` turns on load-shedding admission at the serving layer;
    ``backoff`` bounds grant-shortfall retries (defaults to a seeded
    :class:`GrantBackoff` whenever faults are injected — without
    faults the retry machinery stays off so no-fault replays remain
    byte-identical to the pre-fault stack)."""
    if duration is None:
        duration = default_duration(jobs, ws_trace)
    trace = demand_step_series(ws_trace)
    d0 = trace[0][1]
    policy = InstanceAdjustmentPolicy(
        initial_instances=max(1, d0), min_instances=1,
        nodes_per_instance=1, window_seconds=2 * serve_dt)
    manager = WSManager(policy=policy)
    cloud = LiveCloud(capacity, lease_seconds=lease_seconds,
                      duration=duration, ws_initial=d0, ws=manager)
    service = AutoscaledService(
        policy=policy, slots_per_replica=slots, manager=manager,
        replica_factory=lambda: VirtualReplica(slots),
        max_queue=max_queue)
    cloud.load_trace(jobs, ws_trace=(), lease_ticks=True)
    if faults is not None:
        cloud.inject_faults(faults)
        if backoff is None:
            backoff = GrantBackoff(base=2 * serve_dt,
                                   max_delay=max(600.0, 2 * serve_dt),
                                   seed=0)
    driver = _ServeDriver(cloud, service, trace,
                          ArrivalClock(rho * slots / hold),
                          hold, serve_dt, duration, backoff=backoff)
    driver.start()
    cloud.run_until(duration)
    row = summarize(cloud.service, list(jobs), duration, name)
    return ReplayResult(
        row=row, ledger=cloud.ledger, trace_demand=trace,
        derived_demand=cloud.ledger.demand_series(),
        requests_completed=len(service.completed),
        peak_instances=driver.peak_instances,
        shed_requests=service.shed_requests,
        grant_retries=driver.grant_retries)
