"""Replica autoscaler — the WS TRE's instance-adjustment loop (§6.4).

Bridges the live serving engine to ``core.ws_manager.WSManager``: slot
utilization across replicas feeds ``observe_utilization``; when the 80 %
policy fires, replicas are added/removed and the node delta is
requested/released from the provision service (the PhoenixCloud
coordination point).

Shrink is a *drain* protocol: the policy marks the least-loaded replica
draining (the router stops sending it traffic), the replica keeps
serving its outstanding slots, and only when it empties is it removed —
at which point ``WSManager.confirm_shrink`` drops the instance count and
the node lease behind it. The manager's count and ``len(replicas)``
therefore agree at every tick boundary, by construction.

``replica_factory`` selects the payload tier: the default builds real
``Replica``s (model forward passes — the smoke tier); the replay layer
(``repro.serving.replay``) passes a ``VirtualReplica`` factory so
replayed days of trace run in seconds.
"""

from __future__ import annotations

import random
import time
from typing import Callable, List, Optional

from repro.core.ws_manager import InstanceAdjustmentPolicy, WSManager
from repro.serving.engine import (LeastLoadedRouter, Replica, Request,
                                  SlotPool)


class GrantBackoff:
    """Bounded, deterministic jittered exponential backoff for the WS
    grow path under degraded capacity (the chaos tier).

    When the provision service grants fewer nodes than the autoscaler
    asked for (nodes failed, demand shed), retrying immediately would
    hammer a cluster that cannot satisfy the request until a repair
    lands. Instead the caller asks :meth:`next_delay` how long to wait
    before re-posting the demand: ``base * 2^attempt`` seconds, jittered
    by a seeded ``random.Random`` (equal-jitter — uniform in (d/2, d])
    so replicated services don't retry in lockstep, capped at ``max_delay``
    and at ``max_retries`` attempts (then ``None`` — give up until the
    demand itself changes). Seeded, so a replayed trace backs off
    identically run to run. :meth:`reset` rearms after a full grant."""

    def __init__(self, base: float = 30.0, max_delay: float = 600.0,
                 max_retries: int = 6, seed: int = 0):
        if base <= 0 or max_delay < base or max_retries < 1:
            raise ValueError("need base > 0, max_delay >= base, "
                             "max_retries >= 1")
        self.base = base
        self.max_delay = max_delay
        self.max_retries = max_retries
        self._rng = random.Random(seed)
        self.attempt = 0

    def next_delay(self) -> Optional[float]:
        """Delay before the next retry, or ``None`` when exhausted."""
        if self.attempt >= self.max_retries:
            return None
        d = min(self.base * (2.0 ** self.attempt), self.max_delay)
        self.attempt += 1
        return d * (1.0 - 0.5 * self._rng.random())   # (d/2, d] jitter

    def reset(self) -> None:
        self.attempt = 0


class AutoscaledService:
    def __init__(self, cfg=None, mesh=None, *,
                 policy: Optional[InstanceAdjustmentPolicy] = None,
                 slots_per_replica: int = 8, max_len: int = 128,
                 params=None,
                 on_scale: Optional[Callable[[int, int], None]] = None,
                 replica_factory: Optional[Callable[[], SlotPool]] = None,
                 manager: Optional[WSManager] = None,
                 max_queue: Optional[int] = None):
        if policy is None:
            policy = InstanceAdjustmentPolicy(
                nodes_per_instance=cfg.serve_chips_per_replica
                if cfg is not None else 1)
        self.cfg = cfg
        self.mesh = mesh
        self.policy = policy
        # A shared manager lets one WSManager serve both roles at once:
        # the autoscaler's instance ledger here AND the provision
        # service's WS TRE in a LiveCloud (the replay wiring).
        self.manager = manager if manager is not None else \
            WSManager(policy=policy)
        self.slots = slots_per_replica
        self.max_len = max_len
        self.router = LeastLoadedRouter()
        self.on_scale = on_scale       # callback(old_n, new_n) → provision
        self._params = params
        self._factory = replica_factory or self._real_replica
        self.replicas: List[SlotPool] = []
        self.draining: List[SlotPool] = []
        self._mk_replica_count = 0
        for _ in range(self.policy.initial_instances):
            self._add_replica()
        self.queue: List[Request] = []
        self.completed: List[Request] = []
        # Load-shedding mode (chaos tier): with ``max_queue`` set, a
        # request arriving at a full backlog is refused instead of
        # queued — graceful degradation while failed nodes keep the
        # autoscaler's grants short. Shed requests are counted, never
        # silently dropped.
        self.max_queue = max_queue
        self.shed_requests = 0

    def _real_replica(self) -> Replica:
        r = Replica(self.cfg, self.mesh, slots=self.slots,
                    max_len=self.max_len, params=self._params)
        if self._params is None:
            self._params = r.params       # share weights across replicas
        return r

    def _add_replica(self):
        self.replicas.append(self._factory())
        self._mk_replica_count += 1

    def submit(self, req: Request, now: Optional[float] = None) -> bool:
        """Admit a request; returns False (and counts the shed) when the
        backlog is at ``max_queue``."""
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            self.shed_requests += 1
            return False
        req.submitted = time.time() if now is None else now
        self.queue.append(req)
        return True

    @property
    def utilization(self) -> float:
        if not self.replicas:
            return 1.0
        return sum(r.n_active for r in self.replicas) / \
            sum(r.slots for r in self.replicas)

    def tick(self, now: float):
        """One scheduling tick: admit, decode, drain, autoscale."""
        old = len(self.replicas)
        # Admit queued requests to the least-loaded serving replicas
        # (draining replicas take no new traffic — that is the drain).
        serving = [r for r in self.replicas if r not in self.draining]
        still = []
        for req in self.queue:
            target = self.router.route(serving)
            if target is None or not target.admit(req):
                still.append(req)
        self.queue = still
        # Sample utilization HERE — serving slots occupied during this
        # tick, after admission and before retirement. Sampling after
        # step() would read just-finished slots as idle and sit below
        # the 80 % threshold even with an unbounded backlog; sampling
        # after admit reads a backed-up service as exactly 1.0
        # (admission only leaves a queue when every serving slot is
        # full). Draining replicas are excluded: the policy decides on
        # serving instances, so their slots would only dilute the
        # signal.
        util = (sum(r.n_active for r in serving) /
                sum(r.slots for r in serving)) if serving else 1.0
        # Decode step on every replica — draining ones included; they
        # still owe their outstanding requests.
        for r in self.replicas:
            self.completed.extend(r.step())
        self._retire_drained()
        # Autoscaling (the §6.4 policy, verbatim thresholds).
        target_n = self.manager.observe_utilization(now, util)
        if target_n is not None:
            self._apply_target(target_n)
            self._retire_drained()     # an already-idle mark goes at once
        if self.on_scale and len(self.replicas) != old:
            self.on_scale(old, len(self.replicas))

    # ------------------------------------------------------ drain machinery

    def _apply_target(self, n: int) -> None:
        """Match the number of *serving* replicas to the manager's
        target. Grow resurrects a draining replica before building a new
        one (mirroring WSManager's bookkeeping); shrink marks the
        least-loaded serving replica draining."""
        while len(self.replicas) - len(self.draining) < n:
            if self.draining:
                self.draining.pop()
            else:
                self._add_replica()
        while len(self.replicas) - len(self.draining) > n:
            serving = [r for r in self.replicas if r not in self.draining]
            self.draining.append(min(serving, key=lambda r: r.n_active))

    def _retire_drained(self) -> None:
        for r in [d for d in self.draining if d.n_active == 0]:
            self.draining.remove(r)
            self.replicas.remove(r)
            self.manager.confirm_shrink()
