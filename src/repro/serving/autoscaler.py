"""Replica autoscaler — the WS TRE's instance-adjustment loop (§6.4).

Bridges the live serving engine to ``core.ws_manager.WSManager``: slot
utilization across replicas feeds ``observe_utilization``; when the 80 %
policy fires, replicas are added/removed and the node delta is
requested/released from the provision service (the PhoenixCloud
coordination point).
"""

from __future__ import annotations

import time
from typing import Callable, List, Optional

from repro.configs.base import ArchConfig
from repro.core.ws_manager import InstanceAdjustmentPolicy, WSManager
from repro.serving.engine import LeastLoadedRouter, Replica, Request


class AutoscaledService:
    def __init__(self, cfg: ArchConfig, mesh, *,
                 policy: Optional[InstanceAdjustmentPolicy] = None,
                 slots_per_replica: int = 8, max_len: int = 128,
                 params=None,
                 on_scale: Optional[Callable[[int, int], None]] = None):
        self.cfg = cfg
        self.mesh = mesh
        self.policy = policy or InstanceAdjustmentPolicy(
            nodes_per_instance=cfg.serve_chips_per_replica)
        self.manager = WSManager(policy=self.policy)
        self.slots = slots_per_replica
        self.max_len = max_len
        self.router = LeastLoadedRouter()
        self.on_scale = on_scale       # callback(old_n, new_n) → provision
        self._params = params
        self.replicas: List[Replica] = []
        self._mk_replica_count = 0
        for _ in range(self.policy.initial_instances):
            self._add_replica()
        self.queue: List[Request] = []
        self.completed: List[Request] = []

    def _add_replica(self):
        r = Replica(self.cfg, self.mesh, slots=self.slots,
                    max_len=self.max_len, params=self._params)
        if self._params is None:
            self._params = r.params       # share weights across replicas
        self.replicas.append(r)
        self._mk_replica_count += 1

    def submit(self, req: Request):
        req.submitted = time.time()
        self.queue.append(req)

    @property
    def utilization(self) -> float:
        if not self.replicas:
            return 1.0
        return sum(r.n_active for r in self.replicas) / \
            sum(r.slots for r in self.replicas)

    def tick(self, now: float):
        """One scheduling tick: admit, decode, autoscale."""
        # Admit queued requests to the least-loaded replicas.
        still = []
        for req in self.queue:
            target = self.router.route(self.replicas)
            if target is None or not target.admit(req):
                still.append(req)
        self.queue = still
        # Decode step on every replica.
        for r in self.replicas:
            self.completed.extend(r.step())
        # Autoscaling (the §6.4 policy, verbatim thresholds).
        new_count = self.manager.observe_utilization(now, self.utilization)
        if new_count is not None and new_count != len(self.replicas):
            old = len(self.replicas)
            while len(self.replicas) < new_count:
                self._add_replica()
            while len(self.replicas) > new_count:
                idle = [r for r in self.replicas if r.n_active == 0]
                if not idle:
                    break                 # drain before shrink
                self.replicas.remove(idle[-1])
            if self.on_scale and len(self.replicas) != old:
                self.on_scale(old, len(self.replicas))
