"""Serving engine — continuous-batching decode over KV caches.

A ``Replica`` is the WS TRE's unit of scaling (== the paper's "Web
service instance"): it owns a fixed pool of decode slots; requests are
prefilled into free slots and all active slots step together — each at
its OWN cache position (per-slot ``pos``, the continuous-batching
invariant). Slot occupancy is the utilization signal the paper's §6.4
instance-adjustment policy consumes (the 80 % rule), via
``Replica.utilization``.

``VirtualReplica`` is the replay tier: the identical slot lifecycle and
utilization signal with a fixed tokens-per-request latency model instead
of a Model forward pass — days of replayed World Cup traffic run in
seconds of wall clock, while the real-``Replica`` path stays as the
smoke tier (``repro.serving.replay``).

``LeastLoadedRouter`` is the LVS least-connection analogue: requests go
to the replica with the fewest outstanding slots.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models.transformer import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int = 16
    submitted: float = 0.0
    completed: float = 0.0
    output: Optional[List[int]] = None


class SlotPool:
    """The slot-occupancy surface shared by the real and virtual tiers:
    whatever serves requests, the router and the §6.4 policy only ever
    see ``n_active`` / ``utilization`` / ``free_slot``."""

    slots: int
    active: Dict[int, Request]

    @property
    def n_active(self) -> int:
        return len(self.active)

    @property
    def utilization(self) -> float:
        return self.n_active / self.slots

    def free_slot(self) -> Optional[int]:
        for s in range(self.slots):
            if s not in self.active:
                return s
        return None


class Replica(SlotPool):
    def __init__(self, cfg: ArchConfig, mesh, slots: int = 8,
                 max_len: int = 256, compute_dtype=jnp.float32,
                 params=None, seed: int = 0):
        self.cfg = cfg
        self.model = Model(cfg, mesh, compute_dtype=compute_dtype)
        self.params = params if params is not None else self.model.init(seed)
        self.slots = slots
        self.max_len = max_len
        self.cache = self.model.init_cache(slots, max_len,
                                           dtype=compute_dtype)
        self.pos = np.zeros(slots, np.int32)       # next write position
        self.remaining = np.zeros(slots, np.int32)
        self.active: Dict[int, Request] = {}       # slot → request
        self.last_token = np.zeros(slots, np.int32)
        self._decode = jax.jit(self.model.decode)
        self._prefill = jax.jit(self.model.prefill)

    # ----------------------------------------------------------- serving

    def admit(self, req: Request) -> bool:
        slot = self.free_slot()
        if slot is None:
            return False
        # Prefill the slot: run the prompt through a single-row cache and
        # splice it in (batch=1 prefill keeps latency bounded).
        row_cache = self.model.init_cache(1, self.max_len,
                                          dtype=self.cache_dtype())
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        if self.cfg.family in ("vlm", "audio"):
            batch["frontend"] = jnp.zeros(
                (1, self.cfg.frontend_len, self.cfg.d_model), jnp.float32)
        logits, row_cache = self._prefill(self.params, batch, row_cache)
        self.cache = jax.tree.map(
            lambda full, row: jax.lax.dynamic_update_slice(
                full, row.astype(full.dtype),
                (0, slot) + (0,) * (full.ndim - 2)),
            self.cache, row_cache)
        self.active[slot] = req
        self.pos[slot] = len(req.prompt)
        self.remaining[slot] = req.max_new_tokens
        self.last_token[slot] = int(jnp.argmax(logits[0, -1]))
        req.output = [self.last_token[slot]]
        return True

    def cache_dtype(self):
        return jax.tree.leaves(self.cache)[0].dtype

    def step(self) -> List[Request]:
        """One decode step for all active slots; returns finished reqs."""
        if not self.active:
            return []
        toks = jnp.asarray(self.last_token[:, None])
        # Per-slot write positions: with heterogeneous prompt lengths
        # every slot rotates, writes and masks at its own cache position
        # (inactive rows scatter at stale positions — harmless, admit
        # re-splices the whole row cache).
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, toks, self.cache, pos)
        nxt = np.asarray(jnp.argmax(logits[:, 0, :], axis=-1), np.int32)
        finished = []
        for slot, req in list(self.active.items()):
            self.last_token[slot] = nxt[slot]
            req.output.append(int(nxt[slot]))
            self.pos[slot] += 1
            self.remaining[slot] -= 1
            if self.remaining[slot] <= 0 or self.pos[slot] >= self.max_len - 1:
                req.completed = time.time()
                finished.append(req)
                del self.active[slot]
        return finished


class VirtualReplica(SlotPool):
    """The replay-tier replica: Replica's slot lifecycle — admit into a
    free slot, one token per step, finish after ``max_new_tokens`` —
    with no Model and no forward pass. A request therefore holds its
    slot for exactly ``max_new_tokens`` serve ticks: the latency model
    the replay layer's arrival calibration is built on."""

    def __init__(self, slots: int = 8):
        self.slots = slots
        self.active: Dict[int, Request] = {}
        self.remaining = np.zeros(slots, np.int32)

    def admit(self, req: Request) -> bool:
        slot = self.free_slot()
        if slot is None:
            return False
        self.active[slot] = req
        self.remaining[slot] = req.max_new_tokens
        req.output = []
        return True

    def step(self) -> List[Request]:
        finished = []
        for slot, req in list(self.active.items()):
            self.remaining[slot] -= 1
            req.output.append(0)         # a stand-in token per tick
            if self.remaining[slot] <= 0:
                finished.append(req)
                del self.active[slot]
        return finished


class LeastLoadedRouter:
    """LVS least-connection scheduling (§6.4) over replicas."""

    def route(self, replicas: List[SlotPool]) -> Optional[SlotPool]:
        candidates = [r for r in replicas if r.free_slot() is not None]
        if not candidates:
            return None
        return min(candidates, key=lambda r: r.n_active)
