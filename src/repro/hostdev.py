"""Force XLA host-device count before jax initializes — jax-free.

On CPU-only machines XLA exposes one device per process unless
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` is in the
environment *before* jax touches its backends. The sweep CLIs
(``benchmarks.run sweep --devices N``, ``examples/sweep_capacity.py
--devices N``) call :func:`force_host_device_count` straight after
argument parsing, ahead of any import that pulls jax, so a single plain
invocation can exercise the sharded sweep backend. This module must
stay importable without jax (stdlib only) or the call would defeat
itself by initializing the backends it is trying to configure.
"""

from __future__ import annotations

import os
import sys

FLAG = "--xla_force_host_platform_device_count"


def force_host_device_count(n: int) -> bool:
    """Request ``n`` XLA host devices via the force flag.

    Returns ``True`` when the flag is in place before jax has loaded
    (whether set here or already present — a pre-existing flag, e.g.
    exported by CI, wins and is left untouched). Returns ``False`` when
    jax is already initialized, in which case the flag would be ignored;
    callers then get the authoritative error from
    ``repro.compat.resolve_devices`` once the device count falls short.
    """
    if FLAG in os.environ.get("XLA_FLAGS", ""):
        return True
    if "jax" in sys.modules:
        return False
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + f" {FLAG}={n}").strip()
    return True
