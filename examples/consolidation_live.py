"""LIVE PhoenixCloud on JAX: real training jobs + a serving spike.

A miniature FB-policy cloud (8 logical chips): a real smollm training job
holds 6 chips; a web-serving spike demands 5, force-preempting the job
via CHECKPOINT (the beyond-paper §5.1 adaptation); the spike recedes, the
next lease tick re-provisions, and the job resumes from its checkpoint —
no lost work.

Run:  PYTHONPATH=src python examples/consolidation_live.py
"""
import os, sys, tempfile
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.runtime_bridge import LiveCloud
from repro.launch.mesh import make_local_mesh

root = tempfile.mkdtemp(prefix="phoenixcloud_")
cloud = LiveCloud(capacity=8, mesh=make_local_mesh(), checkpoint_root=root)
cloud.submit_training(jid=1, arch="smollm_135m", chips=6, steps=20)
print("job 1 scheduled on 6/8 chips; training...")
cloud.run_quantum(steps=6)
p = cloud._live[1].payload
print(f"  progressed to step {p.step}/20")

print("WS spike: demand=5 chips -> checkpoint-preempt the job")
cloud.preempt_for_ws(5)
print(f"  job running: {1 in cloud.pbj.running}; "
      f"WS holds {cloud.service.cluster.allocated('WS')} chips; "
      f"checkpoint at step {p.step}")

print("spike recedes; lease tick re-provisions idle chips")
cloud.set_ws_demand(1)
cloud.lease_tick()
print(f"  job running again: {1 in cloud.pbj.running}")
while 1 in cloud._live:
    cloud.run_quantum(steps=6)
print(f"job 1 completed at step {p.step}/20 — "
      f"preemption cost zero lost steps (kill-restart would have lost "
      f"{6} steps).")

# Every decision above went through the same event pump + ledger the
# simulator uses — a live run is diffable against a simulated one.
print("\ndecision ledger (t, kind, arg, started/killed, pbj+ws nodes):")
for e in cloud.ledger.entries:
    print(f"  t={e.t:6.0f} {e.kind:7s} arg={e.arg:4.0f} "
          f"+{e.started}/-{e.killed} pbj={e.pbj_nodes} ws={e.ws_nodes}")
