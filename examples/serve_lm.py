"""Serving example: batched requests against the autoscaled WS TRE.

The §6.4 instance-adjustment policy (80% slot-utilization threshold)
scales replicas up under a request burst and back down as it drains —
the live version of the paper's World Cup experiment.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
from repro.configs.base import get_config, reduced_config
from repro.launch.mesh import make_local_mesh
from repro.serving.autoscaler import AutoscaledService
from repro.serving.engine import Request

cfg = reduced_config(get_config("smollm_135m"))
svc = AutoscaledService(cfg, make_local_mesh(), slots_per_replica=4,
                        max_len=64)
rng = np.random.default_rng(0)
print("tick  queue  active  replicas  util")
trace = []
for tick in range(120):
    if tick < 30:                      # request burst
        for _ in range(rng.poisson(1.5)):
            svc.submit(Request(rid=tick * 100 + _, max_new_tokens=12,
                               prompt=rng.integers(0, cfg.vocab, 8)
                               .astype(np.int32)))
    svc.tick(now=float(tick))
    trace.append(len(svc.replicas))
    if tick % 10 == 0:
        active = sum(r.n_active for r in svc.replicas)
        print(f"{tick:4d} {len(svc.queue):6d} {active:7d} "
              f"{len(svc.replicas):9d} {svc.utilization:5.2f}")
    if tick > 60 and not svc.queue and \
            all(r.n_active == 0 for r in svc.replicas) and \
            len(svc.replicas) <= 2:
        break
lat = [r.completed - r.submitted for r in svc.completed]
print(f"\ncompleted={len(svc.completed)} max_replicas={max(trace)} "
      f"final_replicas={trace[-1]}")
print("scale-up under load and scale-down after drain = paper Fig 8/9 "
      "behaviour, live.")
