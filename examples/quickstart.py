"""Quickstart: the PhoenixCloud pipeline in 60 lines.

1. Express two runtime-environment requirements (paper Fig. 3).
2. Let the CSF create + pair the coordinated TREs.
3. Consolidate a batch-job trace and a web-service trace on one site
   under the FB policy; compare against two dedicated clusters.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.lifecycle import LifecycleManagementService
from repro.core.pbj_manager import PBJManager
from repro.core.provision import FBProvisionService
from repro.core.spec import (CoordinationModel, Granularity, Relationship,
                             ResourceBounds, RuntimeEnvironmentSpec,
                             SetupPolicy, WorkloadType)
from repro.core.ws_manager import WSManager
from repro.sim import traces
from repro.sim.engine import build_dcs, clone_jobs, run_sim

# 1. Runtime-environment specifications.
pbj_spec = RuntimeEnvironmentSpec(
    name="dept_batch", relationship=Relationship.AFFILIATED,
    workload=WorkloadType.PARALLEL_BATCH_JOBS,
    granularity=Granularity.CHIP_SLICE, coordination=CoordinationModel.FB,
    bounds=ResourceBounds(153, 153), setup_policy=SetupPolicy.RELOAD,
    arch="smollm_135m")
ws_spec = RuntimeEnvironmentSpec(
    name="dept_serving", relationship=Relationship.AFFILIATED,
    workload=WorkloadType.WEB_SERVICE,
    granularity=Granularity.CHIP_SLICE, coordination=CoordinationModel.FB,
    bounds=ResourceBounds(0, 0), arch="smollm_135m")
print("PBJ spec XML:\n " + pbj_spec.to_xml()[:120] + "...\n")

# 2. CSF lifecycle: create, deploy, pair, activate.
csf = LifecycleManagementService()
csf.create(pbj_spec)
csf.create(ws_spec)
print(f"coordinated pair: {csf.tre('dept_batch').partner!r} <-> "
      f"{csf.tre('dept_serving').partner!r}\n")
pbj, ws = PBJManager(), WSManager()
csf.activate("dept_batch", pbj)
csf.activate("dept_serving", ws)

# 3. Consolidation vs dedicated clusters.
T = traces.TWO_WEEKS
jobs = traces.nasa_ipsc(seed=0)
ws_trace = traces.worldcup98(seed=0, peak_vms=128)
fb = run_sim(FBProvisionService(153, pbj, ws), clone_jobs(jobs), ws_trace,
             T, name="PhoenixCloud-FB(153)")
dcs = run_sim(build_dcs(128, 128), clone_jobs(jobs), ws_trace, T,
              name="DCS(256)")
for r in (dcs, fb):
    print(f"{r.system:22s} jobs={r.completed_jobs:5d} "
          f"turnaround={r.avg_turnaround:7.0f}s peak={r.peak_nodes:4d} "
          f"node_hours={r.node_hours:9.0f}")
print(f"\n=> same throughput with a {1-153/256:.0%} smaller site "
      f"(the paper's §6.5 claim).")
