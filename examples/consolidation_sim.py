"""The paper's full evaluation (§6) end-to-end: all four systems on both
heterogeneous workload pairs, printing Tables 1/2/5/6-shaped output.

Run:  PYTHONPATH=src python examples/consolidation_sim.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.pbj_manager import PBJPolicyParams
from repro.sim import traces
from repro.sim.engine import (build_dcs, build_ec2_rightscale, build_fb,
                              build_flb_nub, clone_jobs, run_sim)

T = traces.TWO_WEEKS
HDR = (f"{'system':26s} {'jobs':>5s} {'exec(s)':>8s} {'turn(s)':>8s} "
       f"{'peak':>6s} {'node-h':>9s} {'adjusts':>8s} {'kills':>6s}")

for name, mk, prc0, B in (("NASA iPSC + WorldCup", traces.nasa_ipsc, 128, 25),
                          ("SDSC BLUE + WorldCup", traces.sdsc_blue, 144, 27)):
    jobs = mk(seed=0)
    ws = traces.worldcup98(seed=0, peak_vms=128)
    print(f"\n=== {name}  (PRC_PBJ={prc0}, PRC_WS=128) ===")
    print(HDR)
    systems = [
        (build_dcs(prc0, 128), f"DCS({prc0+128})"),
        (build_fb(prc0), f"PhoenixCloud-FB({prc0})"),
        (build_fb(int((prc0+128)*0.6)), f"PhoenixCloud-FB({int((prc0+128)*0.6)})"),
        (build_fb(int((prc0+128)*0.6),
                  params=PBJPolicyParams(checkpoint_preempt=True)),
         "  + checkpoint-preempt"),
        (build_flb_nub(B-12, 12), f"PhoenixCloud-FLBNUB(B{B})"),
        (build_ec2_rightscale(), "EC2+RightScale"),
    ]
    for sys_, label in systems:
        r = run_sim(sys_, clone_jobs(jobs), ws, T, name=label)
        print(f"{label:26s} {r.completed_jobs:5d} {r.avg_execution:8.0f} "
              f"{r.avg_turnaround:8.0f} {r.peak_nodes:6d} {r.node_hours:9.0f} "
              f"{r.adjust_events:8d} {r.kills:6d}")
print("""
Paper claims to check against the rows above (§6.7):
 * FB at 60% of the DCS size: same completed jobs, bounded turnaround hit.
 * FLB-NUB: lower total AND peak consumption than EC2+RightScale,
   at a moderate turnaround premium (jobs queue until U fires).
 * EC2+RightScale: zero queueing (exec == turnaround) but 1.5-2x the peak.
 * checkpoint-preempt (beyond paper): same consolidation, less lost work.""")
