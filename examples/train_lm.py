"""End-to-end training driver: train smollm-135m (the ~100M-class arch)
on the synthetic bigram-structured LM stream with checkpointing.

CPU container: defaults to the reduced config + 120 steps so the loss
curve is visible in ~a minute. The full 135M config and a few hundred
steps is the same command with --full --steps 300 (TPU-scale).

Run:  PYTHONPATH=src python examples/train_lm.py [--full] [--steps N]
"""
import argparse, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import get_config, reduced_config
from repro.launch.mesh import make_local_mesh
from repro.train.trainer import TrainJob, TrainJobConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="full 135M config (slow on CPU)")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--arch", default="smollm_135m")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced_config(cfg)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"steps={args.steps}")
    job = TrainJob(cfg, TrainJobConfig(
        arch=args.arch, steps=args.steps, batch=8, seq_len=64, lr=3e-3,
        checkpoint_dir=args.ckpt, checkpoint_every=50), make_local_mesh())
    result = job.run()
    h = job.history
    print(f"loss: start {sum(h[:10])/10:.3f} -> end {sum(h[-10:])/10:.3f} "
          f"({result['wall_seconds']:.0f}s, ckpt at {args.ckpt})")
    assert sum(h[-10:]) < sum(h[:10]), "loss must decrease"
    print("resume check:", end=" ")
    job2 = TrainJob(cfg, TrainJobConfig(
        arch=args.arch, steps=args.steps, batch=8, seq_len=64,
        checkpoint_dir=args.ckpt), make_local_mesh())
    job2.initialize()
    print(f"restored at step {job2.step} OK")


if __name__ == "__main__":
    main()
