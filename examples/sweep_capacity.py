"""Paper-scale parameter study in one call (Figs. 13/14/18, §6.5–§6.6).

Sweeps the whole comparison grid — private-cloud capacity C for the FB
policy (Fig. 13, the ~40 % configuration-size headline), coordinated
pool size B for FLB-NUB (Fig. 14), and the lease unit L for both
PhoenixCloud and EC2+RightScale (Fig. 18) — through
``repro.sim.sweep.run_sweep``. DCS and EC2 points are evaluated on the
exact vectorized jnp fast path in every mode; ``--mode`` picks how the
stateful PhoenixCloud policies run:

  auto   (default) FB / FLB-NUB on the event-round engine — same as
         rounds, with an event-engine fallback for points the fast
         path rejects
  rounds FB / FLB-NUB batched through the jump-to-next-event engine
         (completed jobs exact, node-hours/peak within 5 %)
  scan   FB / FLB-NUB batched through one fixed-dt jitted lax.scan
         (approximate: jobs ±2 %, node-hours ±15 %, trends exact)
  event  everything on the event engine (the cross-validation reference)

``--devices N`` shards the batched paths' point lanes across N host
devices (forcing N XLA CPU devices when needed) — the multi-core
backend of the sweep engine.

``--queries`` additionally runs the capacity query layer
(``repro.sim.capacity``) on top of the same grid: the §6.5.3 headline
re-derived as a batched min-C bisection against the DCS throughput
(instead of eyeballing the swept rows), a Pareto frontier over the
evaluated grid, and the multi-cloud cost lens answering "cheapest
provider for this frontier".

Run:  PYTHONPATH=src python examples/sweep_capacity.py [--mode rounds]
      [--devices 2] [--queries]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ap = argparse.ArgumentParser()
ap.add_argument("--mode", default="auto",
                help="execution path for the FB / FLB-NUB points")
ap.add_argument("--devices", type=int, default=0,
                help="shard the batched-path lanes across N host devices "
                "(requires a batched mode: auto, scan or rounds)")
ap.add_argument("--queries", action="store_true",
                help="also run the capacity query layer: min-C "
                "bisection, Pareto frontier and the cost lens")
args = ap.parse_args()

if args.devices >= 2:
    if args.mode not in ("auto", "scan", "rounds"):
        # Only the batched paths consume the devices option — anything
        # else would silently run unsharded.
        ap.error("--devices requires a batched mode (auto, scan, rounds)")
    from repro.hostdev import force_host_device_count
    force_host_device_count(args.devices)

import numpy as np

from repro.core.profiles import job_demand_profile
from repro.sim import traces
from repro.sim.sweep import MODES, paper_grid, run_sweep

if args.mode not in MODES:
    ap.error(f"--mode must be one of {MODES}")

T = traces.TWO_WEEKS
jobs = traces.nasa_ipsc(seed=0)
ws = traces.worldcup98(seed=0, peak_vms=128)

# The precomputed per-lease-window PBJ demand profile the sweep engine
# batches over — also a quick feasibility read on any capacity C.
profile = job_demand_profile(np.array([j.submit for j in jobs]),
                             np.array([j.size for j in jobs]), T, 3600.0)
print(f"PBJ demand profile: peak {profile.max():.0f} nodes/h, "
      f"mean {profile.mean():.1f} nodes/h over {len(profile)} lease windows\n")

PRC_PBJ, PRC_WS = 128, 128
rows = run_sweep(paper_grid(prc_pbj=PRC_PBJ, prc_ws=PRC_WS), jobs, ws, T,
                 mode=args.mode, devices=args.devices or None)

print(f"{'point':22s} {'engine':>10s} {'jobs':>5s} {'peak':>6s} "
      f"{'node-h':>9s} {'adjusts':>8s}")
for r in rows:
    jobs_s = str(r.get("completed_jobs", "-"))
    print(f"{r['system']:22s} {r['engine']:>10s} {jobs_s:>5s} "
          f"{r['peak_nodes']:6d} {r['node_hours']:9.0f} "
          f"{r['adjust_events']:8d}")

dcs_size = PRC_PBJ + PRC_WS
dcs = next(r for r in rows if r["system_kind"] == "dcs")
fb60 = next(r for r in rows
            if r["system"] == f"FB(C={int(round(dcs_size * 0.6))})")
fb100 = next(r for r in rows if r["system"] == f"FB(C={dcs_size})")
print(f"\n=> FB at 60% capacity completes {fb60['completed_jobs']} jobs — the "
      f"same throughput as the full-size FB(C={dcs_size}) "
      f"({fb100['completed_jobs']}) on a site 40% smaller than the "
      f"{dcs['peak_nodes']}-node DCS (Fig. 13).")

if args.queries:
    import warnings

    from repro.sim.capacity import (CapacitySLO, CostModel, SweepPoint,
                                    min_capacity, pareto_front)

    # The §6.5.3 claim as a QUERY: minimum FB capacity matching the DCS
    # throughput, found by batched bisection instead of grid eyeballing.
    dcs_jobs = next(r for r in run_sweep(
        [SweepPoint("dcs", prc_pbj=PRC_PBJ, prc_ws=PRC_WS)], jobs, ws, T,
        mode="event"))["completed_jobs"]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        rep = min_capacity(SweepPoint("fb"), (jobs, ws),
                           CapacitySLO(min_completed=dcs_jobs),
                           lo=1, hi=dcs_size, duration=T, mode="rounds",
                           devices=args.devices or None)
    r = rep.results[0]
    print(f"\n=> min_capacity: FB needs C={r.capacity} to match DCS's "
          f"{dcs_jobs} completed jobs — a "
          f"{1 - r.capacity / dcs_size:.1%} smaller configuration, "
          f"found in {rep.rows_evaluated} sweep rows vs "
          f"{rep.brute_force_rows} for a brute-force scan.")

    # The non-dominated policies of the grid just swept (minus the
    # vectorized DCS row, which carries no completed_jobs), and what
    # the cheapest provider would charge for them.
    front = pareto_front(rows=[r for r in rows if "completed_jobs" in r])
    cm = CostModel()
    est = cm.cheapest(front.frontier_rows())
    print(f"=> Pareto frontier (node-hours, peak, throughput): "
          f"{[front.points[i].row['system'] for i in front.frontier]}")
    print(f"=> cheapest provider for the frontier mix: {est.provider} "
          f"(${est.total_usd:,.0f} = {est.node_hours:,.0f} node-h + "
          f"{est.requests} API requests)")
