"""Per-arch smoke tests (deliverable f): reduced config of each family,
one forward/train step on CPU, asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, reduced_config
from repro.launch.mesh import make_local_mesh
from repro.models.transformer import Model
from repro.train.optimizer import get_optimizer
from repro.train.trainer import make_train_step

B, S = 2, 32


def _batch(cfg, key):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.family in ("vlm", "audio"):
        batch["frontend"] = 0.02 * jax.random.normal(
            key, (B, cfg.frontend_len, cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def mesh():
    return make_local_mesh()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch, mesh):
    cfg = reduced_config(get_config(arch))
    model = Model(cfg, mesh, compute_dtype=jnp.float32)
    params = model.init(0)
    batch = _batch(cfg, jax.random.PRNGKey(1))
    loss = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), f"{arch}: NaN loss"
    assert float(loss) < 2 * np.log(cfg.vocab) + 1
    # One real optimizer step.
    opt = get_optimizer(cfg.optimizer, lr=1e-3)
    step = jax.jit(make_train_step(model, opt, accum_steps=2))
    state = opt.init(params)
    p2, s2, metrics = step(params, state, batch, jnp.float32(1e-3))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # Parameters actually moved.
    delta = sum(float(jnp.sum(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0, f"{arch}: optimizer step was a no-op"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch, mesh):
    cfg = reduced_config(get_config(arch))
    model = Model(cfg, mesh, compute_dtype=jnp.float32)
    params = model.init(0)
    batch = _batch(cfg, jax.random.PRNGKey(2))
    batch.pop("labels")
    cache = model.init_cache(B, S, dtype=jnp.float32)
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape == (B, 1, cfg.vocab)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits2, cache = jax.jit(model.decode)(params, tok, cache,
                                           jnp.int32(S - 1))
    assert logits2.shape == (B, 1, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))


def test_param_counts_match_published():
    published = {
        "gemma2_2b": 2.6e9, "smollm_135m": 1.35e8, "qwen2_5_14b": 14e9,
        "qwen1_5_0_5b": 4.6e8, "llama32_vision_90b": 88e9,
        "jamba15_large_398b": 398e9, "whisper_base": 7.4e7,
        "granite_moe_3b": 3.3e9, "grok1_314b": 314e9, "mamba2_130m": 1.3e8,
    }
    for arch, target in published.items():
        got = get_config(arch).param_count()
        assert abs(got - target) / target < 0.15, \
            f"{arch}: {got/1e9:.2f}B vs published {target/1e9:.2f}B"


def test_shape_grid_and_skips():
    """All 40 cells exist; skips follow the assignment rules."""
    total = 0
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        shapes = cfg.shapes()
        assert set(shapes) == {"train_4k", "prefill_32k", "decode_32k",
                               "long_500k"}
        total += len(shapes)
        long = shapes["long_500k"]
        if cfg.family in ("ssm", "hybrid"):
            assert long.skip is None, f"{arch} must run long_500k"
        else:
            assert long.skip is not None, f"{arch} must skip long_500k"
    assert total == 40
