"""Unit tests for the PhoenixCloud core: ledger, managers, policies, spec."""

import pytest

pytestmark = pytest.mark.tier1

from repro.core.cluster import Cluster, LedgerError, ceil_to_lease
from repro.core.jobs import Job, JobQueue, RunningSet
from repro.core.pbj_manager import PBJManager, PBJPolicyParams
from repro.core.provision import FBProvisionService, FLBNUBProvisionService
from repro.core.spec import (CoordinationModel, Granularity, Relationship,
                             ResourceBounds, RuntimeEnvironmentSpec,
                             SetupPolicy, WorkloadType, paper_fig3_example)
from repro.core.ws_manager import InstanceAdjustmentPolicy, WSManager
from repro.core.lifecycle import LifecycleManagementService, TREState


# --------------------------------------------------------------- spec / xml

def test_spec_xml_roundtrip():
    spec = paper_fig3_example()
    spec.validate()
    xml = spec.to_xml()
    back = RuntimeEnvironmentSpec.from_xml(xml)
    assert back == spec
    assert 'resource_coordination_mode="FLB_NUB"' in xml
    assert 'upper_bound_size="null"' in xml


def test_spec_validation():
    with pytest.raises(ValueError):
        ResourceBounds(lower=10, upper=5)
    fb_bad = RuntimeEnvironmentSpec(
        name="x", relationship=Relationship.AFFILIATED,
        workload=WorkloadType.WEB_SERVICE, granularity=Granularity.NODE,
        coordination=CoordinationModel.FB,
        bounds=ResourceBounds(10, 20))
    with pytest.raises(ValueError):
        fb_bad.validate()
    flb_bad = RuntimeEnvironmentSpec(
        name="x", relationship=Relationship.BUSINESS,
        workload=WorkloadType.WEB_SERVICE, granularity=Granularity.NODE,
        coordination=CoordinationModel.FLB_NUB,
        bounds=ResourceBounds(10, 20))
    with pytest.raises(ValueError):
        flb_bad.validate()


def test_lifecycle_partner_matching():
    svc = LifecycleManagementService()
    mk = lambda name, wl: RuntimeEnvironmentSpec(
        name=name, relationship=Relationship.BUSINESS, workload=wl,
        granularity=Granularity.NODE, coordination=CoordinationModel.FLB_NUB,
        bounds=ResourceBounds(10, None))
    svc.create(mk("pbj1", WorkloadType.PARALLEL_BATCH_JOBS))
    # Same workload type → NOT a coordination partner (heterogeneous only).
    svc.create(mk("pbj2", WorkloadType.PARALLEL_BATCH_JOBS))
    assert svc.tre("pbj2").partner is None
    tre = svc.create(mk("ws1", WorkloadType.WEB_SERVICE))
    assert tre.partner == "pbj1"
    assert svc.tre("pbj1").partner == "ws1"
    svc.activate("ws1", WSManager())
    assert svc.tre("ws1").state is TREState.RUNNING


# ------------------------------------------------------------------- ledger

def test_ledger_conservation_and_accounting():
    c = Cluster(100)
    c.register("A")
    c.register("B")
    c.allocate(0.0, "A", 60)
    with pytest.raises(LedgerError):
        c.allocate(1.0, "B", 50)     # over capacity
    c.allocate(3600.0, "B", 40)
    assert c.idle == 0
    c.release(7200.0, "A", 10)
    c.finalize(10800.0)
    # A: 60 for 3h minus 10 for the last hour = 170 node-h; B: 40 for 2h.
    assert c.node_hours_of("A") == pytest.approx(170.0)
    assert c.node_hours_of("B") == pytest.approx(80.0)
    assert c.peak == 100
    assert c.adjust_events() == 3   # failed allocation doesn't count


def test_ceil_to_lease():
    assert ceil_to_lease(0.0, 3600) == 0.0
    assert ceil_to_lease(1.0, 3600) == 3600.0
    assert ceil_to_lease(3600.0, 3600) == 3600.0
    assert ceil_to_lease(3600.1, 3600) == 7200.0


# ------------------------------------------------------------ PBJ scheduler

def test_first_fit_scans_in_arrival_order():
    q = JobQueue()
    q.push(Job(1, 0.0, size=8, runtime=10))
    q.push(Job(2, 1.0, size=4, runtime=10))
    q.push(Job(3, 2.0, size=2, runtime=10))
    started = q.first_fit(6)
    assert [j.jid for j in started] == [2, 3]   # 8 doesn't fit; skip it
    assert len(q) == 1


def test_kill_order_smallest_then_latest_start():
    r = RunningSet()
    a = Job(1, 0.0, size=4, runtime=10); a.start = 0.0
    b = Job(2, 0.0, size=2, runtime=10); b.start = 5.0
    c = Job(3, 0.0, size=2, runtime=10); c.start = 9.0
    for j in (a, b, c):
        r.add(j, 100.0)
    order = [j.jid for j in r.kill_order()]
    assert order == [3, 2, 1]   # size 2 first, latest start first


def test_force_release_kills_and_requeues():
    m = PBJManager()
    m.grant(0.0, 10)
    m.submit(0.0, Job(1, 0.0, size=6, runtime=100))
    m.submit(0.0, Job(2, 0.0, size=4, runtime=100))
    assert m.free == 0
    released, _ = m.force_release(1.0, 5)
    assert released == 5
    assert m.owned == 5
    # Both jobs were killed (smallest first, then job 1 to cover need=5);
    # job 2 (size 4) restarts immediately in the leftover 5 free nodes,
    # job 1 (size 6) no longer fits and stays queued.
    assert 2 in m.running
    assert any(j.jid == 1 for j in m.queue)
    assert m.kill_count == 2
    assert m.free == 1


def test_flb_nub_adjust_rules():
    p = PBJPolicyParams(request_threshold=1.2, release_threshold=0.2,
                        elastic_factor=0.5)
    m = PBJManager(params=p)
    m.grant(0.0, 10)
    # Empty queue, all idle → release G×idle = 5.
    action, n = m.adjust(0.0)
    assert (action, n) == ("release", 5)
    # Large queued demand → DR1 = demand - owned.
    m.queue.push(Job(1, 0.0, size=30, runtime=10))
    action, n = m.adjust(1.0)
    assert (action, n) == ("request", 20)
    # Biggest-job rule (DR2): demand ratio below U but biggest > owned.
    m2 = PBJManager(params=p)
    m2.grant(0.0, 100)
    m2.queue.push(Job(2, 0.0, size=110, runtime=10))
    # ratio = 110/100 = 1.1 < 1.2 but biggest (110) > owned (100)
    action, n = m2.adjust(0.0)
    assert action == "request"
    assert n == 110 - m2.free


# ----------------------------------------------------------------- services

def test_fb_ws_priority_with_kills():
    pbj, ws = PBJManager(), WSManager()
    svc = FBProvisionService(100, pbj, ws, lease_seconds=3600)
    svc.startup(0.0, ws_initial=20)
    assert pbj.owned == 80
    pbj.submit(0.0, Job(1, 0.0, size=50, runtime=1e6))
    pbj.submit(0.0, Job(2, 0.0, size=30, runtime=1e6))
    # WS spike to 60: idle 0, PBJ frees 40 by killing smallest-first:
    # job2 (30) then job1 (50). Job2 restarts in the leftover free nodes;
    # job1 (size 50 > 40 owned) stays queued.
    svc.on_ws_demand(1.0, 60)
    assert svc.cluster.allocated("WS") == 60
    assert pbj.owned == 40
    assert 2 in pbj.running
    assert any(j.jid == 1 for j in pbj.queue)
    # WS shrinks; next tick hands idle back to PBJ.
    svc.on_ws_demand(2.0, 10)
    svc.on_lease_tick(3600.0)
    assert pbj.owned == 90
    assert svc.cluster.idle == 0


def test_flb_nub_pool_flow():
    pbj, ws = PBJManager(), WSManager()
    svc = FLBNUBProvisionService(13, 12, pbj, ws, lease_seconds=3600)
    svc.startup(0.0, ws_initial=5)
    assert pbj.owned == 13
    assert svc.cluster.allocated("POOL") == 25
    assert svc.cluster.allocated("WS") == 0      # within lower bound
    svc.on_ws_demand(1.0, 40)                    # beyond lb → leased
    assert svc.cluster.allocated("WS") == 40 - svc._pool_ws
    # Tick with an empty queue: RSS releases G×idle (pool nodes churn back
    # to the pool — they are still held and paid for, I3 in the property
    # tests); pool conservation always holds.
    svc.on_lease_tick(3600.0)
    assert svc.cluster.allocated("POOL") == 25
    assert pbj.owned + svc._pool_idle + svc._pool_ws >= 13


def test_instance_adjustment_policy_80pct():
    pol = InstanceAdjustmentPolicy()
    assert pol.decide(4, 0.85) == 1
    assert pol.decide(4, 0.7) == 0
    # Below 80%·(n-1)/n → remove one.
    assert pol.decide(4, 0.55) == -1
    assert pol.decide(1, 0.0) == 0   # never below min_instances
