"""Event-round engine (repro.sim.rounds) vs the discrete-event engine.

The rounds engine's contract is *tighter* than the scan's: jumping
straight to event times makes completions exact (no substep rounding),
so on any workload the completed-job count must match the event engine
exactly and — with enough first-fit passes for the queue to resolve the
way the engine's sequential scan does — the completion *times* must
match too, not just within a tolerance. These tests pin that, the §5.1
kill semantics on the designed spike scenario, the window-overflow
diagnostic (surfaced as a RuntimeWarning — the satellite of this PR),
and the pick_dt edge cases of the fixed-dt scan it complements.
"""

import random
import warnings

import numpy as np
import pytest

pytestmark = pytest.mark.tier1

from repro.core.jobs import Job
from repro.sim.engine import build_fb, build_flb_nub, clone_jobs, run_sim
from repro.sim.sweep import ScanOptions, SweepPoint, run_sweep

DAY = 24 * 3600.0


def rounds_row(point, jobs, ws, duration, **opts):
    return run_sweep([point], jobs, ws, duration, mode="rounds",
                     scan_options=ScanOptions(**opts))[0]


def random_workload(seed, n_jobs=40, ws_level=2):
    """Queue-provoking random trace: bursty arrivals, constant low WS
    demand (no demand rises, so FB never kills and the §5.1 tie-order
    caveat cannot blur the exactness assertion)."""
    rng = random.Random(seed)
    jobs = [Job(i, rng.uniform(0.0, 16 * 3600.0),
                size=2 ** rng.randrange(0, 4),
                runtime=rng.uniform(600.0, 3 * 3600.0))
            for i in range(n_jobs)]
    ws = [(0.0, ws_level)]
    return jobs, ws


# ------------------------------------------------ exact completion times

@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("system", ["fb", "flb_nub"])
def test_rounds_completion_times_match_event_exactly(seed, system):
    """The event-round property: in float64, start times are event
    times and end times are the same float sum the engine computes, so
    completed jobs, turnaround and execution agree to round-off — not
    to a discretization tolerance. (ff_passes is raised so the
    vectorized first-fit provably converges to the engine's sequential
    scan on every round.)"""
    import jax
    from jax.experimental import enable_x64

    jobs, ws = random_workload(seed)
    if system == "fb":
        point = SweepPoint("fb", capacity=12)
        ref_sys = build_fb(12)
    else:
        point = SweepPoint("flb_nub", lb_pbj=6, lb_ws=4)
        ref_sys = build_flb_nub(6, 4)
    ref = run_sim(ref_sys, clone_jobs(jobs), ws, DAY)
    with enable_x64():
        row = rounds_row(point, jobs, ws, DAY, ff_passes=8,
                         dtype=np.float64)
    assert row["window_overflow"] == 0 and row["truncated"] == 0
    assert row["completed_jobs"] == ref.completed_jobs, (seed, system)
    assert row["avg_turnaround"] == pytest.approx(ref.avg_turnaround,
                                                  rel=1e-9), (seed, system)
    assert row["avg_execution"] == pytest.approx(ref.avg_execution,
                                                 rel=1e-9), (seed, system)
    assert row["kills"] == ref.kills == 0
    assert row["peak_nodes"] == ref.peak_nodes


@pytest.mark.parametrize("seed", range(4))
def test_rounds_fidelity_contract_on_random_traces(seed):
    """At the default (float32) settings the contract is: completed
    jobs exact, node-hours and peak within 5 % of the event engine."""
    rng = random.Random(100 + seed)
    jobs = [Job(i, rng.uniform(0.0, 12 * 3600.0),
                size=2 ** rng.randrange(0, 4),
                runtime=rng.uniform(900.0, 2 * 3600.0))
            for i in range(30)]
    ws = [(k * 900.0, rng.randrange(0, 13)) for k in range(0, 96, 2)]
    for point, ref_sys in (
            (SweepPoint("fb", capacity=16), build_fb(16)),
            (SweepPoint("flb_nub", lb_pbj=13, lb_ws=12),
             build_flb_nub(13, 12))):
        row = rounds_row(point, jobs, ws, DAY, window=32)
        ref = run_sim(ref_sys, clone_jobs(jobs), ws, DAY)
        assert row["window_overflow"] == 0 and row["truncated"] == 0
        assert row["completed_jobs"] == ref.completed_jobs, (seed, point)
        assert row["node_hours"] == pytest.approx(ref.node_hours,
                                                  rel=0.05), (seed, point)
        if point.system == "fb":
            # FB peak is exact by construction (the §5.1 ratchet makes
            # each lease window's max analytic). FLB-NUB peak carries
            # the shared U/V/G *policy* approximation on adversarial
            # small traces — the scan path reports the identical value
            # — so only the paper-grid contract (<= 5 %, gated in the
            # sweep benchmark) applies to it.
            assert row["peak_nodes"] == ref.peak_nodes, (seed, point)


# ------------------------------------------------------ §5.1 kill spike

def spike_workload():
    jobs = [Job(0, 0.0, size=4, runtime=2 * 3600.0),
            Job(1, 0.0, size=4, runtime=2 * 3600.0),
            Job(2, 0.0, size=2, runtime=1200.0)]
    ws = [(0.0, 0), (1800.0, 8), (2 * 3600.0, 0)]
    return jobs, ws


def test_rounds_fb_killed_jobs_reenter_and_finish():
    """The §5.1 demand spike: both size-4 jobs die and can only finish
    by re-queueing — the rounds engine reproduces kills, restarts and
    the exact completion count, with exact node-hours (the spike's
    reclaim happens at a demand-rise stop, not a rounded substep)."""
    jobs, ws = spike_workload()
    row = rounds_row(SweepPoint("fb", capacity=10), jobs, ws, 8 * 3600.0,
                     window=16)
    ref = run_sim(build_fb(10), clone_jobs(jobs), ws, 8 * 3600.0)
    assert ref.kills == 2
    assert row["kills"] == ref.kills
    assert row["completed_jobs"] == ref.completed_jobs == 3
    assert row["peak_nodes"] == ref.peak_nodes == 10
    assert row["node_hours"] == pytest.approx(ref.node_hours, rel=1e-5)


def test_rounds_killed_job_restarts_at_the_freeing_completion():
    """Regression: a §5.1 kill re-queues its job, and the very next
    completion that frees enough capacity must restart it AT that
    completion time (the event engine's behavior) — the queue flag and
    the usage carried between rounds must reflect the post-kill state,
    or the restart slips to the next tick."""
    jobs = [Job(0, 0.0, size=4, runtime=1200.0),       # killed at 500
            Job(1, 0.0, size=6, runtime=1000.0)]       # frees 6 at 1000
    ws = [(0.0, 0), (500.0, 4)]
    T = 3000.0      # next lease tick (3600) is beyond the horizon
    row = rounds_row(SweepPoint("fb", capacity=10), jobs, ws, T, window=8)
    ref = run_sim(build_fb(10), clone_jobs(jobs), ws, T)
    assert ref.kills == 1
    assert ref.completed_jobs == 2   # restart at 1000 + 1200 s < 3000 s
    assert row["kills"] == 1
    # Job 0 completes (at exactly 2200 s) only if it restarted at the
    # t=1000 completion; a restart deferred to the next stop would
    # leave it running at the horizon.
    assert row["completed_jobs"] == 2
    assert row["avg_turnaround"] == pytest.approx(ref.avg_turnaround,
                                                  rel=1e-5)
    assert row["node_hours"] == pytest.approx(ref.node_hours, rel=1e-5)
    assert row["peak_nodes"] == ref.peak_nodes


def test_rounds_fb_partial_kill():
    jobs, ws = spike_workload()
    ws = [(0.0, 0), (1800.0, 5), (2 * 3600.0, 0)]
    row = rounds_row(SweepPoint("fb", capacity=10), jobs, ws, 8 * 3600.0,
                     window=16)
    ref = run_sim(build_fb(10), clone_jobs(jobs), ws, 8 * 3600.0)
    assert ref.kills == 1
    assert row["kills"] == 1
    assert row["completed_jobs"] == ref.completed_jobs == 3


# ------------------------------------------------- diagnostics surface

def test_rounds_window_overflow_warns():
    """A window too small for the backlog must not fail silently: the
    rows carry ``window_overflow`` and run_sweep emits a
    RuntimeWarning (this PR's diagnostic satellite)."""
    rng = random.Random(7)
    jobs = [Job(i, float(i), size=8, runtime=9 * 3600.0)
            for i in range(24)]          # 24 jobs, site fits 1 at a time
    ws = [(0.0, 0)]
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        row = rounds_row(SweepPoint("fb", capacity=8), jobs, ws, DAY,
                         window=8)
    assert row["window_overflow"] > 0
    messages = [str(w.message) for w in caught
                if issubclass(w.category, RuntimeWarning)]
    assert any("backlog outgrew" in m for m in messages), messages


def test_scan_window_overflow_warns_too():
    """Same surface for the fixed-dt scan path."""
    jobs = [Job(i, float(i), size=8, runtime=9 * 3600.0)
            for i in range(24)]
    ws = [(0.0, 0)]
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        row = run_sweep([SweepPoint("fb", capacity=8)], jobs, ws, DAY,
                        mode="scan",
                        scan_options=ScanOptions(window=8))[0]
    assert row["window_overflow"] > 0
    assert any("backlog outgrew" in str(w.message) for w in caught)


def test_rounds_rejects_checkpoint_preempt_and_auto_falls_back():
    from repro.core.pbj_manager import PBJPolicyParams

    jobs, ws = spike_workload()
    ckpt = SweepPoint("fb", capacity=8,
                      params=PBJPolicyParams(checkpoint_preempt=True))
    with pytest.raises(ValueError, match="checkpoint_preempt"):
        run_sweep([ckpt], jobs, ws, 7200.0, mode="rounds")
    # auto: the rejected point quietly takes the event engine, the rest
    # still batch through rounds.
    rows = run_sweep([ckpt, SweepPoint("fb", capacity=8)], jobs, ws,
                     7200.0, mode="auto")
    assert rows[0]["engine"] == "event"
    assert rows[1]["engine"] == "rounds"


def test_rounds_batches_trace_axis():
    """run_sweep_workloads in rounds mode: per-workload rows reflect
    their own trace (the workload axis runs as separate invocations of
    one compiled program)."""
    from repro.sim.sweep import run_sweep_workloads

    jobs1, ws1 = random_workload(11)
    jobs2, ws2 = random_workload(12, n_jobs=25, ws_level=5)
    pts = [SweepPoint("fb", capacity=12),
           SweepPoint("flb_nub", lb_pbj=6, lb_ws=4)]
    rows = run_sweep_workloads(pts, [(jobs1, ws1), (jobs2, ws2)], DAY,
                               mode="rounds")
    assert len(rows) == 2 and all(len(r) == 2 for r in rows)
    for w, (jobs, ws) in enumerate([(jobs1, ws1), (jobs2, ws2)]):
        for i, (pt, ref_sys) in enumerate((
                (pts[0], build_fb(12)), (pts[1], build_flb_nub(6, 4)))):
            ref = run_sim(ref_sys if w + i else build_fb(12),
                          clone_jobs(jobs), ws, DAY)
            assert rows[w][i]["engine"] == "rounds"
            if i == 0 and w == 0:
                assert rows[w][i]["completed_jobs"] == ref.completed_jobs
    # The traces differ (40 vs 25 jobs), so per-workload job metrics
    # must too. (FB node-hours would NOT discriminate here: with flat
    # WS demand the §5.1 allocation is exactly C around the clock for
    # any job trace.)
    assert rows[0][0]["completed_jobs"] != rows[1][0]["completed_jobs"]
    assert rows[0][0]["avg_turnaround"] != rows[1][0]["avg_turnaround"]


# ------------------------------------------------------ pick_dt edges

def test_pick_dt_edge_cases():
    """The satellite's pick_dt edge cases: empty WS change-point lists,
    change spacing below FLB_MIN_DT, and single-lease grids."""
    from repro.sim import scan as scanlib

    # Empty ws_traces containers: the spacing cap must not fire.
    assert scanlib.pick_dt("flb_nub", [3600.0], None) == scanlib.FLB_DT
    assert scanlib.pick_dt("flb_nub", [3600.0], []) == scanlib.FLB_DT
    assert scanlib.pick_dt("flb_nub", [3600.0], [[]]) == scanlib.FLB_DT
    assert scanlib.pick_dt("flb_nub", [3600.0],
                           [[(0.0, 3)]]) == scanlib.FLB_DT
    # Spacing below the floor clamps at FLB_MIN_DT, never explodes the
    # substep count.
    ws_fine = [(float(k), k % 3) for k in range(100)]
    assert scanlib.pick_dt("flb_nub", [3600.0],
                           [ws_fine]) == scanlib.FLB_MIN_DT
    # Single-lease grids: the lease caps the substep for both policies.
    assert scanlib.pick_dt("fb", [450.0]) == 450.0
    assert scanlib.pick_dt("flb_nub", [120.0]) == 120.0
    assert scanlib.pick_dt("fb", [3600.0]) == scanlib.FB_DT
    # The FB grid ignores WS spacing (its reclaim is demand-driven, not
    # sampled): even a 1-second trace keeps the coarse substep.
    assert scanlib.pick_dt("fb", [3600.0], [ws_fine]) == scanlib.FB_DT


def test_round_budget_scales_with_inputs():
    from repro.sim.rounds import round_budget

    base = round_budget(100, 50, DAY, 3600.0)
    assert base > 100 + 50 + 24
    assert round_budget(200, 50, DAY, 3600.0) > base
    assert round_budget(100, 50, DAY, 900.0) > base   # more ticks


def test_compat_jit_donation_gate():
    """The donation shim: donate_argnums reaches jax.jit only on
    backends with buffer donation; on others it is dropped so no
    aliasing warning can fire (asserted for real in the bench run)."""
    import jax.numpy as jnp
    from repro import compat

    assert compat.supports_donation("tpu")
    assert compat.supports_donation("gpu")
    assert not compat.supports_donation("cpu")

    calls = []
    f = compat.jit(lambda x: x + 1, donate_argnums=(0,), platform="cpu")
    out = f(jnp.zeros(3))
    assert out.shape == (3,)
    # On a donating platform the kwarg passes through - jax validates
    # it, so a bad argnum raises.
    with pytest.raises(Exception):
        g = compat.jit(lambda x: x + 1, donate_argnums=(5,),
                       platform="tpu")
        g(jnp.zeros(3))
