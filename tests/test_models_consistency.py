"""Decode-vs-full-forward consistency — validates KV caches, rope offsets,
sliding windows, SSM state carry, and cross-attention caches."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced_config
from repro.launch.mesh import make_local_mesh
from repro.models import mlp as mlp_mod
from repro.models.transformer import Model

S = 24


def _last_logit_paths(arch, monkeypatch=None, cap_factor=None):
    cfg = reduced_config(get_config(arch))
    mesh = make_local_mesh()
    model = Model(cfg, mesh, compute_dtype=jnp.float32)
    params = model.init(0)
    key = jax.random.PRNGKey(3)
    toks = jax.random.randint(key, (2, S), 0, cfg.vocab)
    batch = {"tokens": toks[:, :S - 1]}
    if cfg.family in ("vlm", "audio"):
        batch["frontend"] = 0.02 * jax.random.normal(
            key, (2, cfg.frontend_len, cfg.d_model))
    cache = model.init_cache(2, S, dtype=jnp.float32)
    _, cache = jax.jit(model.prefill)(params, batch, cache)
    lgA, _ = jax.jit(model.decode)(params, toks[:, S - 1:S], cache,
                                   jnp.int32(S - 1))
    full = dict(batch)
    full["tokens"] = toks
    src = model._frontend(params, full)
    x = model._embed(params, toks)
    x, _ = model._run_blocks(params, x, "full", src=src)
    lgB = model._logits(params, x)[:, -1:, :]
    return np.asarray(lgA), np.asarray(lgB)


@pytest.mark.parametrize("arch", [
    "smollm_135m", "gemma2_2b", "qwen1_5_0_5b", "qwen2_5_14b",
    "mamba2_130m", "whisper_base", "llama32_vision_90b",
])
def test_decode_matches_full(arch):
    a, b = _last_logit_paths(arch)
    np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-4)


@pytest.mark.parametrize("arch", ["granite_moe_3b", "grok1_314b",
                                  "jamba15_large_398b"])
def test_decode_matches_full_moe(arch, monkeypatch):
    """MoE routing is capacity-based, so token-set-dependent drops make
    different-shaped calls diverge; with generous capacity the paths must
    agree exactly (validates that drops are the ONLY divergence source)."""
    monkeypatch.setattr(mlp_mod, "CAPACITY_FACTOR", 64.0)
    a, b = _last_logit_paths(arch)
    np.testing.assert_allclose(a, b, atol=5e-5, rtol=5e-4)


def test_unrolled_matches_scan():
    """The analytic-cost path (unroll=True) computes the same function."""
    cfg = reduced_config(get_config("gemma2_2b"))
    mesh = make_local_mesh()
    key = jax.random.PRNGKey(5)
    batch = {"tokens": jax.random.randint(key, (2, S), 0, cfg.vocab),
             "labels": jax.random.randint(key, (2, S), 0, cfg.vocab)}
    m1 = Model(cfg, mesh, compute_dtype=jnp.float32, unroll=False)
    m2 = Model(cfg, mesh, compute_dtype=jnp.float32, unroll=True)
    params = m1.init(0)
    l1 = float(jax.jit(m1.loss)(params, batch))
    l2 = float(jax.jit(m2.loss)(params, batch))
    assert l1 == pytest.approx(l2, rel=1e-5)


def test_sliding_window_masks_history():
    """gemma2 local layers: tokens beyond the window can't influence the
    output (move a distant token, logits unchanged)."""
    cfg = reduced_config(get_config("gemma2_2b"), sliding_window=8,
                         n_layers=2)   # one local + one global layer
    # Keep only the local layer by making both layers local.
    import dataclasses
    cfg = dataclasses.replace(cfg, local_global=False, sliding_window=8)
    from repro.models.attention import attend_full, init_attn
    from repro.models.common import KeyGen, AxisSizes
    p = init_attn(KeyGen(jax.random.PRNGKey(0)), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model)) * 0.1
    ax = AxisSizes.single()
    out1 = attend_full(p, x, cfg, ax, local=True)
    x2 = x.at[0, 0, :].set(99.0)      # token 0 is > window away from 31
    out2 = attend_full(p, x2, cfg, ax, local=True)
    np.testing.assert_allclose(np.asarray(out1[0, -1]),
                               np.asarray(out2[0, -1]), atol=1e-5)
    assert not np.allclose(np.asarray(out1[0, 1]), np.asarray(out2[0, 1]))


def test_pallas_decode_matches_xla():
    """Flash-decode kernel path (impl='pallas', interpret mode on CPU)
    produces the same serve-step logits as the XLA path."""
    cfg = reduced_config(get_config("gemma2_2b"))   # window + softcap
    mesh = make_local_mesh()
    key = jax.random.PRNGKey(9)
    toks = jax.random.randint(key, (2, S), 0, cfg.vocab)
    outs = []
    for impl in ("xla", "pallas"):
        model = Model(cfg, mesh, impl=impl, compute_dtype=jnp.float32)
        params = model.init(0)
        cache = model.init_cache(2, S, dtype=jnp.float32)
        _, cache = jax.jit(model.prefill)(
            params, {"tokens": toks[:, :S - 1]}, cache)
        lg, _ = model.decode(params, toks[:, S - 1:S], cache,
                             jnp.int32(S - 1))
        outs.append(np.asarray(lg))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4, rtol=1e-4)
