"""FLB-NUB coordinated-pool accounting invariants (§5.2).

Property-style tests over randomized demand/submit/tick/finish
sequences, using only stdlib ``random`` so they run even when
``hypothesis`` is absent (it is an optional dev dependency). Invariants
checked after EVERY event:

  P1  0 <= _pool_ws <= lb_ws          (WS within-pool share bounded)
  P2  _pool_idle >= 0                 (pool never oversubscribed)
  P3  _pool_pbj >= 0 and pool split sums to B  (conservation)
  P4  pool share + leased == WS demand (WS always fully covered)
  P5  the POOL ledger entry holds exactly B at all times
  P6  PBJ first-fit never overcommits (free >= 0)
"""

import random

import pytest

pytestmark = pytest.mark.tier1

from repro.core.jobs import Job
from repro.core.pbj_manager import PBJManager, PBJPolicyParams
from repro.core.provision import POOL, FLBNUBProvisionService
from repro.core.ws_manager import WSManager


def _check_invariants(svc):
    lb_ws = svc.lb_ws
    B = svc.coordinated_size
    assert 0 <= svc._pool_ws <= lb_ws, (svc._pool_ws, lb_ws)          # P1
    assert svc._pool_idle >= 0                                        # P2
    assert svc._pool_pbj >= 0                                         # P3
    assert svc._pool_ws + svc._pool_pbj + svc._pool_idle == B
    leased_ws = svc.cluster.allocated(svc.ws.name)
    assert svc._pool_ws + leased_ws == svc.ws.demand                  # P4
    assert svc.cluster.allocated(POOL) == B                           # P5
    assert svc.pbj.free >= 0                                          # P6
    assert svc.pbj.running.used() <= svc.pbj.owned


def _drive(svc, rng, n_events=200):
    pending = {}          # jid -> (end_time, epoch)
    jid = 0
    t = 0.0

    def pump(starts):
        for s in starts:
            pending[s.job.jid] = (s.end_time, s.epoch)

    pump(svc.startup(0.0, ws_initial=rng.randrange(0, 30)))
    _check_invariants(svc)
    for _ in range(n_events):
        t += rng.uniform(1.0, 900.0)
        kind = rng.choice(("submit", "ws", "tick", "finish"))
        if kind == "submit":
            job = Job(jid, t, size=rng.randrange(1, 40),
                      runtime=rng.uniform(1.0, 5000.0))
            jid += 1
            pump(svc.submit(t, job))
        elif kind == "ws":
            pump(svc.on_ws_demand(t, rng.randrange(0, 120)))
        elif kind == "tick":
            pump(svc.on_lease_tick(t))
        elif pending:
            k = min(pending, key=lambda q: pending[q][0])
            end, epoch = pending.pop(k)
            t = max(t, end)
            pump(svc.on_finish(t, k, epoch))
        _check_invariants(svc)
    return jid


@pytest.mark.parametrize("seed", range(8))
def test_flb_nub_pool_invariants_random_sequences(seed):
    rng = random.Random(seed)
    lb_pbj = rng.randrange(1, 30)
    lb_ws = rng.randrange(1, 30)
    svc = FLBNUBProvisionService(lb_pbj, lb_ws, PBJManager(), WSManager(),
                                 lease_seconds=3600.0)
    n_jobs = _drive(svc, rng)
    # No lost jobs: every submitted job is queued, running, or completed.
    pbj = svc.pbj
    accounted = (len(pbj.queue) + len(pbj.running) + len(pbj.completed))
    assert accounted == n_jobs


@pytest.mark.parametrize("seed", (0, 1, 2))
def test_flb_nub_release_rule_respects_pool(seed):
    """U/V/G release (rule 4) must come out of leased nodes first; pool
    nodes only churn back to the pool — B is held throughout."""
    rng = random.Random(100 + seed)
    svc = FLBNUBProvisionService(10, 5, PBJManager(params=PBJPolicyParams(
        release_threshold=0.9, elastic_factor=0.99)), WSManager(),
        lease_seconds=3600.0)
    svc.startup(0.0, ws_initial=0)
    t = 0.0
    for _ in range(50):
        t += 3600.0
        svc.on_ws_demand(t, rng.randrange(0, 20))
        svc.on_lease_tick(t)
        _check_invariants(svc)
        # Aggressive releasing can never un-hold the rigid lower bound.
        assert svc.cluster.allocated(POOL) == 15
