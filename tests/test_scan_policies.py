"""FB kill semantics and accounting invariants of the batched scan path.

The scan encoding (repro.sim.scan) replaces the event engine's Python
queue/kill machinery with status lanes over a fixed job window: a kill
is a masked flag flip and the killed lane *derives* back into the queue.
These tests pin that encoding down, in the spirit of
tests/test_pool_accounting.py:

  * a designed §5.1 demand-spike scenario where completion is only
    possible if killed jobs re-enter the queue and restart;
  * randomized (jobs, WS) workloads cross-checked against the event
    engine — kill activity, completed jobs and node-hours must agree;
  * capacity / pool invariants readable from the scan's metrics: an FB
    site never allocates beyond C, an FLB-NUB site never drops the
    rigid pool B (§5.2 — it is paid for whether idle or not).
"""

import random

import pytest

pytestmark = pytest.mark.tier1

from repro.core.jobs import Job
from repro.sim.engine import build_fb, build_flb_nub, clone_jobs, run_sim
from repro.sim.sweep import ScanOptions, SweepPoint, run_sweep

DAY = 24 * 3600.0
OPTS = ScanOptions(window=32)   # tiny window: these workloads are small


def scan_row(point, jobs, ws, duration):
    return run_sweep([point], jobs, ws, duration, mode="scan",
                     scan_options=OPTS)[0]


# ------------------------------------------------------ designed kill spike

def spike_workload():
    """C=10: three jobs fill the site, then a WS spike to 8 leaves a
    budget of 2 nodes — both size-4 jobs MUST be killed (§5.1 rule 2)
    and can only finish by re-entering the queue and restarting after
    the demand recedes and the lease tick re-provisions the idle pool."""
    jobs = [Job(0, 0.0, size=4, runtime=2 * 3600.0),
            Job(1, 0.0, size=4, runtime=2 * 3600.0),
            Job(2, 0.0, size=2, runtime=1200.0)]
    ws = [(0.0, 0), (1800.0, 8), (2 * 3600.0, 0)]
    return jobs, ws


def test_fb_scan_killed_jobs_reenter_and_finish():
    jobs, ws = spike_workload()
    point = SweepPoint("fb", capacity=10)
    row = scan_row(point, jobs, ws, 8 * 3600.0)
    ref = run_sim(build_fb(10), clone_jobs(jobs), ws, 8 * 3600.0)
    assert ref.kills == 2                       # the scenario really kills
    assert row["kills"] == ref.kills
    # Re-entry: all three jobs complete in BOTH engines — impossible for
    # the killed pair unless they re-queued and restarted.
    assert ref.completed_jobs == 3
    assert row["completed_jobs"] == 3
    assert row["peak_nodes"] == ref.peak_nodes == 10
    assert row["node_hours"] == pytest.approx(ref.node_hours, rel=0.05)
    assert row["window_overflow"] == 0


def test_fb_scan_partial_kill_prefers_fewest_nodes():
    """A smaller spike (demand 5, free 2 after the small job finished)
    needs only 3 more nodes — exactly one of the size-4 jobs dies, in
    both engines."""
    jobs, ws = spike_workload()
    ws = [(0.0, 0), (1800.0, 5), (2 * 3600.0, 0)]
    row = scan_row(SweepPoint("fb", capacity=10), jobs, ws, 8 * 3600.0)
    ref = run_sim(build_fb(10), clone_jobs(jobs), ws, 8 * 3600.0)
    assert ref.kills == 1
    assert row["kills"] == 1
    assert row["completed_jobs"] == ref.completed_jobs == 3


# ------------------------------------------------- randomized cross-checks

def random_workload(seed):
    rng = random.Random(seed)
    jobs = [Job(i, rng.uniform(0.0, 12 * 3600.0),
                size=2 ** rng.randrange(0, 4),
                runtime=rng.uniform(900.0, 2 * 3600.0))
            for i in range(30)]
    # WS change points on a 900 s grid (>= the scan substep, so both
    # engines see the same demand signal).
    ws = [(k * 900.0, rng.randrange(0, 13)) for k in range(0, 96, 2)]
    return jobs, ws


@pytest.mark.parametrize("seed", range(6))
def test_fb_scan_matches_event_on_random_traces(seed):
    jobs, ws = random_workload(seed)
    C = 12
    row = scan_row(SweepPoint("fb", capacity=C), jobs, ws, DAY)
    ref = run_sim(build_fb(C), clone_jobs(jobs), ws, DAY)
    assert row["window_overflow"] == 0
    # Kill activity agrees (node-weighted timing differences allowed).
    assert (row["kills"] > 0) == (ref.kills > 0)
    assert abs(row["kills"] - ref.kills) <= max(2, 0.5 * ref.kills)
    # Jobs are conserved: killed jobs re-enter, nothing is lost.
    assert abs(row["completed_jobs"] - ref.completed_jobs) <= 2
    assert row["node_hours"] == pytest.approx(ref.node_hours, rel=0.15)
    # Capacity invariant: an FB site can never allocate beyond C (§5.1).
    assert row["peak_nodes"] <= C
    assert row["node_hours"] <= C * DAY / 3600.0 + 1e-6


# --------------------------------------------- FLB-NUB kill-path exemption

@pytest.mark.parametrize("seed", (0, 1, 2))
def test_flb_nub_never_kills(seed):
    """The §5.2 policy has no force-release path: WS demand is satisfied
    elastically (never by taking PBJ nodes back) and the RSS release
    only ever returns *free* nodes — so FLB-NUB cannot kill, even on the
    kill-provoking workloads that make FB kill. This is why the scan
    path's checkpoint_preempt guard (repro.sim.sweep) rejects only FB
    points: for FLB-NUB the preemption mode is provably a no-op."""
    from repro.core.pbj_manager import PBJPolicyParams

    jobs, ws = random_workload(seed) if seed else spike_workload()
    for preempt in (False, True):
        params = PBJPolicyParams(checkpoint_preempt=preempt)
        ref = run_sim(build_flb_nub(13, 12, params=params),
                      clone_jobs(jobs), ws, DAY)
        assert ref.kills == 0, (seed, preempt)
    # ... and the same workload genuinely kills under FB, so the zero
    # above is the policy's doing, not a tame workload.
    assert run_sim(build_fb(10 if seed == 0 else 12), clone_jobs(jobs),
                   ws, DAY).kills > 0, seed


def test_flb_nub_scan_accepts_checkpoint_preempt():
    """mode="scan" accepts FLB-NUB points with checkpoint_preempt=True
    (deliberate exemption — see test_flb_nub_never_kills) and returns
    the same rows as without the flag, since nothing is ever killed."""
    from repro.core.pbj_manager import PBJPolicyParams

    jobs, ws = random_workload(7)
    rows = [scan_row(SweepPoint("flb_nub", lb_pbj=13, lb_ws=12,
                                params=PBJPolicyParams(
                                    checkpoint_preempt=preempt)),
                     jobs, ws, DAY)
            for preempt in (False, True)]
    assert rows[0]["kills"] == rows[1]["kills"] == 0
    assert rows[0] == rows[1]


def test_pick_dt_caps_flb_substep_by_ws_spacing():
    """The FLB-NUB substep never exceeds the WS change-point spacing
    (the U/V/G feedback runs on sampled demand — a finer trace would
    alias), floored at FLB_MIN_DT; FB keeps its validated coarse grid."""
    from repro.sim import scan as scanlib

    assert scanlib.pick_dt("fb", [3600.0]) == scanlib.FB_DT
    assert scanlib.pick_dt("flb_nub", [3600.0]) == scanlib.FLB_DT
    assert scanlib.pick_dt("flb_nub", [120.0]) == 120.0     # lease cap
    ws = [(0.0, 1), (150.0, 2), (300.0, 3)]
    assert scanlib.pick_dt("flb_nub", [3600.0], [ws]) == 150.0
    ws_fine = [(0.0, 1), (1.0, 2), (2.0, 1)]
    assert scanlib.pick_dt("flb_nub", [3600.0], [ws_fine]) \
        == scanlib.FLB_MIN_DT
    assert scanlib.pick_dt("fb", [3600.0], [ws]) == scanlib.FB_DT
    # Change points beyond the simulated horizon are never sampled and
    # must not shrink the substep.
    ws_late = [(0.0, 1), (9000.0, 2), (9150.0, 3)]
    assert scanlib.pick_dt("flb_nub", [3600.0], [ws_late],
                           duration=7200.0) == scanlib.FLB_DT
    assert scanlib.pick_dt("flb_nub", [3600.0], [ws_late]) == 150.0


def test_flb_scan_peak_contract_on_beyond_paper_grid():
    """Regression for the long-lease peak overshoot: on L = 2 h with a
    2×-scaled World Cup profile (a beyond-paper combo) the scan used to
    evaluate the U/V/G rules on *pre-start* demand, letting one tick
    absorb a whole submit burst as a single DR1 request — 57 % peak
    drift vs the event engine. With the event-faithful tick ordering
    (grant → first-fit → adjust → first-fit) the 15 % contract holds."""
    from repro.core.profiles import scale_profile
    from repro.sim import traces

    T = traces.TWO_WEEKS
    jobs = traces.nasa_ipsc(seed=1)
    ws = scale_profile(traces.worldcup98(seed=0, peak_vms=128), 2.0)
    pts = [SweepPoint("flb_nub", lb_pbj=13, lb_ws=12, lease_seconds=L,
                      label=f"FLB-NUB(L={L:g}s)")
           for L in (7200.0, 14400.0)]
    scan = run_sweep(pts, jobs, ws, T, mode="scan")
    event = run_sweep(pts, jobs, ws, T, mode="event")
    for p, s, e in zip(pts, scan, event):
        assert s["window_overflow"] == 0, p
        assert s["peak_nodes"] == pytest.approx(e["peak_nodes"],
                                                rel=0.15), p
        assert s["node_hours"] == pytest.approx(e["node_hours"],
                                                rel=0.15), p
        assert abs(s["completed_jobs"] - e["completed_jobs"]) \
            <= max(2, 0.02 * e["completed_jobs"]), p


@pytest.mark.parametrize("seed", (0, 1, 2))
def test_flb_scan_pool_invariants_on_random_traces(seed):
    jobs, ws = random_workload(100 + seed)
    lb_pbj, lb_ws = 13, 12
    B = lb_pbj + lb_ws
    row = scan_row(SweepPoint("flb_nub", lb_pbj=lb_pbj, lb_ws=lb_ws),
                   jobs, ws, DAY)
    ref = run_sim(build_flb_nub(lb_pbj, lb_ws), clone_jobs(jobs), ws, DAY)
    assert row["window_overflow"] == 0
    assert row["kills"] == 0                    # FLB-NUB never kills (§5.2)
    assert abs(row["completed_jobs"] - ref.completed_jobs) <= 2
    assert row["node_hours"] == pytest.approx(ref.node_hours, rel=0.15)
    # Pool invariants (the scan analog of test_pool_accounting P5): the
    # rigid pool B is held for the whole trace, so consumption is at
    # least B node-hours per hour and the peak is at least B.
    assert row["node_hours"] >= B * DAY / 3600.0 - 1e-6
    assert B <= row["peak_nodes"]
