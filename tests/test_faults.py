"""The chaos tier (repro.sim.faults + FAIL/REPAIR on the shared pump).

What is pinned here:

* the pump's simultaneity order with the new kinds — FINISH beats
  REPAIR beats FAIL at one timestamp, so a job finishing exactly when
  its node dies still completes;
* deterministic schedule generation (PRNG-keyed, replayable) and the
  site ledger's capacity clamp;
* the FB/FLB-NUB failure semantics (absorption order, shed accounting,
  pool bookkeeping) and the §5.1 checkpoint-restart recovery path;
* the three-path differential: event vs rounds under
  ``CONTRACTS["faults"]``, event vs LiveCloud trace replay with exact
  ledger identity;
* the no-lost-jobs invariant and monotone checkpointed progress, as a
  hypothesis property test when hypothesis is installed and over fixed
  seeds otherwise;
* the serving-layer degradation machinery: ``GrantBackoff`` and the
  admission throttle, plus torn-checkpoint skip-and-restore.
"""

import os

import numpy as np
import pytest

pytestmark = pytest.mark.tier1

from repro.core.jobs import Job
from repro.sim.contracts import CONTRACTS, FAULT_CONTRACT, no_lost_jobs
from repro.sim.engine import (build_fb, build_flb_nub, clone_jobs,
                              run_sim)
from repro.sim.faults import (FaultSchedule, burst_schedule,
                              exponential_schedule, merge_schedules,
                              weibull_schedule)
from repro.sim.pump import (CALL, FAIL, FINISH, REPAIR, SUBMIT, TICK,
                            WS, DecisionLedger)

DAY = 24 * 3600.0


# ------------------------------------------------------------ tie order

def test_event_kind_ordinals_pinned():
    """The packed fold tables and the heap tie-break both encode these
    ordinals — changing one silently reorders simultaneous events."""
    assert (WS, CALL, TICK, SUBMIT, FINISH, REPAIR, FAIL) == \
        (0, 1, 2, 3, 4, 5, 6)


def test_same_timestamp_tie_order_with_fault_kinds():
    """At one timestamp: ws < tick < submit < finish < repair < fail.
    The finish-before-fail leg IS the no-lost-jobs convention: a job
    completing at the exact instant its node dies has completed."""
    jobs = [Job(jid=0, submit=0.0, size=2, runtime=1800.0),
            Job(jid=1, submit=1800.0, size=2, runtime=600.0)]
    ws = [(0.0, 0), (1800.0, 1)]
    sched = FaultSchedule(np.array([600.0, 1800.0, 1800.0]),
                          np.array([1, -1, 2]))
    led = DecisionLedger()
    sys_ = build_fb(4, lease_seconds=1800.0)
    run_sim(sys_, jobs, ws, duration=3600.0, ledger=led, faults=sched)
    at = [e.kind for e in led.entries if e.t == 1800.0]
    assert at == ["ws", "tick", "submit", "finish", "repair", "fail"]
    # Job 0 finished at 1800.0 even though 2 nodes failed at 1800.0.
    assert jobs[0].completed
    # The same-instant failure killed the just-started job 1 instead —
    # recorded as a failure kill on the "fail" row, and the job is
    # requeued, not lost.
    assert led.kills("fail") == 1
    assert not jobs[1].completed
    assert no_lost_jobs(jobs, sys_) == []


# ----------------------------------------------------------- schedules

def test_schedule_validation():
    with pytest.raises(ValueError):        # unsorted
        FaultSchedule(np.array([2.0, 1.0]), np.array([1, -1]))
    with pytest.raises(ValueError):        # t <= 0
        FaultSchedule(np.array([0.0]), np.array([1]))
    with pytest.raises(ValueError):        # zero delta
        FaultSchedule(np.array([1.0]), np.array([0]))
    with pytest.raises(ValueError):        # repair before any failure
        FaultSchedule(np.array([1.0, 2.0]), np.array([1, -2]))
    with pytest.raises(ValueError):        # shape mismatch
        FaultSchedule(np.array([1.0, 2.0]), np.array([1]))


def test_generators_deterministic_and_replayable():
    kw = dict(n_nodes=8, mtbf=6 * 3600.0, mttr=1800.0, duration=DAY)
    a = exponential_schedule(seed=3, **kw)
    b = exponential_schedule(seed=3, **kw)
    assert np.array_equal(a.times, b.times)
    assert np.array_equal(a.deltas, b.deltas)
    c = exponential_schedule(seed=4, **kw)
    assert len(a) and (len(a) != len(c)
                       or not np.array_equal(a.times, c.times))
    w = weibull_schedule(seed=3, n_nodes=8, mtbf=6 * 3600.0,
                         mttr=1800.0, duration=DAY, shape=1.5)
    assert len(w) and int(np.sum(w.deltas == 1)) >= 1
    bu = burst_schedule(seed=3, k=4, mtbf=8 * 3600.0, mttr=3600.0,
                        duration=DAY)
    assert set(np.unique(np.abs(bu.deltas))) <= {4}
    assert bu.max_concurrent() in (0, 4)   # bursts never overlap
    m = merge_schedules(a, bu, None)
    assert len(m) == len(a) + len(bu)
    assert np.all(np.diff(m.times) >= 0)


def test_schedule_clamp_matches_ledger():
    """clamp(C) must reproduce the Cluster.fail_nodes/repair_nodes
    recurrence: at most C down at once, repairs revive only
    actually-failed nodes."""
    s = FaultSchedule(np.array([1.0, 2.0, 3.0, 4.0, 5.0]),
                      np.array([6, 6, -6, -6, 2]))
    c = s.clamp(9)
    # +6 -> 6 down; +6 clamps to +3 (9 cap); -6 -> 3 down; -6 clamps
    # to -3; +2 -> 2 down.
    assert list(c.deltas) == [6, 3, -6, -3, 2]
    assert c.max_concurrent() == 9
    # A clamp that never binds is the identity.
    i = s.clamp(100)
    assert np.array_equal(i.times, s.times)
    assert np.array_equal(i.deltas, s.deltas)


# ------------------------------------------------- FB failure semantics

def test_fb_fail_absorption_order_and_shed():
    """Absorption order idle -> PBJ kill -> WS shed, and the §5.1
    priority invariant after every fault event:
    ws_alloc == min(raw demand, C - failed)."""
    sys_ = build_fb(4, lease_seconds=3600.0)
    jobs = [Job(jid=0, submit=0.0, size=2, runtime=DAY)]
    ws = [(0.0, 0), (100.0, 6)]
    sched = FaultSchedule(np.array([200.0, 300.0]), np.array([2, -2]))
    led = DecisionLedger()
    run_sim(sys_, jobs, ws, duration=1000.0, ledger=led, faults=sched)
    # t=100: demand 6 > C=4 -> 4 granted (killing the PBJ job's nodes
    # as needed), 2 shed. t=200: 2 nodes fail -> WS drained to 2, 2
    # more shed. t=300: repair -> WS refilled to 4 from idle.
    assert sys_.shed_count == 4
    assert led.sheds() == 4
    by_t = {e.t: e for e in led.entries if e.kind in ("ws", "fail",
                                                      "repair")}
    assert by_t[100.0].ws_nodes == 4 and by_t[100.0].shed == 2
    assert by_t[200.0].ws_nodes == 2 and by_t[200.0].shed == 2
    assert by_t[300.0].ws_nodes == 4 and by_t[300.0].shed == 0
    assert no_lost_jobs(jobs, sys_) == []


def test_fb_fail_uses_idle_before_killing():
    sys_ = build_fb(8, lease_seconds=3600.0)
    jobs = [Job(jid=0, submit=0.0, size=2, runtime=DAY)]
    sched = FaultSchedule(np.array([100.0]), np.array([4]))
    led = DecisionLedger()
    run_sim(sys_, jobs, [(0.0, 0)], duration=1000.0, ledger=led,
            faults=sched)
    # PBJ owns all 8 but only uses 2: the 4 dead nodes come from its
    # idle share — no kill.
    assert led.kills() == 0
    assert sys_.cluster.allocated("PBJ") == 4
    assert jobs[0].jid in sys_.pbj.running


def test_fb_checkpoint_restart_recovers_progress():
    """§5.1 kill path in checkpoint-preempt mode: a failure-killed job
    restarts from its checkpointed progress, so it still completes
    within a horizon that a from-scratch restart would overrun."""
    from repro.core.pbj_manager import PBJPolicyParams
    ckpt = PBJPolicyParams(checkpoint_preempt=True)
    jobs_k = [Job(jid=0, submit=0.0, size=4, runtime=6000.0)]
    jobs_c = clone_jobs(jobs_k)
    # Down in [4000, 7000); PBJ re-leases at the 7200 tick (repairs
    # refill WS immediately but PBJ regains nodes on lease boundaries).
    # From scratch that restart needs 6000s (ends 13200, past the
    # horizon); from the 4000s checkpoint it needs 2000s (ends 9200).
    sched = FaultSchedule(np.array([4000.0, 7000.0]), np.array([4, -4]))
    run_sim(build_fb(4, 3600.0), jobs_k, [(0.0, 0)], duration=12000.0,
            faults=sched)
    run_sim(build_fb(4, 3600.0, params=ckpt), jobs_c, [(0.0, 0)],
            duration=12000.0, faults=sched)
    assert not jobs_k[0].completed       # from-scratch restart too slow
    assert jobs_c[0].completed           # checkpointed remainder fits
    assert jobs_c[0].kills == 1


# -------------------------------------------- FLB-NUB failure semantics

def test_flb_pool_accounting_under_fail_and_repair():
    sys_ = build_flb_nub(4, 2, lease_seconds=3600.0)
    jobs = [Job(jid=0, submit=0.0, size=2, runtime=5 * 3600.0)]
    ws = [(0.0, 0), (100.0, 2)]
    sched = FaultSchedule(np.array([200.0, 400.0]), np.array([5, -5]))
    led = DecisionLedger()
    run_sim(sys_, jobs, ws, duration=DAY, ledger=led, faults=sched)
    ev = {e.t: e for e in led.entries}
    # t=200: 5 of the 6 pool nodes die. Absorption: pool idle (0),
    # then pool-PBJ (kills the job, 4 nodes), then the WS pool share —
    # which is immediately replaced by an elastic lease: WS never
    # sheds under FLB-NUB.
    assert ev[200.0].killed == 1 and ev[200.0].kind == "fail"
    assert ev[200.0].ws_nodes == 1        # 1 elastic beyond the pool
    assert ev[200.0].total_nodes == 1 + 1  # surviving pool + elastic
    assert led.sheds() == 0
    # t=400: repair. WS moves back onto pool nodes, elastic released.
    assert ev[400.0].ws_nodes == 0
    assert ev[400.0].total_nodes == 6     # full pool held again
    # The killed job re-leases via U/V/G at the next tick and finishes.
    assert jobs[0].completed
    assert no_lost_jobs(jobs, sys_) == []


# ------------------------------------------------ three-path differential

def _chaos_workload(seed=0, n=24, capacity=12, horizon=DAY):
    rng = np.random.Generator(np.random.PCG64(seed))
    jobs = [Job(jid=i, submit=float(rng.uniform(0, horizon * 0.7)),
                size=int(rng.integers(1, max(2, capacity // 3))),
                runtime=float(rng.uniform(600.0, horizon / 6)))
            for i in range(n)]
    ws = [(float(t), int(rng.integers(0, capacity // 2 + 2)))
          for t in np.sort(rng.uniform(0, horizon, 10))]
    return jobs, ws


def _chaos_schedule(capacity, horizon):
    return merge_schedules(
        exponential_schedule(seed=7, n_nodes=capacity // 2,
                             mtbf=5 * 3600.0, mttr=1800.0,
                             duration=horizon),
        burst_schedule(seed=11, k=max(1, capacity // 4),
                       mtbf=10 * 3600.0, mttr=3600.0,
                       duration=horizon))


def test_event_vs_rounds_fault_differential():
    """One schedule through both engines: node-hours/peak in the 2 %
    band, completions within ±2 jobs (CONTRACTS['faults'] — the same
    table the bench gate reads)."""
    from repro.sim.rounds import fb_rounds_row
    capacity, lease, horizon = 12, 3600.0, DAY
    jobs, ws = _chaos_workload(capacity=capacity, horizon=horizon)
    sched = _chaos_schedule(capacity, horizon)
    assert len(sched) > 4
    sys_ = build_fb(capacity, lease)
    ev_jobs = clone_jobs(jobs)
    ev = run_sim(sys_, ev_jobs, ws, duration=horizon, name="event",
                 faults=sched)
    rr = fb_rounds_row(jobs, ws, capacity, lease, horizon, faults=sched)
    assert rr["engine"] == "rounds"
    violations = FAULT_CONTRACT.check_row(rr, ev.row())
    assert violations == [], violations
    assert CONTRACTS["faults"] is FAULT_CONTRACT  # bench gate coupling
    assert no_lost_jobs(ev_jobs, sys_) == []
    # Degenerate schedule: faults=None must agree with the event engine
    # under the ordinary exact rounds semantics.
    ev0 = run_sim(build_fb(capacity, lease), clone_jobs(jobs), ws,
                  duration=horizon, name="event")
    rr0 = fb_rounds_row(jobs, ws, capacity, lease, horizon)
    assert rr0["completed_jobs"] == ev0.completed_jobs
    # (float32 accumulation in the rounds kernel — not the fault band)
    assert rr0["node_hours"] == pytest.approx(ev0.node_hours, rel=1e-5)
    assert rr0["peak_nodes"] == ev0.peak_nodes


def test_live_vs_event_fault_ledger_identity():
    """The LiveCloud trace replay and the simulator share the pump: one
    fault schedule, two paths, identical ledgers entry for entry (the
    'completions exact event-vs-live' half of the chaos contract)."""
    from repro.core.pbj_manager import PBJPolicyParams
    from repro.core.runtime_bridge import LiveCloud
    capacity, lease, horizon = 12, 3600.0, DAY
    jobs, ws = _chaos_workload(seed=1, capacity=capacity,
                               horizon=horizon)
    sched = _chaos_schedule(capacity, horizon)
    sim_led = DecisionLedger()
    sim_jobs = clone_jobs(jobs)
    run_sim(build_fb(capacity, lease,
                     params=PBJPolicyParams(checkpoint_preempt=True)),
            sim_jobs, ws, duration=horizon, ledger=sim_led, faults=sched)
    d0 = max((int(d) for t, d in ws if t <= 0), default=0)
    cloud = LiveCloud(capacity, lease_seconds=lease, duration=horizon,
                      ws_initial=d0)
    live_jobs = clone_jobs(jobs)
    cloud.load_trace(live_jobs, ws_trace=ws, lease_ticks=True)
    cloud.inject_faults(sched)
    cloud.run_until(horizon)
    assert cloud.ledger.entries == sim_led.entries
    assert sum(j.completed for j in live_jobs) == \
        sum(j.completed for j in sim_jobs)
    assert cloud.ledger.kills("fail") > 0   # chaos actually engaged


# ------------------------------------- property: nothing is ever lost

def _run_invariant_case(seed):
    """No lost jobs + monotone checkpointed progress, FB and FLB-NUB."""
    from repro.core.pbj_manager import PBJPolicyParams
    capacity, horizon = 10, DAY
    jobs, ws = _chaos_workload(seed=seed, n=16, capacity=capacity,
                               horizon=horizon)
    rng = np.random.Generator(np.random.PCG64(seed + 99))
    sched = merge_schedules(
        exponential_schedule(seed=seed, n_nodes=capacity,
                             mtbf=float(rng.uniform(2, 8)) * 3600.0,
                             mttr=float(rng.uniform(0.2, 2)) * 3600.0,
                             duration=horizon),
        burst_schedule(seed=seed + 1, k=int(rng.integers(1, capacity)),
                       mtbf=8 * 3600.0, mttr=3600.0, duration=horizon))
    for build in (
            lambda: build_fb(capacity, 3600.0),
            lambda: build_fb(capacity, 3600.0, params=PBJPolicyParams(
                checkpoint_preempt=True)),
            lambda: build_flb_nub(capacity // 2, capacity // 2, 3600.0)):
        sys_ = build()
        progress = {}

        def watch(t, job, progress=progress):
            progress.setdefault(job.jid, []).append(job.progress)

        sys_.pbj.preempt_hooks.append(watch)
        run_jobs = clone_jobs(jobs)
        run_sim(sys_, run_jobs, ws, duration=horizon, faults=sched)
        assert no_lost_jobs(run_jobs, sys_) == [], (seed, type(sys_))
        ckpt = sys_.pbj.params.checkpoint_preempt
        for jid, seq in progress.items():
            if ckpt:
                # Checkpointed progress only ever accumulates across
                # restarts — a failure can never roll a job backwards.
                assert all(b >= a for a, b in zip(seq, seq[1:])), (
                    seed, jid, seq)
            else:
                assert all(p == 0.0 for p in seq), (seed, jid, seq)


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
    def test_no_lost_jobs_property(seed):
        _run_invariant_case(seed)
else:                                                  # pragma: no cover
    @pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
    def test_no_lost_jobs_property(seed):
        _run_invariant_case(seed)


# ------------------------------------------- serving-layer degradation

def test_grant_backoff_deterministic_and_bounded():
    from repro.serving.autoscaler import GrantBackoff
    a = GrantBackoff(base=30.0, max_delay=240.0, max_retries=5, seed=7)
    b = GrantBackoff(base=30.0, max_delay=240.0, max_retries=5, seed=7)
    da = [a.next_delay() for _ in range(7)]
    assert da == [b.next_delay() for _ in range(7)]
    # Exactly max_retries delays, then None (give up until demand moves).
    assert sum(d is not None for d in da) == 5
    assert da[5] is None and da[6] is None
    for i, d in enumerate(da[:5]):
        cap = min(30.0 * 2 ** i, 240.0)
        assert cap / 2 < d <= cap        # equal-jitter window, capped
    a.reset()
    assert a.next_delay() is not None
    with pytest.raises(ValueError):
        GrantBackoff(base=0.0)
    with pytest.raises(ValueError):
        GrantBackoff(base=10.0, max_delay=5.0)


def test_admission_throttle_sheds_and_counts():
    from repro.serving.autoscaler import AutoscaledService
    from repro.serving.engine import Request, VirtualReplica
    from repro.core.ws_manager import InstanceAdjustmentPolicy
    svc = AutoscaledService(
        policy=InstanceAdjustmentPolicy(initial_instances=1,
                                        min_instances=1,
                                        nodes_per_instance=1),
        slots_per_replica=2, max_queue=2,
        replica_factory=lambda: VirtualReplica(2))
    admitted = sum(
        svc.submit(Request(rid=i, prompt=np.zeros(2, np.int32),
                           max_new_tokens=2), now=0.0)
        for i in range(5))
    assert admitted == 2
    assert svc.shed_requests == 3
    assert len(svc.queue) == 2


def test_replay_with_faults_backs_off_and_recovers():
    """A full-capacity outage mid-replay: the autoscaler's grants come
    back short, the driver retries on the bounded backoff instead of
    every serve tick, and service recovers after the repair."""
    from repro.serving.replay import replay
    horizon = 6 * 3600.0
    ws = [(0.0, 2), (600.0, 4)]
    sched = FaultSchedule(np.array([3600.0, 3600.0 + 1800.0]),
                          np.array([6, -6]))
    res = replay([], ws, capacity=6, duration=horizon, serve_dt=60.0,
                 lease_seconds=1800.0, faults=sched, max_queue=512)
    assert res.grant_retries >= 1
    assert res.ledger.sheds() > 0          # the outage shed WS demand
    assert res.requests_completed > 0      # ...and service recovered
    # After the repair the provision service can satisfy the trace
    # demand again: the last derived-demand grant is fully allocated.
    assert res.row.peak_nodes <= 6


# --------------------------------------------------- torn checkpoints

def test_torn_checkpoint_skip_and_restore(tmp_path):
    from repro.train.checkpoint import Checkpointer, TornCheckpointError
    tree = {"w": np.arange(6, dtype=np.float32),
            "b": np.ones(3, dtype=np.float32)}
    ck = Checkpointer(str(tmp_path), keep=5)
    ck.save(1, tree, metadata={"step": 1})
    ck.save(2, {"w": tree["w"] * 2, "b": tree["b"] * 2},
            metadata={"step": 2})
    # Tear step 2: flip a leaf's bytes (CRC mismatch).
    leaf = os.path.join(str(tmp_path), "step_2", "leaf_0.npy")
    arr = np.load(leaf)
    np.save(leaf, arr + 1.0)
    with pytest.raises(TornCheckpointError):
        ck.restore(2, tree)
    # restore_latest skips the torn step and lands on the intact one.
    with pytest.warns(UserWarning, match="torn checkpoint"):
        restored, meta, step = ck.restore_latest(tree)
    assert step == 1 and meta["step"] == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])
    # Tear step 1's manifest too: nothing restorable left.
    with open(os.path.join(str(tmp_path), "step_1",
                           "manifest.msgpack"), "wb") as f:
        f.write(b"\xc1garbage")
    with pytest.warns(UserWarning, match="torn checkpoint"):
        assert ck.restore_latest(tree) is None
    # verify=False still refuses structurally torn steps (missing blob).
    os.remove(leaf)
    with pytest.raises(TornCheckpointError):
        ck.restore(2, tree, verify=False)
