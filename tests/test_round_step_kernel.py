"""Fused Pallas round-step kernel: interpret-mode bit-equality.

The kernel (``repro.kernels.round_step``) packs the rounds-engine loop
state into a scalar vector + window matrix and runs the shared
``rounds._chunk_core`` — compaction (``stable_compact``), job-table
admission, size classes and the unrolled event rounds built on
``fb_actions`` / ``flb_actions`` — as ONE ``pallas_call``. These tests
pin the two promises the ``kernel="pallas"`` backend rests on:

* the state pack round-trips EXACTLY (bools, int cursors, times,
  accumulators — no field loses a bit);
* a fused step equals the unfused reference step bit-for-bit on random,
  all-full, all-empty and overflow-edge windows, for both policies,
  with coalescing off and on, in f32 and f64 — and whole-sweep rows
  through ``ScanOptions(kernel="pallas")`` equal the ``"xla"`` rows.

Everything runs in interpret mode (CPU CI); on TPU the same tests
exercise the compiled kernel via ``ops._default_interpret``.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import round_step as rsk
from repro.sim import rounds as roundslib
from repro.sim import traces
from repro.sim.rounds import ACC_KEYS, RoundsSpec
from repro.sim.sweep import ScanOptions, SweepPoint, run_sweep

pytestmark = pytest.mark.tier1

DAY = 24 * 3600.0
K = 16          # small window → fast interpret steps, real compaction


def _spec(**kw):
    base = dict(duration=2 * DAY, max_rounds=4096, window=K,
                kernel="pallas")
    base.update(kw)
    return RoundsSpec(**base)


def _lane(policy, seed=0):
    """One real packed lane + its ctx dict and kernel input stack."""
    rng = np.random.default_rng(seed)
    horizon = 2 * DAY
    jobs = [j for j in traces.nasa_ipsc(seed=seed) if j.submit < horizon]
    ws = [(t, d) for t, d in traces.worldcup98(seed=seed, peak_vms=64)
          if t < horizon]
    if policy == "fb":
        leases, levels = [3600.0], [24]
        prm = {"lease": jnp.asarray(3600.0), "capacity": jnp.asarray(24.0),
               "p_idx": jnp.asarray(0, jnp.int32)}
    else:
        leases, levels = [3600.0], [12]
        prm = {"lease": jnp.asarray(3600.0), "B": jnp.asarray(25.0),
               "lb_ws": jnp.asarray(12.0), "U": jnp.asarray(0.25),
               "V": jnp.asarray(0.5), "G": jnp.asarray(2.0),
               "p_idx": jnp.asarray(0, jnp.int32)}
    pk = jax.tree_util.tree_map(
        lambda a: a[0], roundslib.pack_event_workloads(
            [(jobs, ws)], horizon, K, policy, leases=leases,
            levels=levels))
    prm = {k: v.astype(pk.submit.dtype) if k != "p_idx" else v
           for k, v in prm.items()}
    ctx = roundslib._lane_ctx(policy, prm, pk)
    return pk, ctx, rsk.lane_inputs(policy, ctx), rng


def _core(pk, kind, rng):
    """A loop state of the requested shape: ``random`` mid-simulation,
    ``all_full`` (every lane running, nothing done), ``all_empty``
    (every lane a pad row), ``overflow_edge`` (admission cursor at the
    table end — the dynamic-slice clamp path)."""
    f = pk.submit.dtype
    zero = jnp.zeros((), f)
    Jp = int(pk.submit.shape[0])
    acc = {k: jnp.asarray(rng.uniform(0, 50), f) for k in ACC_KEYS}
    t = jnp.asarray(rng.uniform(0, DAY), f)
    w_sub = pk.submit[:K]
    w_sz, w_rt = pk.size[:K], pk.runtime[:K]
    if kind == "random":
        run = jnp.asarray(rng.random(K) < 0.4)
        done = jnp.asarray(rng.random(K) < 0.2) & ~run
        next_row = jnp.asarray(K + 7, jnp.int32)
    elif kind == "all_full":
        run = jnp.ones(K, bool)
        done = jnp.zeros(K, bool)
        next_row = jnp.asarray(K, jnp.int32)
    elif kind == "all_empty":
        run = jnp.zeros(K, bool)
        done = jnp.ones(K, bool)      # whole window compacts away
        next_row = jnp.asarray(Jp, jnp.int32)
        w_sub = jnp.full(K, jnp.inf, f)
        w_sz = jnp.zeros(K, f)
        w_rt = jnp.zeros(K, f)
    else:                              # overflow_edge
        run = jnp.asarray(rng.random(K) < 0.5)
        done = ~run                    # max churn at the table end
        next_row = jnp.asarray(Jp, jnp.int32)
    start_t = jnp.where(run | done, jnp.maximum(w_sub, 0.0), zero)
    end_t = jnp.where(run | done, start_t + w_rt, zero)
    return (t, jnp.asarray(24.0, f), jnp.asarray(4.0, f),
            jnp.sum(jnp.where(run, w_sz, zero)),
            jnp.asarray(bool(rng.random() < 0.5)),
            pk.ws0, jnp.asarray(20.0, f), jnp.asarray(0, jnp.int32),
            next_row, w_sub, w_sz, w_rt, run, done, start_t, end_t, acc)


def _assert_trees_equal(a, b, label):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        assert x.dtype == y.dtype, (label, x.dtype, y.dtype)
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=str(label))


@pytest.mark.parametrize("policy", ["fb", "flb_nub"])
@pytest.mark.parametrize("kind", ["random", "all_full", "all_empty",
                                  "overflow_edge"])
def test_pack_carry_roundtrip_is_exact(policy, kind):
    pk, _, _, rng = _lane(policy)
    core = _core(pk, kind, rng)
    back = rsk.unpack_carry(*rsk.pack_carry(core))
    _assert_trees_equal(core, back, (policy, kind))
    # Bool/int fields come back with their exact types, not floats.
    assert back[4].dtype == jnp.bool_          # has_queue
    assert back[7].dtype == jnp.int32          # rise_i
    assert back[8].dtype == jnp.int32          # next_row
    assert back[12].dtype == back[13].dtype == jnp.bool_   # run, done


@pytest.mark.parametrize("policy", ["fb", "flb_nub"])
def test_ctx_roundtrip_through_kernel_inputs(policy):
    """lane_inputs → _ctx_from_inputs reproduces the _lane_ctx dict
    value-for-value — the precondition for shared-_chunk_core
    equality."""
    _, ctx, inputs, _ = _lane(policy)
    back = rsk._ctx_from_inputs(policy, *inputs)
    assert set(back) == set(ctx)
    for k in ctx:
        np.testing.assert_array_equal(np.asarray(ctx[k]),
                                      np.asarray(back[k]), err_msg=k)


@pytest.mark.parametrize("policy", ["fb", "flb_nub"])
@pytest.mark.parametrize("kind", ["random", "all_full", "all_empty",
                                  "overflow_edge"])
@pytest.mark.parametrize("batch", [1, 8])
def test_fused_step_bit_equals_reference(policy, kind, batch):
    """One fused pallas_call == one unfused traced step, bit-for-bit,
    on every window shape × policy × coalesce setting. Both sides run
    under jit — the only way the engines ever call them (an EAGER
    op-by-op reference can drift a ULP on the float accumulators, as
    eager dispatch rounds each mul/add separately)."""
    pk, _, inputs, rng = _lane(policy)
    spec = _spec(batch=batch)
    sc, win = rsk.pack_carry(_core(pk, kind, rng))

    def call(fn):
        return jax.jit(lambda s, w: fn(*inputs, s, w, policy=policy,
                                       spec=spec, interpret=True))(sc, win)

    _assert_trees_equal(call(rsk.chunk_step), call(rsk.chunk_step_ref),
                        (policy, kind, batch))


@pytest.mark.parametrize("policy", ["fb", "flb_nub"])
def test_fused_step_equals_reference_vmapped(policy):
    """Under vmap (the lane axis the sweep engines batch over): every
    DISCRETE outcome — the window matrix (starts, completions, kills,
    queue state, times) and the event-exact scalars — matches the
    vmapped reference bit-for-bit. The three float TIME-INTEGRAL
    accumulators (turn_sum, exec_sum, node_seconds) are compared to
    1e-6 relative instead: a batched reduction may round a ULP apart
    from a per-lane one in EITHER backend (vmapping the pure-jnp
    reference shifts them the same way), so cross-batching bit-equality
    is not a property any backend has. The bit-identity contract that
    matters — fused vs unfused rows under the SAME engine batching —
    is pinned end-to-end by test_sweep_rows_match_xla_backend and the
    differential harness."""
    pk, _, inputs, rng = _lane(policy)
    spec = _spec()
    cores = [rsk.pack_carry(_core(pk, "random", rng)) for _ in range(5)]
    sc = jnp.stack([c[0] for c in cores])
    win = jnp.stack([c[1] for c in cores])

    def call(fn):
        return jax.jit(jax.vmap(
            lambda s, w: fn(*inputs, s, w, policy=policy, spec=spec,
                            interpret=True), in_axes=(0, 0)))(sc, win)

    fused, ref = call(rsk.chunk_step), call(rsk.chunk_step_ref)
    np.testing.assert_array_equal(np.asarray(fused[1]),
                                  np.asarray(ref[1]), err_msg=policy)
    integral = [rsk.SC_ACC0 + ACC_KEYS.index(k)
                for k in ("turn_sum", "exec_sum", "node_seconds")]
    exact = [i for i in range(rsk.SC_SIZE) if i not in integral]
    sf, sr = np.asarray(fused[0]), np.asarray(ref[0])
    np.testing.assert_array_equal(sf[:, exact], sr[:, exact],
                                  err_msg=policy)
    np.testing.assert_allclose(sf[:, integral], sr[:, integral],
                               rtol=1e-6, err_msg=policy)


def test_fused_step_bit_equals_reference_float64():
    """f64 lanes (the bit-match-vs-event precision) through the fused
    kernel — the pack dtype follows the lane dtype."""
    from jax.experimental import enable_x64

    with enable_x64():
        pk, _, inputs, rng = _lane("fb")
        assert pk.submit.dtype == jnp.float64
        spec = _spec()
        sc, win = rsk.pack_carry(_core(pk, "random", rng))
        assert sc.dtype == win.dtype == jnp.float64

        def call(fn):
            return jax.jit(lambda s, w: fn(*inputs, s, w, policy="fb",
                                           spec=spec, interpret=True)
                           )(sc, win)

        _assert_trees_equal(call(rsk.chunk_step),
                            call(rsk.chunk_step_ref), "f64")


def test_sweep_rows_match_xla_backend():
    """End to end: ScanOptions(kernel="pallas") rows == kernel="xla"
    rows on a queue-provoking trace, for both policies, plain and
    coalesced."""
    horizon = 2 * DAY
    jobs = [j for j in traces.nasa_ipsc(seed=11) if j.submit < horizon]
    ws = [(t, d) for t, d in traces.worldcup98(seed=11, peak_vms=64)
          if t < horizon]
    pts = [SweepPoint("fb", capacity=24),
           SweepPoint("flb_nub", lb_pbj=13, lb_ws=12)]
    for co in (None, 8):
        xla = run_sweep(pts, jobs, ws, horizon, mode="rounds",
                        scan_options=ScanOptions(coalesce=co))
        pallas = run_sweep(pts, jobs, ws, horizon, mode="rounds",
                           scan_options=ScanOptions(coalesce=co,
                                                    kernel="pallas"))
        assert pallas == xla, (co, [(i, a, b) for i, (a, b) in
                                    enumerate(zip(xla, pallas))
                                    if a != b][:2])


def test_kernel_field_is_validated_and_cached_separately():
    """Unknown kernels fail fast; the jit-cache key (policy, spec)
    distinguishes backends, so switching can never reuse a stale
    program."""
    with pytest.raises(ValueError, match="unknown rounds kernel"):
        _spec(kernel="triton")
    with pytest.raises(ValueError, match="unknown rounds kernel"):
        dataclasses.replace(_spec(), kernel="")
    s = _spec()
    assert roundslib._rounds_lane("fb", s) is roundslib._rounds_lane(
        "fb", _spec())
    assert roundslib._rounds_lane("fb", s) is not roundslib._rounds_lane(
        "fb", dataclasses.replace(s, kernel="xla"))


def test_warmup_sweep_is_clear_caches_safe():
    """The bench's compile-measurement helper: warming, clearing and
    re-warming returns identical rows (nothing stale survives a
    jax.clear_caches), and the warmed steady-state call still works."""
    from repro.sim.sweep import warmup_sweep
    from repro.sim.sweep import run_sweep_workloads

    horizon = 12 * 3600.0
    jobs = [j for j in traces.nasa_ipsc(seed=2) if j.submit < horizon]
    ws = [(t, d) for t, d in traces.worldcup98(seed=2, peak_vms=64)
          if t < horizon]
    pts = [SweepPoint("fb", capacity=24)]
    wls = [(jobs, ws)]
    opts = ScanOptions(kernel="pallas")
    wall = warmup_sweep(pts, wls, horizon, mode="rounds",
                        scan_options=opts)
    assert wall > 0
    rows1 = run_sweep_workloads(pts, wls, horizon, mode="rounds",
                                scan_options=opts)
    jax.clear_caches()
    warmup_sweep(pts, wls, horizon, mode="rounds", scan_options=opts)
    rows2 = run_sweep_workloads(pts, wls, horizon, mode="rounds",
                                scan_options=opts)
    assert rows1 == rows2
