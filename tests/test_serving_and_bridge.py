"""Serving engine, autoscaler (§6.4 policy live), and the runtime bridge
(live PhoenixCloud with checkpoint-preempt) — end-to-end behaviour."""

import numpy as np
import pytest

from repro.configs.base import get_config, reduced_config
from repro.core.runtime_bridge import LiveCloud
from repro.launch.mesh import make_local_mesh
from repro.serving.autoscaler import AutoscaledService
from repro.serving.engine import LeastLoadedRouter, Replica, Request


@pytest.fixture(scope="module")
def mesh():
    return make_local_mesh()


def _req(rid, cfg, n=6, plen=8):
    rng = np.random.default_rng(rid)
    return Request(rid=rid,
                   prompt=rng.integers(0, cfg.vocab, plen).astype(np.int32),
                   max_new_tokens=n)


def test_replica_decodes_requests(mesh):
    cfg = reduced_config(get_config("smollm_135m"))
    rep = Replica(cfg, mesh, slots=2, max_len=32)
    assert rep.admit(_req(0, cfg))
    assert rep.admit(_req(1, cfg))
    assert rep.free_slot() is None
    done = []
    for _ in range(10):
        done += rep.step()
        if len(done) == 2:
            break
    assert len(done) == 2
    for r in done:
        assert len(r.output) >= r.max_new_tokens
        assert all(0 <= t < cfg.vocab for t in r.output)


def test_greedy_decode_is_deterministic(mesh):
    cfg = reduced_config(get_config("smollm_135m"))
    outs = []
    for _ in range(2):
        rep = Replica(cfg, mesh, slots=1, max_len=32, seed=7)
        rep.admit(_req(5, cfg, n=5))
        done = []
        while not done:
            done = rep.step()
        outs.append(done[0].output)
    assert outs[0] == outs[1]


def test_router_least_loaded(mesh):
    cfg = reduced_config(get_config("smollm_135m"))
    r1 = Replica(cfg, mesh, slots=2, max_len=32)
    r2 = Replica(cfg, mesh, slots=2, max_len=32, params=r1.params)
    r1.admit(_req(0, cfg))
    assert LeastLoadedRouter().route([r1, r2]) is r2


def test_autoscaler_scales_up_under_load(mesh):
    cfg = reduced_config(get_config("smollm_135m"))
    svc = AutoscaledService(cfg, mesh, slots_per_replica=2, max_len=32)
    start = len(svc.replicas)
    for i in range(12):
        svc.submit(_req(i, cfg, n=12))
    for t in range(40):
        svc.tick(now=float(t))
        if len(svc.replicas) > start:
            break
    assert len(svc.replicas) > start, "80% policy never scaled up"
    # Drain; the (n-1)/n rule must scale back down.
    for t in range(40, 200):
        svc.tick(now=float(t))
        if not svc.queue and all(r.n_active == 0 for r in svc.replicas) \
                and len(svc.replicas) <= start:
            break
    assert len(svc.replicas) <= start + 1


def test_live_cloud_preempt_and_resume(mesh, tmp_path):
    """End-to-end PhoenixCloud-on-JAX: FB policy, WS spike preempts the
    training job via checkpoint, job resumes and completes after the
    spike recedes."""
    cloud = LiveCloud(capacity=8, mesh=mesh,
                      checkpoint_root=str(tmp_path))
    cloud.submit_training(jid=1, arch="smollm_135m", chips=6, steps=20)
    assert 1 in cloud.pbj.running
    cloud.run_quantum(steps=5)          # make some progress
    payload = cloud._live[1].payload
    assert payload.step >= 5
    # WS spike to 5 chips: 8 - 5 < 6 → job must be preempted.
    cloud.preempt_for_ws(5)
    assert 1 not in cloud.pbj.running
    assert cloud.service.cluster.allocated("WS") == 5
    step_at_preempt = payload.step
    # Spike recedes; next lease tick re-provisions idle chips to PBJ.
    cloud.set_ws_demand(1)
    cloud.lease_tick()
    assert 1 in cloud.pbj.running
    finished = []
    for _ in range(6):
        finished = cloud.run_quantum(steps=5)
        if finished:
            break
    assert finished == [1]
    assert payload.step == 20
    assert payload.step >= step_at_preempt   # no lost progress
