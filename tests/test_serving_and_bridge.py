"""Serving engine, autoscaler (§6.4 policy live), and the runtime bridge
(live PhoenixCloud with checkpoint-preempt).

Two speed tiers share this file:

* fast tier-1 tests exercise the live stack's logic with
  ``VirtualReplica`` payloads and stub training jobs — window semantics
  of the utilization policy, the deferred-shrink drain protocol, router
  edge cases, lease accounting of a virtual-tier ``LiveCloud``, and the
  checkpoint-on-preempt hook;
* ``slow``-marked tests run real ``Replica``/``TrainJob`` payloads
  (model forward passes, jit compiles) end-to-end — excluded from the
  CI smoke job via ``-m "not slow"``.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.jobs import Job
from repro.core.runtime_bridge import LiveCloud, LiveJob
from repro.core.ws_manager import InstanceAdjustmentPolicy, WSManager
from repro.serving.engine import (LeastLoadedRouter, Request,
                                  VirtualReplica)

pytestmark = pytest.mark.tier1


@pytest.fixture(scope="module")
def mesh():
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh()


def _cfg():
    from repro.configs.base import get_config, reduced_config
    return reduced_config(get_config("smollm_135m"))


def _req(rid, cfg=None, n=6, plen=8):
    rng = np.random.default_rng(rid)
    vocab = cfg.vocab if cfg is not None else 64
    return Request(rid=rid,
                   prompt=rng.integers(0, vocab, plen).astype(np.int32),
                   max_new_tokens=n)


# ------------------------------------------------- WSManager (fast tier)

def test_ws_manager_window_semantics():
    """Samples feed a (t - window, t] average: stale samples age out,
    growth fires above 80 %, shrink below 80 %·(n−1)/n — deferred."""
    policy = InstanceAdjustmentPolicy(initial_instances=2,
                                      window_seconds=20.0)
    mgr = WSManager(policy=policy)
    # avg(0.95) > 0.8 → grow fires on the first sample.
    assert mgr.observe_utilization(0.0, 0.95) == 3
    assert mgr.instances == 3
    # Window restarts after a change; a pair averaging under the grow
    # threshold but over the shrink one holds steady.
    assert mgr.observe_utilization(5.0, 0.70) is None
    assert mgr.observe_utilization(10.0, 0.75) is None
    assert mgr.instances == 3
    # 25s later the old samples aged out of the 20s window: the single
    # fresh sample 0.1 < 0.8·(2/3) fires a shrink.
    assert mgr.observe_utilization(35.0, 0.10) == 2
    assert mgr.draining == 1
    assert mgr.instances == 3            # deferred until drain confirms


def test_ws_manager_deferred_shrink_and_resurrect():
    policy = InstanceAdjustmentPolicy(initial_instances=3,
                                      window_seconds=10.0)
    mgr = WSManager(policy=policy)
    assert mgr.observe_utilization(0.0, 0.0) == 2       # mark one
    assert (mgr.instances, mgr.draining) == (3, 1)
    assert mgr.nodes_needed == 3          # drainer still holds its lease
    # Growth while draining resurrects the marked instance — no new one.
    assert mgr.observe_utilization(1.0, 0.99) == 3
    assert (mgr.instances, mgr.draining) == (3, 0)
    # Shrink again, then the drain completes: both counts drop together.
    assert mgr.observe_utilization(2.0, 0.0) == 2
    mgr.confirm_shrink()
    assert (mgr.instances, mgr.draining) == (2, 0)
    assert mgr.nodes_needed == 2


def test_ws_manager_respects_min_instances():
    policy = InstanceAdjustmentPolicy(initial_instances=1,
                                      min_instances=1,
                                      window_seconds=10.0)
    mgr = WSManager(policy=policy)
    for k in range(5):
        assert mgr.observe_utilization(float(k), 0.0) is None
    assert (mgr.instances, mgr.draining) == (1, 0)


# ------------------------------------------ router + virtual replicas

def test_router_edge_cases():
    router = LeastLoadedRouter()
    assert router.route([]) is None
    full = VirtualReplica(slots=1)
    assert full.admit(_req(0, n=3))
    assert router.route([full]) is None          # all slots taken
    empty = VirtualReplica(slots=1)
    assert router.route([full, empty]) is empty  # least-loaded wins


def test_virtual_replica_slot_lifecycle():
    rep = VirtualReplica(slots=2)
    a, b = _req(0, n=2), _req(1, n=4)
    assert rep.admit(a) and rep.admit(b)
    assert rep.free_slot() is None and rep.utilization == 1.0
    assert rep.step() == []                      # nothing done yet
    assert rep.step() == [a]                     # a held 2 ticks exactly
    assert rep.n_active == 1
    assert rep.step() == []
    assert rep.step() == [b]                     # b held 4 ticks exactly
    assert rep.n_active == 0
    assert len(a.output) == 2 and len(b.output) == 4


def test_autoscaler_shrink_stays_in_sync():
    """Regression for the shrink desync: the manager's instance count
    used to drop when no replica was idle, leaving ``instances`` <
    ``len(replicas)`` forever. Under the drain protocol the two agree
    after EVERY tick, and the shrink still completes once the drainer
    empties."""
    from repro.serving.autoscaler import AutoscaledService

    policy = InstanceAdjustmentPolicy(initial_instances=2,
                                      min_instances=1,
                                      window_seconds=10.0)
    svc = AutoscaledService(policy=policy, slots_per_replica=4,
                            replica_factory=lambda: VirtualReplica(4))
    # One long request per replica: utilization 2/8 is under the shrink
    # threshold 0.8·(1/2), so the policy fires while BOTH replicas still
    # hold work — the marked one must drain, not vanish with its
    # request.
    svc.submit(_req(0, n=12), now=0.0)
    svc.submit(_req(1, n=12), now=0.0)
    history = []
    for k in range(1, 40):
        svc.tick(now=float(k) * 5.0)
        history.append((svc.manager.instances, len(svc.replicas),
                        svc.manager.draining, len(svc.draining)))
        assert svc.manager.instances == len(svc.replicas)
        assert svc.manager.draining == len(svc.draining)
    assert len(svc.replicas) == policy.min_instances  # shrink completed
    assert len(svc.completed) == 2                    # nothing dropped
    assert any(d > 0 for _, _, d, _ in history)       # drain really ran


def test_autoscaler_grows_under_virtual_load():
    from repro.serving.autoscaler import AutoscaledService

    policy = InstanceAdjustmentPolicy(initial_instances=1,
                                      window_seconds=10.0)
    svc = AutoscaledService(policy=policy, slots_per_replica=2,
                            replica_factory=lambda: VirtualReplica(2))
    rid = 0
    for k in range(1, 15):
        for _ in range(3):
            svc.submit(_req(rid, n=4), now=float(k) * 5.0)
            rid += 1
        svc.tick(now=float(k) * 5.0)
    assert len(svc.replicas) > 1, "80% policy never scaled up"
    assert svc.manager.instances == len(svc.replicas)


# ------------------------------------- LiveCloud, virtual tier (fast)

def test_live_cloud_virtual_lease_accounting():
    """The bridge on the pump, no JAX anywhere: virtual jobs complete
    from their Started.end_time, WS demand moves leases, and every
    decision lands in the ledger with conserved node counts."""
    cloud = LiveCloud(capacity=8, lease_seconds=60.0, ws_initial=2)
    assert cloud.service.cluster.allocated("WS") == 2
    assert cloud.service.cluster.allocated("PBJ") == 6   # rest granted
    cloud.submit_job(Job(jid=1, submit=0.0, size=4, runtime=120.0))
    assert 1 in cloud.pbj.running
    cloud.set_ws_demand(6)            # 8-6=2 < 4 → job preempted
    assert 1 not in cloud.pbj.running
    assert cloud.service.cluster.allocated("WS") == 6
    cloud.set_ws_demand(1)
    cloud.lease_tick()                # idle chips flow back to PBJ
    assert 1 in cloud.pbj.running
    cloud.run_until(cloud.t + 600.0)  # virtual FINISH auto-scheduled
    assert 1 not in cloud.pbj.running
    job = next(e for e in cloud.ledger.entries if e.kind == "finish")
    assert job.arg == 1.0
    for e in cloud.ledger.entries:
        assert e.pbj_nodes + e.ws_nodes == e.total_nodes <= 8


class _StubPayload:
    """Stands in for TrainJob in hook tests: counts checkpoints."""

    def __init__(self, step=7):
        self.step = step
        self.checkpoints = 0

    def checkpoint(self, block=False):
        self.checkpoints += 1


def test_preempt_hook_checkpoints_live_victims():
    """Satellite regression: a live job killed by a WS spike must get a
    checkpoint call at the manager's kill site, and its queue entry's
    progress must be pinned to the payload's step count (bridge time
    unit), not the wall-clock formula."""
    cloud = LiveCloud(capacity=8, lease_seconds=60.0)
    job = Job(jid=9, submit=0.0, size=6, runtime=30.0)
    stub = _StubPayload(step=7)
    cloud._live[9] = LiveJob(job, stub)
    cloud.submit_job(job)
    assert 9 in cloud.pbj.running
    victims = cloud.preempt_for_ws(5)      # 8-5=3 < 6 → must preempt
    assert victims == [9]
    assert stub.checkpoints == 1
    assert job.progress == 7.0
    assert 9 in [j.jid for j in cloud.pbj.queue]


def test_preempt_hook_ignores_virtual_jobs():
    cloud = LiveCloud(capacity=8, lease_seconds=60.0)
    cloud.submit_job(Job(jid=2, submit=0.0, size=6, runtime=3600.0))
    assert cloud.preempt_for_ws(5) == [2]  # no payload — no crash
    progress = next(j for j in cloud.pbj.queue if j.jid == 2).progress
    assert progress >= 0.0                 # wall-clock formula applied


# --------------------------------------------- real payloads (slow)

@pytest.mark.slow
def test_replica_decodes_requests(mesh):
    from repro.serving.engine import Replica
    cfg = _cfg()
    rep = Replica(cfg, mesh, slots=2, max_len=32)
    assert rep.admit(_req(0, cfg))
    assert rep.admit(_req(1, cfg))
    assert rep.free_slot() is None
    done = []
    for _ in range(10):
        done += rep.step()
        if len(done) == 2:
            break
    assert len(done) == 2
    for r in done:
        assert len(r.output) >= r.max_new_tokens
        assert all(0 <= t < cfg.vocab for t in r.output)


@pytest.mark.slow
def test_replica_per_slot_positions(mesh):
    """Satellite regression: two slots with UNEQUAL prompt lengths must
    each write at their own cache position. The old uniform
    ``pos.max()`` write put the short slot's token at the long slot's
    position, leaving a hole in its cache row."""
    cfg = _cfg()
    from repro.serving.engine import Replica
    rep = Replica(cfg, mesh, slots=2, max_len=32)
    assert rep.admit(_req(0, cfg, n=4, plen=3))
    assert rep.admit(_req(1, cfg, n=4, plen=8))
    assert list(rep.pos) == [3, 8]
    rep.step()
    assert list(rep.pos) == [4, 9]
    k = np.asarray(rep.cache["l0"]["k"])   # (periods, slots, kv, L, hd)
    # Slot 0's decode token landed at ITS position 3...
    assert np.abs(k[:, 0, :, 3, :]).max() > 0
    # ...and nowhere past it (the uniform-pos bug wrote at 8).
    assert np.abs(k[:, 0, :, 4:, :]).max() == 0
    assert np.abs(k[:, 1, :, 8, :]).max() > 0


@pytest.mark.slow
def test_greedy_decode_is_deterministic(mesh):
    from repro.serving.engine import Replica
    cfg = _cfg()
    outs = []
    for _ in range(2):
        rep = Replica(cfg, mesh, slots=1, max_len=32, seed=7)
        rep.admit(_req(5, cfg, n=5))
        done = []
        while not done:
            done = rep.step()
        outs.append(done[0].output)
    assert outs[0] == outs[1]


@pytest.mark.slow
def test_router_least_loaded(mesh):
    from repro.serving.engine import Replica
    cfg = _cfg()
    r1 = Replica(cfg, mesh, slots=2, max_len=32)
    r2 = Replica(cfg, mesh, slots=2, max_len=32, params=r1.params)
    r1.admit(_req(0, cfg))
    assert LeastLoadedRouter().route([r1, r2]) is r2


@pytest.mark.slow
def test_autoscaler_scales_up_under_load(mesh):
    from repro.serving.autoscaler import AutoscaledService
    cfg = _cfg()
    svc = AutoscaledService(cfg, mesh, slots_per_replica=2, max_len=32)
    start = len(svc.replicas)
    for i in range(12):
        svc.submit(_req(i, cfg, n=12))
    for t in range(40):
        svc.tick(now=float(t))
        if len(svc.replicas) > start:
            break
    assert len(svc.replicas) > start, "80% policy never scaled up"
    # Drain; the (n-1)/n rule must scale back down.
    for t in range(40, 200):
        svc.tick(now=float(t))
        if not svc.queue and all(r.n_active == 0 for r in svc.replicas) \
                and len(svc.replicas) <= start:
            break
    assert len(svc.replicas) <= start + 1


@pytest.mark.slow
def test_live_cloud_preempt_and_resume(mesh, tmp_path):
    """End-to-end PhoenixCloud-on-JAX: FB policy, WS spike preempts the
    training job via checkpoint, job resumes and completes after the
    spike recedes."""
    cloud = LiveCloud(capacity=8, mesh=mesh,
                      checkpoint_root=str(tmp_path))
    cloud.submit_training(jid=1, arch="smollm_135m", chips=6, steps=20)
    assert 1 in cloud.pbj.running
    cloud.run_quantum(steps=5)          # make some progress
    payload = cloud._live[1].payload
    assert payload.step >= 5
    # WS spike to 5 chips: 8 - 5 < 6 → job must be preempted.
    cloud.preempt_for_ws(5)
    assert 1 not in cloud.pbj.running
    assert cloud.service.cluster.allocated("WS") == 5
    # The preempt hook really checkpointed: state is on disk.
    ckpt_files = list((tmp_path / "job1").rglob("*"))
    assert ckpt_files, "preempt did not write a checkpoint"
    step_at_preempt = payload.step
    # Spike recedes; next lease tick re-provisions idle chips to PBJ.
    cloud.set_ws_demand(1)
    cloud.lease_tick()
    assert 1 in cloud.pbj.running
    finished = []
    for _ in range(6):
        finished = cloud.run_quantum(steps=5)
        if finished:
            break
    assert finished == [1]
    assert payload.step == 20
    assert payload.step >= step_at_preempt   # no lost progress
