"""End-to-end behaviour test for the paper's system.

Walks the full PhoenixCloud story in one scenario: RE specifications →
lifecycle (create/deploy/activate, partner matching) → coordinated
FB provisioning against the consolidated iPSC+WorldCup workload →
the paper's headline metrics, all in one process.
"""

import pytest

pytestmark = pytest.mark.tier1

import numpy as np

from repro.core.lifecycle import LifecycleManagementService, TREState
from repro.core.pbj_manager import PBJManager
from repro.core.spec import (CoordinationModel, Granularity, Relationship,
                             ResourceBounds, RuntimeEnvironmentSpec,
                             SetupPolicy, WorkloadType)
from repro.core.provision import FBProvisionService
from repro.core.ws_manager import WSManager
from repro.sim import traces
from repro.sim.engine import build_dcs, clone_jobs, run_sim


def test_full_consolidation_story():
    # --- 1. Service providers express RE requirements (paper Fig. 3).
    pbj_spec = RuntimeEnvironmentSpec(
        name="dept_batch", relationship=Relationship.AFFILIATED,
        workload=WorkloadType.PARALLEL_BATCH_JOBS,
        granularity=Granularity.NODE, coordination=CoordinationModel.FB,
        bounds=ResourceBounds(150, 150), setup_policy=SetupPolicy.WIPE)
    ws_spec = RuntimeEnvironmentSpec(
        name="dept_web", relationship=Relationship.AFFILIATED,
        workload=WorkloadType.WEB_SERVICE,
        granularity=Granularity.NODE, coordination=CoordinationModel.FB,
        bounds=ResourceBounds(0, 0))
    for s in (pbj_spec, ws_spec):
        s.validate()
        # XML round-trip (the paper's interchange format).
        assert RuntimeEnvironmentSpec.from_xml(s.to_xml()) == s

    # --- 2. Lifecycle: create both TREs; the CSF pairs them.
    lifecycle = LifecycleManagementService()
    lifecycle.create(pbj_spec)
    tre_ws = lifecycle.create(ws_spec)
    assert tre_ws.partner == "dept_batch"
    pbj, ws = PBJManager(), WSManager()
    lifecycle.activate("dept_batch", pbj)
    lifecycle.activate("dept_web", ws)
    assert lifecycle.tre("dept_batch").state is TREState.RUNNING

    # --- 3. Coordinated FB provisioning on the consolidated workload.
    T = traces.TWO_WEEKS
    jobs = traces.nasa_ipsc(seed=1)
    ws_trace = traces.worldcup98(seed=1, peak_vms=128)
    svc = FBProvisionService(150, pbj, ws, lease_seconds=3600)
    fb = run_sim(svc, clone_jobs(jobs), ws_trace, T, name="PhoenixCloud-FB")

    # --- 4. Baseline: two dedicated clusters.
    dcs = run_sim(build_dcs(128, 128), clone_jobs(jobs), ws_trace, T,
                  name="DCS")

    # --- 5. The paper's claims, end to end: ~40 % smaller site (150 vs
    # 256), throughput parity, WS never starved, bounded mgmt overhead.
    assert fb.peak_nodes <= 150
    assert fb.completed_jobs >= 0.97 * dcs.completed_jobs
    assert svc.cluster.allocated("WS") == min(ws.demand, 150)
    assert fb.adjust_events > 0
    saving = 1 - 150 / 256
    assert saving > 0.4
