"""Multi-device sweep backend: sharded vs single-device equality.

The ``devices`` option of ``run_sweep_workloads`` splits the scan path's
flattened (point × trace) lane axis across host devices via
``shard_map`` (repro.sim.scan). Because every lane runs the identical
per-lane program, the sharded backend must reproduce the single-device
rows BIT-IDENTICALLY — including when the lane count is not divisible by
the device count, which exercises the pad-and-drop path. The equality
test runs in a subprocess with two forced XLA host devices (the
test_distributed.py pattern), so it holds regardless of the machine CI
lands on.
"""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.tier1

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Every subprocess FIRST asserts the device count it was forced to —
# the resolved count, through the same repro.compat.resolve_devices the
# sweep backends use. If the XLA flag is ignored (a jax upgrade, a
# conflicting XLA_FLAGS from the outer environment, a platform that
# pins one device) the test FAILS with the resolution error instead of
# silently exercising the single-device path and reporting green.
_DEVICE_PREAMBLE = """
    import jax
    from repro.compat import resolve_devices
    devs = resolve_devices(2)
    assert devs is not None and len(devs) == 2, (
        "forced host device count not honored: resolved %r from %r"
        % (devs, jax.devices()))
    assert len(jax.devices()) == 2, jax.devices()
"""


def _run2(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c",
         textwrap.dedent(_DEVICE_PREAMBLE) + textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
@pytest.mark.sharded_subprocess
def test_forced_device_count_is_asserted_inside_the_subprocess():
    """The skip-surface fix: a subprocess whose device resolution falls
    back to 1 must FAIL (returncode != 0 with the resolution message),
    never skip — exercised by running the same preamble WITHOUT the
    XLA flag."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)          # no forced devices
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_DEVICE_PREAMBLE)],
        capture_output=True, text=True, env=env, timeout=420)
    if out.returncode == 0:             # multi-device host: flag moot
        import jax
        assert len(jax.devices()) >= 2
    else:
        assert ("xla_force_host_platform_device_count" in out.stderr
                or "not honored" in out.stderr), out.stderr[-2000:]


@pytest.mark.slow
@pytest.mark.sharded_subprocess
def test_sharded_matches_single_device_on_odd_lane_count():
    """3 workloads × 3 points per policy = 9 lanes — NOT divisible by 2
    devices, so both policies pad one lane and must drop it from the
    reported rows."""
    out = _run2("""
        from repro.sim import traces
        from repro.sim.sweep import SweepPoint, run_sweep_workloads

        T = 2 * 24 * 3600.0
        def cut(jobs):
            return [j for j in jobs if j.submit < T]
        def cutws(ws):
            return [(t, d) for t, d in ws if t < T]
        wls = [(cut(traces.nasa_ipsc(seed=3)),
                cutws(traces.worldcup98(seed=3, peak_vms=64))),
               (cut(traces.sdsc_blue(seed=3)),
                cutws(traces.worldcup98(seed=4, peak_vms=64))),
               (cut(traces.nasa_ipsc(seed=5)),
                cutws(traces.worldcup98(seed=5, peak_vms=64)))]
        pts = ([SweepPoint("fb", capacity=c) for c in (96, 128, 160)]
               + [SweepPoint("flb_nub", lb_pbj=B - 12, lb_ws=12)
                  for B in (25, 51, 102)]
               + [SweepPoint("ec2", lease_seconds=3600.0)])
        single = run_sweep_workloads(pts, wls, T, mode="scan")
        sharded = run_sweep_workloads(pts, wls, T, mode="scan", devices=2)
        assert sharded == single, [
            (w, i, a, b)
            for w, (ra, rb) in enumerate(zip(single, sharded))
            for i, (a, b) in enumerate(zip(ra, rb)) if a != b][:3]
        # The scan rows really took the scan engine on both backends.
        assert all(r["engine"] == "scan" for row in sharded
                   for r in row[:-1])
        # Same bit-identity contract for the event-round engine (its
        # per-workload invocations shard their 3 point-lanes over the
        # 2 devices - the odd-lane pad-and-drop path again).
        single_r = run_sweep_workloads(pts, wls, T, mode="rounds")
        sharded_r = run_sweep_workloads(pts, wls, T, mode="rounds",
                                        devices=2)
        assert sharded_r == single_r, [
            (w, i, a, b)
            for w, (ra, rb) in enumerate(zip(single_r, sharded_r))
            for i, (a, b) in enumerate(zip(ra, rb)) if a != b][:3]
        assert all(r["engine"] == "rounds" for row in sharded_r
                   for r in row[:-1])
        # ...and for the contended-stretch COALESCED variant: its bulk
        # section adds (K, k) intermediates to the per-lane program,
        # which must shard exactly like the plain one (this is the only
        # place the coalesce x shard_map combination is exercised — the
        # bench gate's sharded leg covers plain rounds only).
        from repro.sim.sweep import ScanOptions
        co = ScanOptions(coalesce=8)
        single_c = run_sweep_workloads(pts, wls, T, mode="rounds",
                                       scan_options=co)
        sharded_c = run_sweep_workloads(pts, wls, T, mode="rounds",
                                        scan_options=co, devices=2)
        assert sharded_c == single_c, [
            (w, i, a, b)
            for w, (ra, rb) in enumerate(zip(single_c, sharded_c))
            for i, (a, b) in enumerate(zip(ra, rb)) if a != b][:3]
        assert sum(r.get("coalesced", 0) for row in single_c
                   for r in row) > 0
        print("OK")
    """)
    assert "OK" in out


def test_devices_request_beyond_visible_raises():
    """Asking for more devices than jax sees must fail with a message
    that names the XLA flag, not silently fall back to one device."""
    import jax
    import pytest
    from repro.sim import traces
    from repro.sim.sweep import SweepPoint, run_sweep

    T = 12 * 3600.0
    jobs = [j for j in traces.nasa_ipsc(seed=3) if j.submit < T]
    ws = [(t, d) for t, d in traces.worldcup98(seed=3, peak_vms=64)
          if t < T]
    too_many = len(jax.devices()) + 1
    with pytest.raises(ValueError,
                       match="xla_force_host_platform_device_count"):
        run_sweep([SweepPoint("fb", capacity=64)], jobs, ws, T,
                  mode="scan", devices=too_many)


def test_devices_one_is_the_plain_single_device_path():
    """devices=1 collapses to the unsharded backend (resolve_devices
    returns None) — results are the plain path's, trivially
    bit-identical to not passing devices at all."""
    from repro.compat import resolve_devices
    from repro.sim import traces
    from repro.sim.sweep import SweepPoint, run_sweep

    import pytest

    assert resolve_devices(None) is None
    assert resolve_devices(1) is None
    with pytest.raises(ValueError, match="devices must be >= 1"):
        resolve_devices(0)
    with pytest.raises(ValueError, match="devices must be >= 1"):
        resolve_devices(-1)

    T = 12 * 3600.0
    jobs = [j for j in traces.nasa_ipsc(seed=3) if j.submit < T]
    ws = [(t, d) for t, d in traces.worldcup98(seed=3, peak_vms=64)
          if t < T]
    pts = [SweepPoint("fb", capacity=64),
           SweepPoint("flb_nub", lb_pbj=13, lb_ws=12)]
    assert run_sweep(pts, jobs, ws, T, mode="scan", devices=1) \
        == run_sweep(pts, jobs, ws, T, mode="scan")
