"""Live-vs-sim differential harness: the tentpole's proof obligation.

The live serving stack (``LiveCloud`` + ``AutoscaledService`` +
``VirtualReplica`` replay) and the reference simulator now share ONE
event core (``repro.sim.pump``). This file pins that claim from three
angles:

* **bit-identity** — the pump-based ``run_sim`` reproduces the legacy
  inline event loop exactly (per-job completion times included), so the
  refactor cannot have moved any published number;
* **ledger identity** — driving one trace through ``LiveCloud`` (the
  bridge path, virtual-job tier) and through ``run_sim`` (the simulator
  path) writes the SAME decision ledger, entry for entry;
* **live differential** — replaying a trace as request traffic through
  the real autoscaler (``repro.serving.replay``) stays inside
  ``CONTRACTS["live"]`` versus the simulator on a paper-trace pair and
  a synthesized ``synth_ws`` lane — the same table the CI bench gate
  (``benchmarks/run.py live --check-contract``) imports.
"""

import heapq
import itertools
import random

import pytest

from repro.core.jobs import Job
from repro.core.pbj_manager import PBJPolicyParams
from repro.sim import scenarios as sc
from repro.sim.contracts import CONTRACTS, LIVE_CONTRACT, demand_drift
from repro.sim.engine import (build_fb, build_flb_nub, clone_jobs,
                              run_sim)
from repro.sim.pump import DecisionLedger
from repro.sim.traces import nasa_ipsc, worldcup98

pytestmark = pytest.mark.tier1

DAY = 24 * 3600.0
CKPT = PBJPolicyParams(checkpoint_preempt=True)


# ------------------------------------------------------------ workloads

def random_workload(seed, n_jobs=24, horizon=16 * 3600.0):
    rng = random.Random(seed)
    jobs = [Job(i, rng.uniform(0.0, horizon),
                size=2 ** rng.randrange(0, 3),
                runtime=rng.uniform(600.0, 2.5 * 3600.0))
            for i in range(n_jobs)]
    ws = [(k * 1800.0, rng.randrange(0, 7)) for k in range(12)]
    return jobs, ws


def paper_pair(capacity=16, duration=DAY):
    """A tiny cut of the paper's workloads: NASA iPSC jobs rescaled to
    the test capacity, World Cup demand rescaled to peak 8."""
    jobs = [Job(jid=i, submit=j.submit, size=min(j.size, capacity // 2),
                runtime=j.runtime)
            for i, j in enumerate(j for j in nasa_ipsc(seed=0)
                                  if j.submit < duration * 0.6)][:40]
    ws = worldcup98(seed=0, peak_vms=8, duration=duration)
    return jobs, ws


# --------------------------------------------------- pump bit-identity

def legacy_run_sim(system, jobs, ws_trace, duration, lease_seconds):
    """The pre-pump inline event loop, verbatim semantics: one heap,
    (t, kind, seq) ordering with WS < TICK < SUBMIT < FINISH, t<=0 WS
    entries collapsed into startup. The pump must reproduce this
    bit-for-bit."""
    _WS, _TICK, _SUBMIT, _FINISH = 0, 1, 2, 3
    seq = itertools.count()
    heap = []

    def push(t, kind, payload=None):
        if t <= duration + 1e-9:
            heapq.heappush(heap, (t, kind, next(seq), payload))

    def push_starts(starts):
        for s in starts:
            push(s.end_time, _FINISH, (s.job.jid, s.epoch))

    for job in jobs:
        push(job.submit, _SUBMIT, job)
    ws_initial = 0
    for t, d in sorted(ws_trace, key=lambda e: e[0]):
        if t <= 0:
            ws_initial = int(d)
        else:
            push(t, _WS, d)
    k = 1
    while k * lease_seconds <= duration:
        push(k * lease_seconds, _TICK, None)
        k += 1
    push_starts(system.startup(0.0, ws_initial=ws_initial))
    while heap:
        t, kind, _, payload = heapq.heappop(heap)
        if t > duration + 1e-9:
            break
        if kind == _SUBMIT:
            push_starts(system.submit(t, payload))
        elif kind == _FINISH:
            jid, epoch = payload
            push_starts(system.on_finish(t, jid, epoch))
        elif kind == _WS:
            push_starts(system.on_ws_demand(t, int(payload)))
        elif kind == _TICK:
            push_starts(system.on_lease_tick(t))
    system.cluster.finalize(duration)


def fingerprint(system, jobs, duration):
    done = sorted((j.jid, j.end) for j in jobs if j.completed)
    return (done, system.cluster.node_hours, system.cluster.peak,
            system.cluster.adjust_events(), system.pbj.kill_count)


@pytest.mark.parametrize("builder", [
    lambda: build_fb(16, lease_seconds=3600.0),
    lambda: build_fb(24, lease_seconds=1800.0),
    lambda: build_flb_nub(6, 4, lease_seconds=3600.0),
])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_run_sim_bit_identical_to_legacy_loop(builder, seed):
    jobs, ws = random_workload(seed)
    legacy_jobs = clone_jobs(jobs)
    legacy_sys = builder()
    legacy_run_sim(legacy_sys, legacy_jobs, ws, DAY, 3600.0
                   if legacy_sys.lease_seconds == 3600.0
                   else legacy_sys.lease_seconds)
    pump_jobs = clone_jobs(jobs)
    pump_sys = builder()
    run_sim(pump_sys, pump_jobs, ws, duration=DAY)
    assert fingerprint(pump_sys, pump_jobs, DAY) == \
        fingerprint(legacy_sys, legacy_jobs, DAY)


# ------------------------------------------------------- ledger schema

def test_ledger_is_deterministic_and_well_formed():
    jobs, ws = random_workload(7)
    ledgers = []
    for _ in range(2):
        led = DecisionLedger()
        run_sim(build_fb(16), clone_jobs(jobs), ws, duration=DAY,
                ledger=led)
        ledgers.append(led)
    assert ledgers[0].entries == ledgers[1].entries
    entries = ledgers[0].entries
    assert entries[0].kind == "startup" and entries[0].t == 0.0
    kinds = {"startup", "ws", "tick", "submit", "finish"}
    last_t = 0.0
    for e in entries:
        assert e.kind in kinds
        assert e.t >= last_t                  # the one shared clock
        assert 0 <= e.total_nodes <= 16       # FB capacity bound
        assert e.pbj_nodes + e.ws_nodes == e.total_nodes
        last_t = e.t
    assert sum(e.killed for e in entries) == ledgers[0].kills()


def test_bridge_and_simulator_write_identical_ledgers():
    """The virtual-job tier of LiveCloud IS the simulator: one trace
    pushed through either path must yield the same ledger entries —
    same times, same grants, same kills, same node counts."""
    from repro.core.runtime_bridge import LiveCloud

    jobs, ws = random_workload(3)
    sim_ledger = DecisionLedger()
    run_sim(build_fb(16, params=CKPT), clone_jobs(jobs), ws,
            duration=DAY, ledger=sim_ledger)

    ws_sorted = sorted(ws, key=lambda e: e[0])
    d0 = max((int(d) for t, d in ws_sorted if t <= 0), default=0)
    cloud = LiveCloud(capacity=16, lease_seconds=3600.0, duration=DAY,
                      ws_initial=d0)
    cloud.load_trace(clone_jobs(jobs), ws_trace=ws, lease_ticks=True)
    cloud.run_until(DAY)
    assert cloud.ledger.entries == sim_ledger.entries


# -------------------------------------------------- live differential

def run_pair(jobs, ws, capacity, duration, lease=3600.0):
    from repro.serving.replay import replay

    ref_led = DecisionLedger()
    ref = run_sim(build_fb(capacity, lease_seconds=lease, params=CKPT),
                  clone_jobs(jobs), ws, duration=duration, name="event",
                  ledger=ref_led)
    res = replay(clone_jobs(jobs), ws, capacity, lease_seconds=lease,
                 duration=duration)
    violations = LIVE_CONTRACT.check_live(
        res.row.row(), ref.row(), res.derived_demand, res.trace_demand,
        duration)
    return ref, res, violations


def test_live_vs_sim_paper_pair_within_contract():
    jobs, ws = paper_pair()
    ref, res, violations = run_pair(jobs, ws, capacity=16, duration=DAY)
    assert violations == [], violations
    assert res.row.completed_jobs == ref.completed_jobs
    assert res.requests_completed > 0      # traffic actually flowed
    assert CONTRACTS["live"] is LIVE_CONTRACT   # bench gate reads this


def test_live_vs_sim_synth_lane_within_contract():
    grid = sc.ScenarioGrid(
        seeds=(5,),
        pbj=sc.PBJParams(nodes=16.0, utilization=0.45, n_jobs=30.0),
        ws=sc.WSParams(peak=8.0, base_mean=3.0),
        duration=DAY, max_jobs=60, ws_step=900.0)
    (jobs, ws), = sc.sample_workloads(sc.synthesize(grid), [0])
    _, res, violations = run_pair(jobs, ws, capacity=16, duration=DAY)
    assert violations == [], violations
    assert res.requests_completed > 0


def test_autoscaler_rederives_demand_steps():
    """The §6.4 loop tracks a step trace from traffic alone: after a
    demand step, the derived curve reaches the new level within a few
    sampling windows, and overall drift stays well inside the band."""
    from repro.serving.replay import replay

    ws = [(0.0, 2), (3600.0, 6), (10800.0, 2)]
    res = replay([], ws, capacity=16, duration=6 * 3600.0)

    def value_at(series, t):
        v = 0
        for bt, bv in series:
            if bt <= t:
                v = bv
        return v

    # Within 10 serve ticks of each step the derived level is there.
    assert value_at(res.derived_demand, 3600.0 + 300.0) == 6
    assert value_at(res.derived_demand, 10800.0 + 300.0) <= 3
    mae, peak = demand_drift(res.derived_demand, res.trace_demand,
                             6 * 3600.0)
    assert mae <= LIVE_CONTRACT.demand_mae_rel
    assert peak <= LIVE_CONTRACT.demand_peak_rel
