"""Trace statistics + paper-claim integration tests (§6.5, §6.6)."""

import numpy as np
import pytest

pytestmark = pytest.mark.tier1

from repro.sim import traces
from repro.sim.engine import (build_dcs, build_ec2_rightscale, build_fb,
                              build_flb_nub, clone_jobs, run_sim)

T = traces.TWO_WEEKS


@pytest.fixture(scope="module")
def ipsc():
    return traces.nasa_ipsc(seed=0)


@pytest.fixture(scope="module")
def ws128():
    return traces.worldcup98(seed=0, peak_vms=128)


def test_ipsc_moments(ipsc):
    util = sum(j.size * j.runtime for j in ipsc) / (128 * T)
    assert util == pytest.approx(0.466, abs=1e-3)       # exact by design
    assert len(ipsc) == 2603
    mean_rt = np.mean([j.runtime for j in ipsc])
    assert 400 < mean_rt < 700                          # ~573 target
    assert max(j.size for j in ipsc) == 128
    assert all(j.submit < T for j in ipsc)


def test_blue_moments():
    jobs = traces.sdsc_blue(seed=0)
    util = sum(j.size * j.runtime for j in jobs) / (144 * T)
    assert util == pytest.approx(0.762, abs=1e-3)
    assert len(jobs) == 2657
    mean_rt = np.mean([j.runtime for j in jobs])
    assert 1500 < mean_rt < 2500                        # ~1975 target


def test_worldcup_shape(ws128):
    demands = [d for _, d in ws128]
    assert max(demands) == 128                          # exact peak
    assert min(demands) >= 1
    assert np.mean(demands) < 50                        # high peak/normal


def test_trace_determinism():
    a = traces.nasa_ipsc(seed=3)
    b = traces.nasa_ipsc(seed=3)
    assert all(x.submit == y.submit and x.size == y.size
               and x.runtime == y.runtime for x, y in zip(a, b))


def test_scaling(ipsc):
    half = traces.scale_jobs(ipsc, prc=64, prc0=128)
    assert max(j.size for j in half) == 64


@pytest.mark.parametrize("prc", [96, 128, 144, 200, 640])
def test_scale_ws_upscale_round_trips_exactly(prc):
    """scale_ws(scale_ws(tr, prc), 64, prc0=prc) == tr for prc >= 64 —
    the exact-rational rounding in _scale_count guarantees it (the old
    float ``int(round(d * prc / prc0))`` drifted when the product landed
    within an ulp of a half-integer and rounded the wrong way)."""
    tr = traces.worldcup98(seed=5)
    up = traces.scale_ws(tr, prc, prc0=64)
    assert max(d for _, d in up) == traces._scale_count(64, prc, 64)
    assert traces.scale_ws(up, 64, prc0=prc) == tr


@pytest.mark.parametrize("prc", [144, 192, 256, 333, 640])
def test_scale_jobs_upscale_round_trips_exactly(prc, ipsc):
    up = traces.scale_jobs(ipsc, prc=prc, prc0=128)
    back = traces.scale_jobs(up, prc=128, prc0=prc)
    assert [j.size for j in back] == [j.size for j in ipsc]


# --------------------------------------------------- paper claims (scaled)

def test_fb_claim_40pct_smaller_cluster(ipsc, ws128):
    """§6.5.3: at ~60 % of the DCS configuration size, throughput matches
    DCS (the '40 % saving at same throughput' headline)."""
    dcs = run_sim(build_dcs(128, 128), clone_jobs(ipsc), ws128, T)
    fb = run_sim(build_fb(int(256 * 0.6)), clone_jobs(ipsc), ws128, T)
    assert fb.completed_jobs >= 0.97 * dcs.completed_jobs
    assert fb.peak_nodes <= int(256 * 0.6)


def test_fb_small_config_starves_only_big_jobs(ipsc, ws128):
    """PhoenixCloud(128) on (128,128): only the full-machine jobs fail
    (the paper's Table 1 shows 2549/2603)."""
    fb = run_sim(build_fb(128), clone_jobs(ipsc), ws128, T)
    n_full = sum(1 for j in ipsc if j.size == 128)
    assert fb.completed_jobs >= len(ipsc) - n_full - 60


def test_flb_nub_beats_ec2_on_consumption(ipsc, ws128):
    """§6.6.3: PhoenixCloud total and peak resource consumption are below
    EC2+RightScale; EC2 has zero queueing (turnaround == execution)."""
    pc = run_sim(build_flb_nub(13, 12), clone_jobs(ipsc), ws128, T)
    ec2 = run_sim(build_ec2_rightscale(), clone_jobs(ipsc), ws128, T)
    assert pc.node_hours < ec2.node_hours
    assert pc.peak_nodes < 0.75 * ec2.peak_nodes
    assert ec2.avg_turnaround == pytest.approx(ec2.avg_execution)
    assert pc.avg_turnaround >= ec2.avg_turnaround      # the paper's cost
    # Management overhead: EC2 users adjust per-job; PhoenixCloud batches.
    assert pc.adjust_events < ec2.adjust_events


def test_lease_unit_vs_overhead(ipsc, ws128):
    """Fig. 18: management overhead is inversely proportional to L."""
    short = run_sim(build_flb_nub(13, 12, lease_seconds=900),
                    clone_jobs(ipsc), ws128, T)
    long_ = run_sim(build_flb_nub(13, 12, lease_seconds=7200),
                    clone_jobs(ipsc), ws128, T)
    assert short.adjust_events > long_.adjust_events


def test_checkpoint_preempt_beats_kill(ipsc, ws128):
    """Beyond-paper: checkpoint-preempt cuts lost work vs the paper's
    kill-restart under the FB policy (same trace, same capacity)."""
    from repro.core.pbj_manager import PBJPolicyParams
    kill = run_sim(build_fb(150), clone_jobs(ipsc), ws128, T)
    ckpt = run_sim(build_fb(150, params=PBJPolicyParams(
        checkpoint_preempt=True)), clone_jobs(ipsc), ws128, T)
    assert ckpt.completed_jobs >= kill.completed_jobs
    if kill.kills:
        assert ckpt.avg_turnaround <= kill.avg_turnaround * 1.05
