"""Property-based tests (hypothesis) for the provisioning invariants.

System invariants that must hold for ANY event sequence:
  I1 (conservation)  allocations never exceed capacity; never negative.
  I2 (WS priority)   after any FB event, WS holds exactly min(demand, C).
  I3 (rigid bound)   FLB-NUB: PBJ never drops below... pool B is always
                     held; PBJ owned ≥ 0; ledger internally consistent.
  I4 (no lost jobs)  every submitted job is exactly one of: queued,
                     running, or completed.
  I5 (accounting)    node-hours integral is non-negative and peak ≥ any
                     instantaneous allocation seen.
"""

import math

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.jobs import Job
from repro.core.pbj_manager import PBJManager, PBJPolicyParams
from repro.core.provision import FBProvisionService, FLBNUBProvisionService
from repro.core.ws_manager import WSManager

# One event: (kind, value) where kind ∈ submit/ws/tick/finish.
event = st.one_of(
    st.tuples(st.just("submit"),
              st.tuples(st.integers(1, 40), st.floats(1, 5000))),
    st.tuples(st.just("ws"), st.integers(0, 120)),
    st.tuples(st.just("tick"), st.none()),
    st.tuples(st.just("finish"), st.none()),
)


def _drive(svc, events, capacity=None):
    pbj = svc.pbj
    t = 0.0
    jid = 0
    submitted = []
    pending_end = {}   # jid -> (end_time, epoch)

    def pump(starts):
        for s in starts:
            pending_end[s.job.jid] = (s.end_time, s.epoch)

    pump(svc.startup(0.0, ws_initial=0))
    for kind, val in events:
        t += 60.0
        if kind == "submit":
            size, rt = val
            if capacity is not None:
                size = min(size, capacity)
            j = Job(jid, t, size, float(rt))
            submitted.append(j)
            jid += 1
            pump(pbj.submit(t, j))
        elif kind == "ws":
            pump(svc.on_ws_demand(t, val))
        elif kind == "tick":
            pump(svc.on_lease_tick(t))
        elif kind == "finish" and pending_end:
            k = min(pending_end, key=lambda q: pending_end[q][0])
            end, epoch = pending_end.pop(k)
            _, starts = pbj.on_finish(max(t, end), k, epoch)
            t = max(t, end)
            pump(starts)
        _check_core(svc, submitted, capacity)
    return submitted


def _check_core(svc, submitted, capacity):
    c = svc.cluster
    # I1: conservation.
    assert c.total_allocated >= 0
    if capacity is not None:
        assert c.total_allocated <= capacity
        assert c.idle >= 0
    # I4: no lost jobs.
    pbj = svc.pbj
    for j in submitted:
        in_q = any(q.jid == j.jid for q in pbj.queue)
        running = j.jid in pbj.running
        assert in_q + running + j.completed == 1, \
            f"job {j.jid}: queued={in_q} running={running} done={j.completed}"
    # PBJ internal consistency.
    assert pbj.free >= 0
    assert pbj.running.used() <= pbj.owned


@settings(max_examples=60, deadline=None)
@given(st.lists(event, min_size=1, max_size=60), st.integers(40, 150))
def test_fb_invariants(events, capacity):
    svc = FBProvisionService(capacity, PBJManager(), WSManager(),
                             lease_seconds=3600)
    _drive(svc, events, capacity=capacity)
    # I2: WS priority — WS allocation tracks (capped) demand exactly.
    assert svc.cluster.allocated("WS") == min(svc.ws.demand, capacity)
    svc.cluster.finalize(1e7)
    assert svc.cluster.node_hours >= 0
    assert svc.cluster.peak <= capacity


@settings(max_examples=60, deadline=None)
@given(st.lists(event, min_size=1, max_size=60),
       st.integers(1, 30), st.integers(1, 30))
def test_flb_nub_invariants(events, lb_pbj, lb_ws):
    svc = FLBNUBProvisionService(lb_pbj, lb_ws, PBJManager(), WSManager(),
                                 lease_seconds=3600)
    _drive(svc, events, capacity=None)
    # I3: the pool is held in full at all times.
    assert svc.cluster.allocated("POOL") == lb_pbj + lb_ws
    assert 0 <= svc._pool_ws <= lb_ws
    assert svc._pool_idle >= 0
    # WS always satisfied: pool share + leased == demand (or demand small).
    beyond = svc.cluster.allocated("WS")
    assert svc._pool_ws + beyond == svc.ws.demand


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 20), st.floats(1, 100)),
                min_size=1, max_size=30),
       st.integers(5, 50))
def test_first_fit_never_overcommits(jobs, owned):
    m = PBJManager(params=PBJPolicyParams())
    m.grant(0.0, owned)
    for i, (size, rt) in enumerate(jobs):
        m.submit(float(i), Job(i, float(i), size, rt))
        assert m.running.used() <= m.owned
        assert m.free >= 0
