"""Fault-tolerance substrate: checkpoint/restore/reshard, preempt/resume,
deterministic data, optimizer behaviour."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced_config
from repro.launch.mesh import make_local_mesh
from repro.train.checkpoint import Checkpointer
from repro.train.data import SyntheticLM, DataConfig
from repro.train.optimizer import adafactor, adamw, get_optimizer
from repro.train.trainer import TrainJob, TrainJobConfig


# --------------------------------------------------------------- checkpoint

def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": jnp.float32(3.5)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(7, tree, metadata={"step": 7, "note": "x"})
    assert ck.latest_step() == 7
    out, meta = ck.restore(7, jax.tree.map(jnp.zeros_like, tree))
    assert meta["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in range(5):
        ck.save_async(s, {"x": jnp.full((4,), s)})
    ck.wait()
    assert ck.all_steps() == [3, 4]      # GC kept the last two
    out, _ = ck.restore(4, {"x": jnp.zeros((4,))})
    np.testing.assert_array_equal(np.asarray(out["x"]), np.full((4,), 4.0))


def test_checkpoint_atomicity(tmp_path):
    """A stale .tmp dir never shadows a published checkpoint."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"x": jnp.ones(3)})
    os.makedirs(os.path.join(str(tmp_path), "step_2.tmp"))  # crashed write
    assert ck.latest_step() == 1


def test_checkpoint_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"x": jnp.ones((128,))})
    blob = os.path.join(str(tmp_path), "step_1", "leaf_0.npy")
    arr = np.load(blob)
    arr[0] = 99.0
    np.save(blob, arr)
    with pytest.raises(IOError, match="checksum"):
        ck.restore(1, {"x": jnp.zeros((128,))})


def test_checkpoint_reshard_on_restore(tmp_path):
    """Restore places leaves with the *target* sharding (elastic rescale)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = make_local_mesh()
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"w": jnp.arange(16.0).reshape(4, 4)})
    out, _ = ck.restore(1, {"w": jnp.zeros((4, 4))}, mesh=mesh,
                        specs={"w": P("data", "model")})
    assert isinstance(out["w"].sharding, NamedSharding)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.arange(16.0).reshape(4, 4))


# ------------------------------------------------------------------ trainer

def test_train_job_runs_and_loss_drops(tmp_path):
    cfg = reduced_config(get_config("smollm_135m"))
    job = TrainJob(cfg, TrainJobConfig(
        arch="smollm_135m", steps=25, batch=8, seq_len=32, lr=3e-3,
        checkpoint_dir=str(tmp_path), checkpoint_every=10),
        make_local_mesh())
    result = job.run()
    assert result["completed"]
    assert result["step"] == 25
    first = np.mean(job.history[:5])
    last = np.mean(job.history[-5:])
    assert last < first, f"loss did not drop: {first} -> {last}"


def test_preempt_checkpoint_resume(tmp_path):
    """The PhoenixCloud FB kill becomes checkpoint-preempt: a preempted
    job resumes from its checkpoint with the step counter intact."""
    cfg = reduced_config(get_config("smollm_135m"))
    jc = TrainJobConfig(arch="smollm_135m", steps=20, batch=4, seq_len=32,
                        checkpoint_dir=str(tmp_path), checkpoint_every=5)
    job = TrainJob(cfg, jc, make_local_mesh())
    job.initialize()
    job.jc = TrainJobConfig(**{**jc.__dict__, "steps": 8})
    job.run()                      # run to step 8, checkpoints at 5 + final
    job.checkpoint(block=True)
    assert job.step == 8
    # "Node failure": a brand-new process picks the job up.
    job2 = TrainJob(cfg, jc, make_local_mesh())
    job2.initialize()
    assert job2.step == 8          # resumed, not restarted
    result = job2.run()
    assert result["completed"] and job2.step == 20


# --------------------------------------------------------------------- data

def test_data_determinism_and_shift():
    cfg = reduced_config(get_config("smollm_135m"))
    a = SyntheticLM(cfg, batch=4, seq_len=16, data_cfg=DataConfig(seed=1))
    b = SyntheticLM(cfg, batch=4, seq_len=16, data_cfg=DataConfig(seed=1))
    ba, bb = a.batch_at(42), b.batch_at(42)
    np.testing.assert_array_equal(ba["tokens"], bb["tokens"])
    np.testing.assert_array_equal(ba["labels"], bb["labels"])
    assert not np.array_equal(ba["tokens"], a.batch_at(43)["tokens"])
    assert ba["tokens"].max() < cfg.vocab


# --------------------------------------------------------------- optimizers

def _quadratic_losses(opt, steps=60):
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,)), "m": jnp.zeros((2, 3))}

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2) + jnp.sum(p["m"] ** 2)

    state = opt.init(params)
    losses = []
    for _ in range(steps):
        g = jax.grad(loss_fn)(params)
        params, state = opt.update(g, state, params, jnp.float32(0.1))
        losses.append(float(loss_fn(params)))
    return losses


def test_adamw_converges():
    losses = _quadratic_losses(adamw(weight_decay=0.0))
    assert losses[-1] < 0.05 * losses[0]


def test_adafactor_converges_and_is_factored():
    opt = adafactor()
    losses = _quadratic_losses(opt)
    assert losses[-1] < 0.2 * losses[0]
    state = opt.init({"m": jnp.zeros((8, 16))})
    assert state["v"]["m"]["vr"].shape == (8,)
    assert state["v"]["m"]["vc"].shape == (16,)


def test_optimizer_state_specs_match_structure():
    from jax.sharding import PartitionSpec as P
    opt = get_optimizer("adamw")
    params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    pspecs = {"w": P("data", "model"), "b": P(None)}
    sspecs = opt.state_specs(pspecs)
    state = opt.init(params)
    # Every state leaf has a spec leaf at the same path.
    jax.tree.map(lambda *_: None, state,
                 {"mu": params, "nu": params, "count": jnp.int32(0)})
    assert sspecs["mu"]["w"] == P("data", "model")


def test_worker_failure_is_loss_equivalent(tmp_path):
    """Node-failure equivalence: worker A dies mid-run after its last
    checkpoint; replacement worker B restores and replays the SAME
    batches (step-indexed deterministic data) — the final loss history
    from the checkpoint onward is identical to an uninterrupted run.
    This is the straggler/failure-reassignment guarantee of DESIGN.md §5."""
    cfg = reduced_config(get_config("smollm_135m"))
    mk = lambda d, steps: TrainJobConfig(
        arch="smollm_135m", steps=steps, batch=4, seq_len=32, lr=1e-3,
        checkpoint_dir=d, checkpoint_every=10)
    # Uninterrupted reference run.
    ref = TrainJob(cfg, mk(str(tmp_path / "ref"), 20), make_local_mesh())
    ref.run()
    # Worker A: runs to step 13 (checkpointed at 10), then "dies" —
    # steps 11-13 are lost work (a hard crash never writes a final
    # checkpoint, so drop anything newer than step 10).
    import shutil
    a = TrainJob(cfg, mk(str(tmp_path / "ha"), 20), make_local_mesh())
    a.jc = TrainJobConfig(**{**a.jc.__dict__, "steps": 13})
    a.run()
    for s in a.ckpt.all_steps():
        if s > 10:
            shutil.rmtree(str(tmp_path / "ha" / f"step_{s}"))
    del a
    # Worker B: fresh process, restores at 10, finishes the job.
    b = TrainJob(cfg, mk(str(tmp_path / "ha"), 20), make_local_mesh())
    result = b.run()
    assert result["completed"] and b.step == 20
    # Loss histories match exactly from the restore point onward.
    np.testing.assert_allclose(b.history, ref.history[10:20], rtol=1e-5)
