"""Pallas kernel validation (deliverable c): shape/dtype sweeps in
interpret mode against the pure-jnp oracles in kernels/ref.py, plus
cross-checks of the model-internal implementations against the same
oracles, plus hypothesis property tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref
from repro.models.mamba2 import ssd_chunked

KEYS = jax.random.split(jax.random.PRNGKey(0), 8)


def _mk_qkv(b, s, h, kv, hd, dtype):
    q = jax.random.normal(KEYS[0], (b, s, h, hd), dtype)
    k = jax.random.normal(KEYS[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(KEYS[2], (b, s, kv, hd), dtype)
    return q, k, v


def _ref_model_layout(q, k, v, **kw):
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qf = q.reshape(b, s, kv, g, hd).transpose(0, 2, 3, 1, 4) \
        .reshape(b * h, s, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kv, s, hd)
    out = ref.flash_attention_ref(qf, kf, vf, **kw)
    return out.reshape(b, kv, g, s, hd).transpose(0, 3, 1, 2, 4) \
        .reshape(b, s, h, hd)


FLASH_CASES = [
    # (b, s, h, kv, hd, window, softcap, dtype, tol)
    (2, 256, 8, 4, 64, None, None, jnp.float32, 2e-5),
    (1, 128, 4, 4, 32, None, 50.0, jnp.float32, 2e-5),
    (2, 384, 6, 2, 64, 128, None, jnp.float32, 2e-5),
    (1, 512, 8, 1, 128, 256, 30.0, jnp.float32, 2e-5),
    (1, 256, 9, 3, 64, None, None, jnp.float32, 2e-5),   # smollm heads
    (2, 256, 8, 4, 64, None, None, jnp.bfloat16, 2e-2),
    (1, 320, 4, 2, 64, 64, 50.0, jnp.float32, 2e-5),     # ragged blocks
]


@pytest.mark.parametrize("b,s,h,kv,hd,window,cap,dtype,tol", FLASH_CASES)
def test_flash_attention_sweep(b, s, h, kv, hd, window, cap, dtype, tol):
    q, k, v = _mk_qkv(b, s, h, kv, hd, dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              softcap=cap, interpret=True)
    want = _ref_model_layout(q, k, v, causal=True, window=window,
                             softcap=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([64, 128, 192]),
       st.sampled_from([(4, 4), (4, 2), (8, 1)]),
       st.sampled_from([32, 64]))
def test_flash_attention_property(b, s, heads, hd):
    """Property: kernel == oracle for random GQA shapes; causal row 0
    attends only to itself (== v[0])."""
    h, kv = heads
    q, k, v = _mk_qkv(b, s, h, kv, hd, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, interpret=True,
                              block_q=64, block_k=64)
    want = _ref_model_layout(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)
    # Row 0 == value of kv head at position 0 (softmax over one entry).
    g = h // kv
    v0 = np.repeat(np.asarray(v[:, 0]), g, axis=1)
    np.testing.assert_allclose(np.asarray(out[:, 0]), v0, atol=3e-6)


SSD_CASES = [
    # (b, l, h, g, p, n, chunk, dtype, tol)
    (2, 256, 4, 1, 64, 32, 128, jnp.float32, 5e-5),
    (1, 128, 8, 2, 32, 16, 64, jnp.float32, 5e-5),
    (2, 512, 4, 1, 128, 64, 128, jnp.float32, 1e-4),
    (1, 256, 4, 1, 64, 32, 128, jnp.bfloat16, 3e-2),
]


def _mk_ssd(b, l, h, g, p, n, dtype):
    x = (0.5 * jax.random.normal(KEYS[3], (b, l, h, p))).astype(dtype)
    a = -jax.nn.softplus(jax.random.normal(KEYS[4], (b, l, h)))
    B = (0.3 * jax.random.normal(KEYS[5], (b, l, g, n))).astype(dtype)
    C = (0.3 * jax.random.normal(KEYS[6], (b, l, g, n))).astype(dtype)
    return x, a.astype(jnp.float32), B, C


def _ssd_ref_model_layout(x, a, B, C, s0=None):
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, 2).transpose(0, 2, 1, 3).reshape(b * h, l, n)
    Ch = jnp.repeat(C, rep, 2).transpose(0, 2, 1, 3).reshape(b * h, l, n)
    xf = x.transpose(0, 2, 1, 3).reshape(b * h, l, p)
    af = a.transpose(0, 2, 1).reshape(b * h, l)
    sf = None if s0 is None else s0.reshape(b * h, p, n)
    y, sT = ref.ssd_ref(xf, af, Bh, Ch, sf)
    return (y.reshape(b, h, l, p).transpose(0, 2, 1, 3),
            sT.reshape(b, h, p, n))


@pytest.mark.parametrize("b,l,h,g,p,n,chunk,dtype,tol", SSD_CASES)
def test_ssd_kernel_sweep(b, l, h, g, p, n, chunk, dtype, tol):
    x, a, B, C = _mk_ssd(b, l, h, g, p, n, dtype)
    y, sT = ops.ssd(x, a, B, C, chunk=chunk, interpret=True)
    yr, sr = _ssd_ref_model_layout(x, a, B, C)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sr, np.float32),
                               atol=tol, rtol=tol)


def test_ssd_kernel_with_initial_state():
    b, l, h, g, p, n = 1, 128, 4, 1, 32, 16
    x, a, B, C = _mk_ssd(b, l, h, g, p, n, jnp.float32)
    s0 = 0.3 * jax.random.normal(KEYS[7], (b, h, p, n))
    y, sT = ops.ssd(x, a, B, C, init_state=s0, chunk=64, interpret=True)
    yr, sr = _ssd_ref_model_layout(x, a, B, C, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-5,
                               rtol=5e-5)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sr), atol=5e-5,
                               rtol=5e-5)


def test_model_ssd_chunked_matches_oracle():
    """models.mamba2.ssd_chunked (the XLA path) against the same oracle."""
    b, l, h, g, p, n = 2, 256, 4, 1, 64, 32
    x, a, B, C = _mk_ssd(b, l, h, g, p, n, jnp.float32)
    ah = jnp.repeat(a, 1, axis=-1)   # (b, l, h) already per-head
    y, sT = ssd_chunked(x, ah, B, C, chunk=64)
    yr, sr = _ssd_ref_model_layout(x, ah, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-5,
                               rtol=5e-5)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sr), atol=5e-5,
                               rtol=5e-5)


def test_chunked_attention_matches_dense():
    """models.attention Q-chunked path == dense path (32k prefill rule)."""
    import repro.models.attention as A
    from repro.configs.base import get_config, reduced_config
    cfg = reduced_config(get_config("qwen1_5_0_5b"))
    b, s, h, kv, hd = 1, 4 * A.CHUNK_Q // 4, cfg.n_heads, cfg.n_kv_heads, 16
    # Use a small CHUNK_Q for the test.
    old_q, old_t = A.CHUNK_Q, A.CHUNK_THRESHOLD
    try:
        A.CHUNK_Q, A.CHUNK_THRESHOLD = 64, 128
        s = 512
        q, k, v = _mk_qkv(b, s, h, kv, hd, jnp.float32)
        dense = A._sdpa(q, k, v, cfg, A._causal_mask(s, s, 0, None))
        chunked = A._sdpa_qchunked(q, k, v, cfg, None, causal=True)
        np.testing.assert_allclose(np.asarray(chunked), np.asarray(dense),
                                   atol=2e-5, rtol=2e-5)
    finally:
        A.CHUNK_Q, A.CHUNK_THRESHOLD = old_q, old_t


def test_mamba_model_pallas_path_matches_xla():
    """models.mamba2 with impl='pallas' (SSD kernel, interpret) == XLA."""
    import jax
    from repro.configs.base import get_config, reduced_config
    from repro.launch.mesh import make_local_mesh
    from repro.models.transformer import Model
    cfg = reduced_config(get_config("mamba2_130m"))
    mesh = make_local_mesh()
    key = jax.random.PRNGKey(11)
    batch = {"tokens": jax.random.randint(key, (2, 128), 0, cfg.vocab),
             "labels": jax.random.randint(key, (2, 128), 0, cfg.vocab)}
    losses = []
    for impl in ("xla", "pallas"):
        m = Model(cfg, mesh, impl=impl, compute_dtype=jnp.float32)
        params = m.init(0)
        losses.append(float(m.loss(params, batch)))
    assert abs(losses[0] - losses[1]) < 1e-4, losses
