"""Cross-engine differential harness (in the spirit of the paper's
EC2+RightScale comparison methodology and the earlier PhoenixCloud
consolidation study, arXiv:0906.1346).

One shared scenario generator drives random PBJ/WS traces and sweep
points through ALL sweep engines — the per-point discrete-event
reference, the fixed-dt scan, the event-round engine, its
contended-stretch-coalesced variant and its fused Pallas round-step
backend (``kernel="pallas"``, bit-identical by contract) — and asserts
each engine's
fidelity contract from ``repro.sim.contracts`` (the same table the CI
bench gate imports, so the gate and these tests cannot drift apart).

Layout:

* seeded random differentials always run (the container has no
  mandatory hypothesis dependency);
* a hypothesis-driven differential runs when hypothesis is installed,
  reusing the identical checker;
* the coalescer regression pins the crafted all-contended trace: a
  whole batch of completions -> head-of-queue starts per round, event
  times bit-exact, round count within the ceil(completions / batch)
  bound;
* a unit test pins that the bench gate (`benchmarks.run
  .rounds_contract_ok`) actually reads the contract table.

Scenario shapes are FIXED per-axis (job count, WS change count,
horizon, windows) so every seed reuses one compiled program per engine
— the differential sweep stays minutes-cheap despite four engines.
"""

import math
import random

import numpy as np
import pytest

from repro.core.jobs import Job
from repro.sim.contracts import (CONTRACTS, FAULT_CONTRACT,
                                 LIVE_CONTRACT, ROUNDS_CONTRACT,
                                 SCAN_CONTRACT, check_fidelity)
from repro.sim.sweep import ScanOptions, SweepPoint, run_sweep

pytestmark = pytest.mark.tier1

DAY = 24 * 3600.0
N_JOBS = 36          # fixed -> shared RoundsSpec.max_rounds -> one compile
N_WS_STEPS = 24      # fixed -> shared pick_dt / budget across seeds
WINDOW = 48          # >= N_JOBS: no backlog can outgrow the lanes

POINTS = [SweepPoint("fb", capacity=16),
          SweepPoint("fb", capacity=24),
          SweepPoint("flb_nub", lb_pbj=6, lb_ws=4),
          SweepPoint("flb_nub", lb_pbj=13, lb_ws=12)]


def scenario(seed: int):
    """Random queue-provoking workload of a FIXED shape: bursty
    arrivals against small capacities, a stepping WS demand trace
    (rises included, so FB reclaims and kills are exercised)."""
    rng = random.Random(seed)
    jobs = [Job(i, rng.uniform(0.0, 16 * 3600.0),
                size=2 ** rng.randrange(0, 4),
                runtime=rng.uniform(600.0, 3 * 3600.0))
            for i in range(N_JOBS)]
    ws = [(k * 3600.0, rng.randrange(0, 9)) for k in range(N_WS_STEPS)]
    return jobs, ws


def run_engines(jobs, ws, coalesce=None):
    """The shared fixture core: one scenario through all the engines —
    the event reference, the scan, the event-round engine, its
    coalesced variant and its fused-Pallas-kernel backend. Returns
    ``{engine_name: rows}`` aligned with POINTS."""
    opts = ScanOptions(window=WINDOW)
    out = {
        "event": run_sweep(POINTS, jobs, ws, DAY, mode="event"),
        "scan": run_sweep(POINTS, jobs, ws, DAY, mode="scan",
                          scan_options=opts),
        "rounds": run_sweep(POINTS, jobs, ws, DAY, mode="rounds",
                            scan_options=opts),
        "rounds_coalesced": run_sweep(
            POINTS, jobs, ws, DAY, mode="rounds",
            scan_options=ScanOptions(window=WINDOW,
                                     coalesce=coalesce or 8)),
        "rounds_pallas": run_sweep(
            POINTS, jobs, ws, DAY, mode="rounds",
            scan_options=ScanOptions(window=WINDOW, kernel="pallas")),
    }
    return out


def assert_contracts(engines: dict, label) -> None:
    """Per-engine fidelity contracts against the event reference —
    the assertions AND the bench gate read repro.sim.contracts.

    One carve-out, inherited from tests/test_rounds.py: the FLB-NUB
    bands are paper-grid contracts (gated for real by the sweep
    benchmark's --check-fidelity on the Fig. 13/14/18 grids). On
    adversarial random microtraces — WS demand repeatedly crossing a
    tiny lb_ws — the U/V/G feedback's shared policy approximation can
    overshoot them in every fast engine identically, so the random
    differential holds FLB-NUB to DOUBLE each band (still a tight
    differential against real divergence) while FB stays at the full
    contract (its peak is exact by construction) and completed-job
    exactness stays absolute everywhere."""
    import dataclasses

    ev = engines["event"]
    for name in ("scan", "rounds", "rounds_coalesced", "rounds_pallas"):
        rows = engines[name]
        for r in rows:
            assert r["window_overflow"] == 0, (label, name, r["system"])
            assert r.get("truncated", 0) == 0, (label, name, r["system"])
        violations = []
        for r, e in zip(rows, ev):
            c = CONTRACTS[r["engine"]]
            if r["system"].startswith("FLB-NUB"):
                # Double the node-hours band; the FLB peak is checked
                # across the fast engines instead (below) — the event
                # comparison for it is a paper-grid contract only
                # (same carve-out as tests/test_rounds.py).
                c = dataclasses.replace(
                    c, node_hours_rel=2 * c.node_hours_rel,
                    peak_rel=float("inf"))
            if not c.completed_exact:
                # The scan's 2 % completed band is calibrated on the
                # ~2.6k-job paper traces; on an N_JOBS microtrace one
                # substep-displaced §5.1 kill cascade moves whole jobs,
                # so allow 3 jobs of slack there. The rounds family
                # keeps the absolute exactness promise regardless.
                c = dataclasses.replace(
                    c, completed_rel=max(
                        c.completed_rel,
                        3.0 / max(e["completed_jobs"], 1)))
            violations += [f"{r['system']}: {v}"
                           for v in c.check_row(r, e)]
        assert not violations, (label, name, violations)
        # The rounds family additionally promises exact completion
        # counts — assert the integer equality directly (not via the
        # drift machinery), for the plain AND coalesced variants.
        if name.startswith("rounds"):
            for r, e in zip(rows, ev):
                assert r["completed_jobs"] == e["completed_jobs"], (
                    label, name, r["system"])
    # The FLB peak residue is the POLICY approximation, shared by the
    # fast engines — they must agree with each other about it.
    for r_plain, r_coal in zip(engines["rounds"],
                               engines["rounds_coalesced"]):
        assert r_plain["peak_nodes"] == r_coal["peak_nodes"], (
            label, r_plain["system"])
    # The fused Pallas backend is not merely within-contract: it runs
    # the same _chunk_core math on a float-packed state, so its rows
    # must equal the unfused rounds rows BIT-FOR-BIT.
    assert engines["rounds_pallas"] == engines["rounds"], (
        label, [(i, a, b) for i, (a, b) in
                enumerate(zip(engines["rounds"],
                              engines["rounds_pallas"])) if a != b][:2])


@pytest.mark.parametrize("seed", range(4))
def test_differential_random_traces(seed):
    jobs, ws = scenario(seed)
    engines = run_engines(jobs, ws)
    assert_contracts(engines, seed)
    # Both rounds variants must agree with EACH OTHER on the job
    # counts exactly; turnaround can carry a small residue at the
    # default 2-pass first-fit — the plain engine may under-admit for
    # a round where the coalescer's instants are provably exact or
    # deferred — and collapses to 1e-9 agreement at ff_passes=8 in
    # float64 (test_differential_completion_times_bit_match_in_float64).
    for r_plain, r_coal in zip(engines["rounds"],
                               engines["rounds_coalesced"]):
        assert r_plain["completed_jobs"] == r_coal["completed_jobs"]
        assert r_plain["avg_turnaround"] == pytest.approx(
            r_coal["avg_turnaround"], rel=0.01)


def test_differential_completion_times_bit_match_in_float64():
    """The rounds engines' stronger promise: with float64 lanes and
    enough first-fit passes the completion *times* (through the
    turnaround/execution sums) match the event engine to round-off —
    for the coalesced variant too. The WS trace is flat: demand rises
    trigger §5.1 kills, whose size-class tie-breaking is the one
    documented divergence from the engine's latest-start order (the
    same precondition as tests/test_rounds.py's exactness property)."""
    from jax.experimental import enable_x64

    jobs, _ = scenario(97)
    ws = [(0.0, 3)]
    ev = run_sweep(POINTS, jobs, ws, DAY, mode="event")
    with enable_x64():
        for coalesce in (1, 8):
            rows = run_sweep(
                POINTS, jobs, ws, DAY, mode="rounds",
                scan_options=ScanOptions(window=WINDOW, ff_passes=8,
                                         coalesce=coalesce,
                                         dtype=np.float64))
            for r, e in zip(rows, ev):
                assert r["completed_jobs"] == e["completed_jobs"], (
                    coalesce, r["system"])
                assert r["avg_turnaround"] == pytest.approx(
                    e["avg_turnaround"], rel=1e-9), (coalesce,
                                                     r["system"])
                assert r["avg_execution"] == pytest.approx(
                    e["avg_execution"], rel=1e-9), (coalesce,
                                                    r["system"])


try:
    import hypothesis
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
    def test_differential_hypothesis(seed):
        """Hypothesis drives the same differential checker over the
        seeded generator (the scenario shape stays fixed, so every
        example reuses the compiled engines)."""
        jobs, ws = scenario(seed)
        assert_contracts(run_engines(jobs, ws), seed)


# ------------------------------------------------ coalescer regression

def crafted_all_contended():
    """The crafted all-contended trace of the coalescer regression:
    C nodes, >C equal unit jobs all submitted at t=0, flat WS, a lease
    longer than the horizon — the queue drains one GENERATION of C
    simultaneous completions at a time with no reactable WS change,
    submit or lease boundary in between."""
    C, gens, rt = 16, 6, 1000.0
    jobs = [Job(i, 0.0, size=1, runtime=rt) for i in range(C * gens)]
    ws = [(0.0, 0)]
    duration = gens * rt + 500.0
    point = SweepPoint("fb", capacity=C, lease_seconds=10 * duration)
    return jobs, ws, duration, point, C, gens, rt


def test_coalescer_all_contended_regression():
    """One coalesced round absorbs a whole batch of completions plus
    the head-of-queue starts they admit: per-job completion times
    reproduce the event engine bit-exactly (generation k completes at
    exactly k*rt) and the coalesced round count obeys the
    ceil(completions / batch) bound — strictly fewer rounds than the
    uncoalesced engine spends on the same drain."""
    from repro.sim.engine import build_fb, clone_jobs, run_sim

    jobs, ws, duration, point, C, gens, rt = crafted_all_contended()
    batch = 8
    ref = run_sim(build_fb(C, point.lease_seconds), clone_jobs(jobs), ws,
                  duration)
    assert ref.completed_jobs == C * gens
    assert ref.avg_execution == rt          # every generation runs rt

    plain = run_sweep([point], jobs, ws, duration, mode="rounds",
                      scan_options=ScanOptions(window=128))[0]
    coal = run_sweep([point], jobs, ws, duration, mode="rounds",
                     scan_options=ScanOptions(window=128,
                                              coalesce=batch))[0]
    for row in (plain, coal):
        assert row["window_overflow"] == 0 and row["truncated"] == 0
        assert row["completed_jobs"] == C * gens
        # Bit-exact per-job times: generation k completes at k*rt, so
        # the turnaround mean is exactly rt * (1 + ... + gens) / gens.
        exact_turn = rt * (gens + 1) / 2.0
        assert row["avg_turnaround"] == exact_turn
        assert row["avg_execution"] == rt
        # The time integrals accumulate in the lane dtype (float32 by
        # default) — equality up to its round-off, not bit-for-bit.
        assert row["node_hours"] == pytest.approx(ref.node_hours,
                                                  rel=1e-6)
        assert row["peak_nodes"] == ref.peak_nodes == C
    assert coal["coalesced"] > 0
    assert coal["rounds"] <= math.ceil(C * gens / batch)
    assert coal["rounds"] < plain["rounds"]


# --------------------------------------- bench gate <-> contract table

def test_bench_gate_uses_the_contract_table():
    """The CI gate in benchmarks/run.py must read its thresholds from
    repro.sim.contracts: the gate flips exactly at the table's
    node-hours and peak bounds, and hard-fails on inexact job counts,
    truncation, donation warnings and sharded mismatches."""
    from benchmarks.run import rounds_contract_ok

    def fid(**kw):
        base = dict(completed_jobs_exact=True,
                    max_drift_node_hours=0.0, max_drift_peak=0.0,
                    truncated_lanes=0)
        base.update(kw)
        return base

    assert rounds_contract_ok(fid(), [], True)
    # Flips exactly at the table's thresholds (no hardcoded copies).
    nh = ROUNDS_CONTRACT.node_hours_rel
    pk = ROUNDS_CONTRACT.peak_rel
    assert rounds_contract_ok(fid(max_drift_node_hours=nh), [], True)
    assert not rounds_contract_ok(
        fid(max_drift_node_hours=nh + 1e-9), [], True)
    assert rounds_contract_ok(fid(max_drift_peak=pk), [], True)
    assert not rounds_contract_ok(fid(max_drift_peak=pk + 1e-9), [],
                                  True)
    assert not rounds_contract_ok(fid(completed_jobs_exact=False), [],
                                  True)
    assert not rounds_contract_ok(fid(truncated_lanes=1), [], True)
    assert not rounds_contract_ok(fid(), ["donated buffer reused"], True)
    assert not rounds_contract_ok(fid(), [], False)


def test_contract_table_values():
    """The documented bands: scan 2 %/15 %/15 %, rounds exact/5 %/5 %,
    live exact/10 %/10 % plus the 25 % demand-drift bounds, faults
    ±2-jobs-or-2 %/2 %/2 %, queries' §6 headline bands 40–55 %/28–45 %
    (pinned value-by-value in test_capacity.py). A change here is a
    contract change — update README and the bench note in the same
    commit."""
    assert SCAN_CONTRACT.completed_rel == 0.02
    assert SCAN_CONTRACT.node_hours_rel == 0.15
    assert SCAN_CONTRACT.peak_rel == 0.15
    assert not SCAN_CONTRACT.completed_exact
    assert ROUNDS_CONTRACT.completed_exact
    assert ROUNDS_CONTRACT.node_hours_rel == 0.05
    assert ROUNDS_CONTRACT.peak_rel == 0.05
    assert LIVE_CONTRACT.completed_exact
    assert LIVE_CONTRACT.node_hours_rel == 0.10
    assert LIVE_CONTRACT.peak_rel == 0.10
    assert LIVE_CONTRACT.demand_mae_rel == 0.25
    assert LIVE_CONTRACT.demand_peak_rel == 0.25
    assert not FAULT_CONTRACT.completed_exact
    assert FAULT_CONTRACT.completed_abs == 2
    assert FAULT_CONTRACT.completed_rel == 0.02
    assert FAULT_CONTRACT.node_hours_rel == 0.02
    assert FAULT_CONTRACT.peak_rel == 0.02
    assert set(CONTRACTS) == {"scan", "rounds", "vectorized", "live",
                              "faults", "queries"}


def test_check_fidelity_flags_violations():
    ev = [{"system": "FB(C=1)", "engine": "event", "completed_jobs": 100,
           "node_hours": 100.0, "peak_nodes": 10}]
    good = [dict(ev[0], engine="rounds")]
    assert check_fidelity(good, ev) == []
    bad = [dict(ev[0], engine="rounds", completed_jobs=99)]
    assert any("completed_jobs" in v for v in check_fidelity(bad, ev))
    drifted = [dict(ev[0], engine="scan", node_hours=120.0)]
    assert any("node_hours" in v for v in check_fidelity(drifted, ev))
    ok_scan = [dict(ev[0], engine="scan", node_hours=114.0)]
    assert check_fidelity(ok_scan, ev) == []
