"""Capacity query layer (repro.sim.capacity) — bisection vs brute
force, Pareto invariants, the cost lens, and the warning-attribution
satellite.

The bisection tests pin the layer's two guarantees: (1) equality with
the brute-force argmin over a full tiny grid, on BOTH the rounds fast
path and the event reference, and (2) the local property that the
returned capacity is feasible while its predecessor is not (the
monotonicity caveat in the module docstring makes (2) the guarantee and
(1) the empirical check on grids small enough to scan). The
infeasible-SLO test is the regression for the silent-saturation bug:
a capacity interval topping out below the WS trace peak used to return
the grid edge as if it were an answer.
"""

import warnings

import numpy as np
import pytest

pytestmark = pytest.mark.tier1

from repro.core.baselines import billable_requests
from repro.core.jobs import Job
from repro.sim.capacity import (CapacitySLO, CostEstimate, CostModel,
                                DEFAULT_PROVIDERS, ProviderRate,
                                _with_capacity, headline_queries,
                                min_capacity, pareto_front)
from repro.sim.contracts import HEADLINE_CONTRACT, CONTRACTS
from repro.sim.sweep import ScanOptions, SweepPoint, run_sweep

DAY = 24 * 3600.0


def tiny_workload():
    """A queue-provoking workload whose min-C answers are nontrivial:
    16 unit jobs over the morning plus a small WS demand step — at
    C=1 almost nothing finishes inside the day, at C=12 everything
    does, and the crossover sits strictly inside (1, 12)."""
    jobs = [Job(i, float(i) * 600.0, size=2, runtime=2 * 3600.0)
            for i in range(16)]
    ws = [(0.0, 1), (6 * 3600.0, 3), (12 * 3600.0, 1)]
    return jobs, ws


def brute_argmin(template, jobs, ws, slo, lo, hi, mode):
    grid = [_with_capacity(template, c) for c in range(lo, hi + 1)]
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        rows = run_sweep(grid, jobs, ws, DAY, mode=mode)
    feas = [c for c, row in zip(range(lo, hi + 1), rows)
            if slo.satisfied(row, len(jobs))]
    return (feas[0] if feas else None), rows


# ----------------------------------------------- bisection vs brute force

@pytest.mark.parametrize("mode", ["event", "rounds"])
def test_min_capacity_matches_bruteforce(mode):
    jobs, ws = tiny_workload()
    slo = CapacitySLO(min_completed_frac=0.75)
    template = SweepPoint("fb")
    lo, hi = 1, 12
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        rep = min_capacity(template, (jobs, ws), slo, lo=lo, hi=hi,
                           duration=DAY, mode=mode)
    ref, rows = brute_argmin(template, jobs, ws, slo, lo, hi, mode)
    r = rep.results[0]
    assert ref is not None and r.capacity == ref
    assert lo < r.capacity <= hi          # crossover strictly inside
    # The bisection's own guarantee, checked on the brute-force rows:
    # result feasible, result-1 infeasible.
    assert slo.satisfied(rows[r.capacity - lo], len(jobs))
    assert not slo.satisfied(rows[r.capacity - lo - 1], len(jobs))
    # And measurably fewer evaluations than the grid scan.
    assert rep.rows_evaluated < rep.brute_force_rows == (hi - lo + 1)


def test_min_capacity_multilane_property():
    """Several (template x workload) lanes bisect jointly; every lane's
    answer satisfies the feasible/predecessor-infeasible property."""
    jobs, ws = tiny_workload()
    jobs2 = [Job(i, float(i) * 900.0, size=1, runtime=3600.0)
             for i in range(10)]
    slo = CapacitySLO(min_completed_frac=0.7)
    templates = [SweepPoint("fb"),
                 SweepPoint("fb", lease_seconds=1800.0)]
    workloads = [(jobs, ws), (jobs2, ws)]
    rep = min_capacity(templates, workloads, slo, lo=1, hi=10,
                       duration=DAY, mode="event")
    assert len(rep.results) == 4
    for r in rep.results:
        j = workloads[r.workload][0]
        assert slo.satisfied(r.row, len(j))
        if not r.at_grid_edge:
            ref, rows = brute_argmin(r.template, *workloads[r.workload],
                                     slo, 1, 10, "event")
            assert r.capacity == ref
    # The ledger is honest: joint bisection beat the full grid scan.
    assert rep.rows_evaluated < rep.brute_force_rows
    assert rep.iterations <= 6            # ~log2(10) + bracket


def test_min_capacity_grid_edge():
    jobs, ws = tiny_workload()
    rep = min_capacity(SweepPoint("fb"), (jobs, ws),
                       CapacitySLO(min_completed=1), lo=8, hi=12,
                       duration=DAY, mode="event")
    r = rep.results[0]
    assert r.capacity == 8 and r.at_grid_edge


def test_min_capacity_infeasible_slo_raises():
    """The regression: an interval whose top sits below the WS trace
    peak saturates silently — min_capacity must refuse, not return the
    grid edge."""
    jobs, ws = tiny_workload()
    ws_tall = [(0.0, 20)]                 # peak 20 > hi
    with pytest.raises(ValueError, match="infeasible") as ei:
        min_capacity(SweepPoint("fb"), (jobs, ws_tall),
                     CapacitySLO(min_completed_frac=0.9), lo=1, hi=8,
                     duration=DAY, mode="event")
    msg = str(ei.value)
    assert "WS trace peak" in msg and "20" in msg
    # Same refusal when the SLO is simply too ambitious for the grid.
    with pytest.raises(ValueError, match="empty bisection interval"):
        min_capacity(SweepPoint("fb"), (jobs, ws),
                     CapacitySLO(min_completed=len(jobs) * 2),
                     lo=1, hi=12, duration=DAY, mode="event")


def test_min_capacity_validation():
    jobs, ws = tiny_workload()
    with pytest.raises(ValueError, match="empty SLO"):
        CapacitySLO()
    with pytest.raises(ValueError, match="min_completed_frac"):
        CapacitySLO(min_completed_frac=1.5)
    with pytest.raises(ValueError, match="mode='event'"):
        min_capacity(SweepPoint("dcs", prc_ws=4), (jobs, ws),
                     CapacitySLO(min_completed=1), lo=1, hi=8,
                     duration=DAY, mode="rounds")
    with pytest.raises(ValueError, match="no capacity knob"):
        min_capacity(SweepPoint("ec2"), (jobs, ws),
                     CapacitySLO(min_completed=1), lo=1, hi=8,
                     duration=DAY, mode="event")
    with pytest.raises(ValueError, match="hi=4 < lo=6"):
        min_capacity(SweepPoint("fb"), (jobs, ws),
                     CapacitySLO(min_completed=1), lo=6, hi=4,
                     duration=DAY)


def test_with_capacity_knob_mapping():
    fb = _with_capacity(SweepPoint("fb", lease_seconds=1800.0), 7)
    assert fb.capacity == 7 and fb.lease_seconds == 1800.0
    flb = _with_capacity(SweepPoint("flb_nub", lb_ws=12), 25)
    assert flb.lb_pbj + flb.lb_ws == 25 and flb.lb_ws == 12
    # Small pools clamp the WS share to keep lb_pbj >= 1.
    flb2 = _with_capacity(SweepPoint("flb_nub", lb_ws=12), 5)
    assert flb2.lb_pbj + flb2.lb_ws == 5 and flb2.lb_pbj >= 1
    dcs = _with_capacity(SweepPoint("dcs", prc_ws=64), 32)
    assert dcs.prc_pbj == 32 and dcs.prc_ws == 64


# ------------------------------------------------------------ Pareto

def crafted_rows():
    """3-point grid with a known frontier: A and C trade off, B is
    dominated by A on every objective."""
    a = {"system": "A", "node_hours": 10.0, "peak_nodes": 5,
         "completed_jobs": 100}
    b = {"system": "B", "node_hours": 12.0, "peak_nodes": 7,
         "completed_jobs": 90}
    c = {"system": "C", "node_hours": 8.0, "peak_nodes": 9,
         "completed_jobs": 95}
    return [a, b, c]


def test_pareto_front_crafted_3point():
    front = pareto_front(rows=crafted_rows())
    assert front.frontier == (0, 2)
    assert [p.on_frontier for p in front.points] == [True, False, True]
    assert front.points[1].dominated_by == 0     # A dominates B
    assert [r["system"] for r in front.frontier_rows()] == ["A", "C"]


def test_pareto_front_completeness_and_ties():
    # Every dominated point names a frontier dominator...
    rows = crafted_rows()
    front = pareto_front(rows=rows)
    for p in front.points:
        assert p.on_frontier or p.dominated_by in front.frontier
    # ...and exact ties stay on the frontier together.
    twin = dict(rows[0], system="A2")
    front2 = pareto_front(rows=[rows[0], twin])
    assert front2.frontier == (0, 1)


def test_pareto_front_objectives_and_errors():
    rows = crafted_rows()
    # Single-objective: plain argmin.
    front = pareto_front(rows=rows, objectives=("node_hours",))
    assert front.frontier == (2,)
    with pytest.raises(ValueError, match="unknown objective"):
        pareto_front(rows=rows, objectives=("speedup",))
    with pytest.raises(ValueError, match="mode='event'"):
        pareto_front(rows=[{"system": "dcs", "node_hours": 1.0,
                            "peak_nodes": 1}])
    with pytest.raises(ValueError, match="rows"):
        pareto_front()


def test_pareto_front_end_to_end_event():
    """A real tiny sweep: re-check non-domination directly."""
    jobs, ws = tiny_workload()
    points = ([SweepPoint("fb", capacity=c) for c in (2, 4, 8)]
              + [SweepPoint("flb_nub", lb_pbj=3, lb_ws=2)])
    front = pareto_front(points, jobs, ws, duration=DAY, mode="event")
    sense = {"node_hours": 1, "peak_nodes": 1, "completed_jobs": -1}

    def dominates(x, y):
        vals = [(sense[m] * x[m], sense[m] * y[m])
                for m in front.objectives]
        return (all(a <= b for a, b in vals)
                and any(a < b for a, b in vals))
    assert len(front.frontier) >= 1
    for i in front.frontier:
        assert not any(dominates(p.row, front.points[i].row)
                       for p in front.points)
    for p in front.points:
        if not p.on_frontier:
            assert dominates(front.points[p.dominated_by].row, p.row)


# ---------------------------------------------------------- cost lens

def test_cost_estimate_arithmetic():
    rate = ProviderRate("p", node_hour_usd=0.085, request_usd=0.0005)
    cm = CostModel(providers=(rate,))
    est = cm.estimate({"node_hours": 100.0, "adjust_events": 10})
    assert est.node_cost_usd == pytest.approx(8.5)
    assert est.request_cost_usd == pytest.approx(0.005)
    assert est.total_usd == pytest.approx(8.505)
    # Mixes add usage, not prices.
    mix = cm.estimate_mix([{"node_hours": 100.0, "adjust_events": 10},
                           {"node_hours": 50.0, "adjust_events": 0}])
    assert mix.node_hours == pytest.approx(150.0)
    assert mix.requests == 10
    assert mix.total_usd == pytest.approx(150 * 0.085 + 0.005)
    with pytest.raises(ValueError, match="different rates"):
        est + CostEstimate("q", 1.0, 0, 1.0, 0.0)


def test_cost_zero_usage():
    cm = CostModel()
    for p in cm.providers:
        est = cm.estimate({"node_hours": 0.0, "adjust_events": 0},
                          p.name)
        assert est.total_usd == 0.0
        assert est.node_cost_usd == est.request_cost_usd == 0.0


def test_cost_provider_comparison_ordering():
    cm = CostModel()
    row = {"node_hours": 1000.0, "adjust_events": 200}
    comp = cm.compare(row)
    totals = [e.total_usd for e in comp]
    assert totals == sorted(totals)
    assert cm.cheapest(row).provider == comp[0].provider
    # With pure node-hour usage the ordering follows the rates.
    nh_only = {"node_hours": 1000.0, "adjust_events": 0}
    cheapest_rate = min(DEFAULT_PROVIDERS,
                        key=lambda p: p.node_hour_usd)
    assert cm.cheapest(nh_only).provider == cheapest_rate.name
    with pytest.raises(ValueError, match="unknown provider"):
        cm.estimate(row, "nimbus9")
    with pytest.raises(ValueError, match="negative"):
        ProviderRate("bad", node_hour_usd=-1.0)


def test_billable_requests():
    assert billable_requests({"adjust_events": 7}) == 7
    assert billable_requests({}) == 0

    class R:
        adjust_events = 3
    assert billable_requests(R()) == 3
    assert billable_requests(object()) == 0


# ------------------------------------------------- headline contract

def test_headline_contract_bands():
    assert CONTRACTS["queries"] is HEADLINE_CONTRACT
    # The measured reproduction numbers land in band.
    assert HEADLINE_CONTRACT.check(0.4726, 0.386) == []
    assert HEADLINE_CONTRACT.check(0.40, 0.28) == []
    v = HEADLINE_CONTRACT.check(0.20, 0.386)
    assert len(v) == 1 and "config_reduction" in v[0]
    v = HEADLINE_CONTRACT.check(0.4726, 0.10)
    assert len(v) == 1 and "peak_reduction" in v[0]
    assert len(HEADLINE_CONTRACT.check(0.99, 0.99)) == 2


@pytest.mark.slow
def test_headline_queries_tiny_end_to_end():
    """The tiny (CI-sized) headline run: plumbing end-to-end — both
    queries execute, the band gate is explicitly skipped."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", RuntimeWarning)
        out = headline_queries(tiny=True)
    assert out["gate"]["checked"] is False and out["gate"]["ok"]
    priv = out["private"]
    assert priv["min_fb_capacity"] <= priv["dcs_size"]
    assert priv["fb_completed"] >= priv["dcs_completed"]
    assert priv["rows_evaluated"] < priv["brute_force_rows"]
    assert 0.0 < out["public"]["peak_reduction"] < 1.0


# ------------------------------------- warning attribution satellite

def test_sweep_warning_filename_is_callers():
    """The stacklevel satellite: the window-overflow RuntimeWarning
    must report THIS file, not sweep.py internals — through run_sweep
    and run_sweep_workloads both."""
    from repro.sim.sweep import run_sweep_workloads
    jobs = [Job(i, float(i), size=8, runtime=9 * 3600.0)
            for i in range(24)]
    ws = [(0.0, 0)]
    point = SweepPoint("fb", capacity=8)
    opts = ScanOptions(window=8)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        run_sweep([point], jobs, ws, DAY, mode="rounds",
                  scan_options=opts)
    hits = [w for w in caught if "backlog outgrew" in str(w.message)]
    assert hits and all(w.filename == __file__ for w in hits), \
        [(w.filename, w.lineno) for w in hits]
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        run_sweep_workloads([point], [(jobs, ws)], DAY, mode="rounds",
                            scan_options=opts)
    hits = [w for w in caught if "backlog outgrew" in str(w.message)]
    assert hits and all(w.filename == __file__ for w in hits)
    # ...and through the query layer one level further up.
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        min_capacity(SweepPoint("fb"), (jobs, ws),
                     CapacitySLO(min_completed=1), lo=8, hi=9,
                     duration=DAY, mode="rounds", scan_options=opts)
    hits = [w for w in caught if "backlog outgrew" in str(w.message)]
    assert hits and all(w.filename == __file__ for w in hits)


def test_checkpoint_warning_filename_is_callers(tmp_path):
    """Same for the torn-checkpoint skip in restore_latest."""
    import os
    from repro.train.checkpoint import Checkpointer
    tree = {"w": np.arange(4, dtype=np.float32)}
    ck = Checkpointer(str(tmp_path), keep=3)
    ck.save(1, tree, metadata={})
    leaf = os.path.join(str(tmp_path), "step_1", "leaf_0.npy")
    np.save(leaf, np.load(leaf) + 1.0)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        assert ck.restore_latest(tree) is None
    hits = [w for w in caught if "torn checkpoint" in str(w.message)]
    assert hits and all(w.filename == __file__ for w in hits), \
        [(w.filename, w.lineno) for w in hits]


# ----------------------------------------------------------- exports

def test_capacity_exports_lazy():
    import repro.sim as sim
    for name in ("CapacitySLO", "min_capacity", "pareto_front",
                 "CostModel", "CostEstimate", "ProviderRate",
                 "headline_queries"):
        assert getattr(sim, name) is not None
