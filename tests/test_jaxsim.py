"""JAX-native tick simulator: fidelity vs the event simulator + the
paper's parameter trends, as one vmapped program."""

import pytest

from repro.core import jaxsim
from repro.sim import traces
from repro.sim.engine import build_flb_nub, clone_jobs, run_sim


@pytest.fixture(scope="module")
def setup():
    jobs = traces.nasa_ipsc(seed=0)
    ws = traces.worldcup98(seed=0, peak_vms=128)
    return jobs, ws


def test_fidelity_vs_event_sim(setup):
    jobs, ws = setup
    T = traces.TWO_WEEKS
    ref = run_sim(build_flb_nub(13, 12), clone_jobs(jobs), ws, T)
    out = jaxsim.sweep([{"B": 25, "U": 1.2, "V": 0.2, "G": 0.5}],
                       jobs, ws, T)[0]
    assert abs(out["completed_jobs"] - ref.completed_jobs) <= 2
    assert abs(out["node_hours"] - ref.node_hours) / ref.node_hours < 0.15
    assert abs(out["peak_nodes"] - ref.peak_nodes) / ref.peak_nodes < 0.15


def test_pack_trace_dtype_follows_x64_setting(setup):
    """pack_trace defaults to the active x64 mode (the setting the sweep
    engine's exact paths run under), and takes an explicit dtype."""
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental import enable_x64

    jobs, ws = setup
    packed = jaxsim.pack_trace(jobs[:8], ws[:8], 7200.0, 3600.0)
    assert packed[0].dtype == jnp.float32
    with enable_x64():
        packed64 = jaxsim.pack_trace(jobs[:8], ws[:8], 7200.0, 3600.0)
        assert packed64[0].dtype == jnp.float64
        assert packed64[3].dtype == jnp.float64
        forced = jaxsim.pack_trace(jobs[:8], ws[:8], 7200.0, 3600.0,
                                   dtype=np.float32)
        assert forced[0].dtype == jnp.float32
    # Explicit float64 without x64 would be silently downcast — refuse.
    with pytest.raises(ValueError, match="x64"):
        jaxsim.pack_trace(jobs[:8], ws[:8], 7200.0, 3600.0,
                          dtype=np.float64)


def test_vmapped_paper_trends(setup):
    """J1 (Fig 14): consumption grows and turnaround falls with B;
    §6.6.4: turnaround grows with G — in one batched program."""
    jobs, ws = setup
    grid = [{"B": b, "U": 1.2, "V": 0.2, "G": 0.5} for b in (13, 51, 154)] \
        + [{"B": 25, "U": 1.2, "V": 0.2, "G": g} for g in (0.25, 0.99)]
    out = jaxsim.sweep(grid, jobs, ws, traces.TWO_WEEKS)
    b_rows, g_rows = out[:3], out[3:]
    assert b_rows[0]["node_hours"] < b_rows[1]["node_hours"] \
        < b_rows[2]["node_hours"]                       # J1: nh grows w/ B
    assert b_rows[0]["avg_turnaround"] > b_rows[2]["avg_turnaround"]
    assert g_rows[0]["avg_turnaround"] < g_rows[1]["avg_turnaround"]  # G
    assert all(r["completed_jobs"] >= 2600 for r in out)
