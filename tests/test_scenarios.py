"""On-device scenario synthesis (repro.sim.scenarios) + the batched WS
fold tables (repro.sim.rounds.ws_fold_tables_batch).

The generator family's contract has three legs, each pinned here:

* determinism & batching — every lane is a pure function of its PRNG
  key, and K vmapped lanes bit-match K single-key calls. The bit-match
  holds between JITTED programs (the vmapped batch is always jitted);
  an eager single call may reassociate float ops and drift by an ulp,
  which is exactly why the property is stated under jit.
* moments — the paper-trace parameter points reproduce the TraceSpec
  targets: utilization pinned exactly by the rescale, job counts exact,
  runtime means inside the bands the numpy generators realize, WS peak
  exactly the spec's integer peak.
* fold tables — the batched (W, P) build is elementwise EQUAL to the
  per-point reference loop, and the per-workload lru cache in front of
  ``pack_event_workloads`` serves repeated packs from memory.
"""

import functools

import numpy as np
import pytest

pytestmark = pytest.mark.tier1

import jax

from repro.sim import scenarios as sc
from repro.sim.rounds import (_ws_fold_tables_ref, fold_table_cache_clear,
                              fold_table_cache_info, pack_event_workloads,
                              ws_fold_tables_batch)

DAY = 24 * 3600.0


def small_grid(n=4, duration=2 * DAY, max_jobs=200):
    return sc.ScenarioGrid(
        seeds=tuple(range(n)),
        pbj=sc.PBJParams(nodes=64.0, utilization=0.5,
                         n_jobs=float(max_jobs - 50)),
        ws=sc.WSParams(peak=32.0),
        duration=duration, max_jobs=max_jobs)


# ------------------------------------------------- determinism & batching

def test_synthesize_deterministic_per_key():
    grid = small_grid()
    a, b = sc.synthesize(grid), sc.synthesize(grid)
    for f in ("submit", "size", "runtime", "n_jobs", "ws_values"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    # A different seed tuple is a different batch.
    c = sc.synthesize(sc.ScenarioGrid(
        seeds=(7, 8, 9, 10), pbj=grid.pbj, ws=grid.ws,
        duration=grid.duration, max_jobs=grid.max_jobs))
    assert not np.array_equal(a.submit, c.submit)


def test_vmapped_lanes_bitmatch_single_key_calls():
    """K vmapped lanes == K jitted single-key generator calls, bit for
    bit — the property that makes wide generated grids trustworthy
    stand-ins for one-at-a-time synthesis. Both sides must see the same
    program: jitted, with float32 param ARRAYS (the production
    ``_synth_batch`` configuration) — closing over Python-float params
    instead lets XLA constant-fold the arrival CDF into a different op
    order and drift submit times by an ulp."""
    import dataclasses

    seeds = (3, 11, 42)
    keys = sc.lane_keys(seeds)
    params = sc._broadcast_params(
        sc.PBJParams(nodes=64.0, utilization=0.5, n_jobs=150.0), 3)
    wsp = sc._broadcast_params(sc.WSParams(peak=32.0), 3)
    kw = dict(max_jobs=200, duration=2 * DAY)
    batch = jax.jit(jax.vmap(lambda k, p: sc.synth_pbj(k, p, **kw)))(
        keys[:, 0], params)
    single = jax.jit(lambda k, p: sc.synth_pbj(k, p, **kw))
    ws_batch = jax.jit(jax.vmap(lambda k, p: sc.synth_ws(k, p,
                                                         n_steps=96)))(
        keys[:, 1], wsp)
    ws_single = jax.jit(lambda k, p: sc.synth_ws(k, p, n_steps=96))

    def lane(pytree, w):
        return type(pytree)(**{
            f.name: np.asarray(getattr(pytree, f.name))[w]
            for f in dataclasses.fields(pytree)})

    for w, s in enumerate(seeds):
        k0, k1 = jax.random.split(jax.random.PRNGKey(s))
        sub, size, rt, nj = single(k0, lane(params, w))
        assert np.array_equal(np.asarray(batch[0][w]), np.asarray(sub))
        assert np.array_equal(np.asarray(batch[1][w]), np.asarray(size))
        assert np.array_equal(np.asarray(batch[2][w]), np.asarray(rt))
        assert int(batch[3][w]) == int(nj)
        assert np.array_equal(np.asarray(ws_batch[w]),
                              np.asarray(ws_single(k1, lane(wsp, w))))


def test_param_broadcast_and_per_lane_axes():
    grid = sc.ScenarioGrid(
        seeds=(0, 1, 2),
        pbj=sc.PBJParams(nodes=64.0, n_jobs=100.0,
                         utilization=np.array([0.3, 0.5, 0.7])),
        ws=sc.WSParams(peak=np.array([16.0, 32.0, 64.0])),
        duration=2 * DAY, max_jobs=150)
    s = sc.synthesize(grid)
    util = np.array([(s.size[w] * s.runtime[w]).sum()
                     for w in range(3)]) / (64.0 * 2 * DAY)
    assert np.allclose(util, [0.3, 0.5, 0.7], atol=1e-3)
    assert list(s.ws_values.max(axis=1)) == [16.0, 32.0, 64.0]
    with pytest.raises(ValueError, match="leading dim"):
        sc.synthesize(sc.ScenarioGrid(
            seeds=(0, 1, 2),
            pbj=sc.PBJParams(utilization=np.array([0.3, 0.5])),
            duration=2 * DAY, max_jobs=150))


# ------------------------------------------------------ moment matching

@pytest.mark.parametrize("point,nodes,util,n_jobs,rt_band", [
    (sc.NASA_IPSC_PBJ, 128, 0.466, 2603, (400.0, 700.0)),
    (sc.SDSC_BLUE_PBJ, 144, 0.762, 2657, (1500.0, 2500.0)),
])
def test_pbj_paper_points_match_trace_moments(point, nodes, util, n_jobs,
                                              rt_band):
    grid = sc.ScenarioGrid(seeds=(0,), pbj=point)
    s = sc.synthesize(grid)
    n = int(s.n_jobs[0])
    assert n == n_jobs                                  # count exact
    size, rt = s.size[0][:n], s.runtime[0][:n]
    sub = s.submit[0]
    assert np.all(np.diff(sub[:n]) >= 0)                # arrival sorted
    assert np.all(np.isinf(sub[n:]))                    # pad convention
    assert np.all((size >= 1) & (size <= nodes))
    assert np.all(np.log2(size) == np.round(np.log2(size)))
    realized = float((size * rt).sum()) / (nodes * sc.TWO_WEEKS)
    assert realized == pytest.approx(util, abs=1e-3)    # pinned by rescale
    assert rt_band[0] < rt.mean() < rt_band[1]
    assert rt.min() >= 1.0


def test_ws_paper_point_matches_worldcup_moments():
    s = sc.synthesize(sc.ScenarioGrid(seeds=(0, 1), ws=sc.WORLDCUP_WS,
                                      max_jobs=100,
                                      pbj=sc.PBJParams(n_jobs=50.0)))
    v = s.ws_values
    assert np.all(v.max(axis=1) == 64.0)                # peak exact
    assert v.min() >= 1.0                               # 1-VM floor
    assert np.all(v == np.round(v))                     # integer demands
    changes = (v[:, 1:] != v[:, :-1]).sum(axis=1)
    assert np.all(changes > 500)                        # a live series


# ------------------------------------------------------ fold-table batch

def _random_fold_case(rng, W):
    n = rng.integers(5, 60)
    times = np.concatenate([[0.0], np.sort(rng.uniform(
        0.0, 4000.0, n - 1))])
    values = rng.integers(0, 30, (W, n)).astype(np.float64)
    duration = float(rng.uniform(3000.0, 5000.0))
    P = int(rng.integers(1, 5))
    leases = rng.uniform(200.0, 2000.0, P)
    levels = rng.integers(1, 25, P).astype(np.float64)
    return times[times < duration], values, duration, leases, levels


def test_fold_batch_equals_reference_loop():
    """The batched (W, P) build is elementwise EQUAL (not close) to the
    per-point reference loop — integral, window max and boundary gather
    alike — across random lease/level grids and both policies."""
    rng = np.random.default_rng(0)
    for trial in range(25):
        times, values, duration, leases, levels = _random_fold_case(
            rng, W=1)
        values = values[:, :len(times)]
        for policy in ("fb", "flb_nub"):
            ref = _ws_fold_tables_ref(times, values[0], duration, policy,
                                      leases, levels)
            got = ws_fold_tables_batch(times, values[0], duration, policy,
                                       leases, levels)
            for r, g, name in zip(ref, got, ("integral", "winmax",
                                             "at_tick")):
                assert np.array_equal(r, g[0]), (trial, policy, name)


def test_fold_batch_multi_lane_equals_per_lane():
    rng = np.random.default_rng(7)
    times, values, duration, leases, levels = _random_fold_case(rng, W=6)
    values = values[:, :len(times)]
    for policy in ("fb", "flb_nub"):
        integral, winmax, at_tick = ws_fold_tables_batch(
            times, values, duration, policy, leases, levels)
        for w in range(6):
            ref = _ws_fold_tables_ref(times, values[w], duration, policy,
                                      leases, levels)
            assert np.array_equal(ref[0], integral[w])
            assert np.array_equal(ref[1], winmax[w])
            assert np.array_equal(ref[2], at_tick[w])


def test_fold_table_cache_hits_on_repeat_pack():
    """Re-packing the same workload (what the differential harness and
    the multi-engine benchmark do once per engine column) must serve
    the fold tables from the lru cache."""
    s = sc.synthesize(small_grid(n=2))
    workloads = sc.sample_workloads(s, [0, 1])
    fold_table_cache_clear()
    pack_event_workloads(workloads, s.duration, 16, "fb",
                         [3600.0], [48.0])
    info = fold_table_cache_info()
    assert info.misses == 2 and info.hits == 0
    pack_event_workloads(workloads, s.duration, 16, "fb",
                         [3600.0], [48.0])
    info = fold_table_cache_info()
    assert info.misses == 2 and info.hits == 2
    # A different level grid is a different key, not a stale hit.
    pack_event_workloads(workloads, s.duration, 16, "fb",
                         [3600.0], [64.0])
    assert fold_table_cache_info().misses == 4


# ------------------------------------------------------- batch plumbing

def test_sample_workloads_round_trips_the_batch():
    s = sc.synthesize(small_grid(n=3))
    for w, (jobs, trace) in enumerate(sc.sample_workloads(s, [0, 1, 2])):
        assert len(jobs) == int(s.n_jobs[w])
        assert jobs[0].submit == float(s.submit[w, 0])
        assert [j.size for j in jobs[:5]] == list(s.size[w, :5])
        # The step trace re-realizes the dense series exactly.
        t = np.asarray([p[0] for p in trace])
        v = np.asarray([p[1] for p in trace], np.float64)
        idx = np.searchsorted(t, s.ws_times, "right") - 1
        assert np.array_equal(v[idx], s.ws_values[w])


def test_pack_scenarios_matches_pack_event_workloads():
    """The all-array pack path produces the same fold tables and rise
    stops as the host-loop pack of the sampled lanes."""
    s = sc.synthesize(small_grid(n=3))
    workloads = sc.sample_workloads(s, [0, 1, 2])
    a = sc.pack_scenarios(s, window=16, policy="fb", leases=[3600.0],
                          levels=[48.0])
    b = pack_event_workloads(workloads, s.duration, 16, "fb",
                             [3600.0], [48.0])
    assert np.array_equal(np.asarray(a.ws_integral),
                          np.asarray(b.ws_integral))
    assert np.array_equal(np.asarray(a.ws_winmax),
                          np.asarray(b.ws_winmax))
    assert np.array_equal(np.asarray(a.ws_at_tick),
                          np.asarray(b.ws_at_tick))
    assert np.array_equal(np.asarray(a.ws0), np.asarray(b.ws0))
    assert np.array_equal(np.asarray(a.ws_adjusts),
                          np.asarray(b.ws_adjusts))
    assert np.array_equal(np.asarray(a.n_jobs), np.asarray(b.n_jobs))
    # Rise stops agree once both are filtered to the real (finite) ones.
    ra = np.asarray(a.rise_times)
    rb = np.asarray(b.rise_times)
    for w in range(3):
        assert np.array_equal(ra[w][np.isfinite(ra[w])],
                              rb[w][np.isfinite(rb[w])])


def test_traces_module_forwards_scenario_names():
    from repro.sim import traces
    assert traces.synth_pbj is sc.synth_pbj
    assert traces.NASA_IPSC_PBJ is sc.NASA_IPSC_PBJ
    with pytest.raises(AttributeError):
        traces.not_a_scenario_name


# ------------------------------------------- end-to-end generated sweep

@pytest.mark.slow
def test_generated_sweep_matches_event_engine_on_sampled_lanes():
    """A generated ScenarioGrid through ``run_sweep_workloads`` on the
    rounds engine, with sampled lanes re-run on the event engine and
    held to the rounds contract (completed exact, node-hours/peak
    within 5 %) — the PR 5 differential harness over generated lanes."""
    from repro.sim.contracts import CONTRACTS
    from repro.sim.sweep import SweepPoint, run_sweep_workloads

    grid = sc.ScenarioGrid(
        seeds=tuple(range(6)),
        pbj=sc.PBJParams(nodes=64.0, utilization=0.5, n_jobs=350.0),
        ws=sc.WSParams(peak=32.0),
        duration=2 * DAY, max_jobs=400)
    points = [SweepPoint("fb", capacity=48),
              SweepPoint("fb", capacity=64),
              SweepPoint("flb_nub", lb_pbj=6, lb_ws=4),
              SweepPoint("flb_nub", lb_pbj=13, lb_ws=12,
                         lease_seconds=1800.0)]
    rows = run_sweep_workloads(points, grid, mode="rounds")
    assert len(rows) == 6 and all(len(r) == len(points) for r in rows)

    sample = [0, 3, 5]
    synth = sc.synthesize(grid)
    ev_rows = run_sweep_workloads(points, sc.sample_workloads(
        synth, sample), grid.duration, mode="event")
    for j, w in enumerate(sample):
        for i in range(len(points)):
            violations = CONTRACTS["rounds"].check_row(rows[w][i],
                                                       ev_rows[j][i])
            assert not violations, (w, points[i].name(), violations)


def test_generated_sweep_rejects_bad_modes_and_duration():
    from repro.sim.sweep import SweepPoint, run_sweep_workloads
    grid = small_grid(n=2)
    points = [SweepPoint("fb", capacity=48)]
    with pytest.raises(ValueError, match="duration is fixed"):
        run_sweep_workloads(points, grid, 3 * DAY, mode="rounds")
    with pytest.raises(ValueError):
        run_sweep_workloads(points, grid, mode="scan")
    with pytest.raises(ValueError):
        run_sweep_workloads([SweepPoint("dcs", prc_pbj=32, prc_ws=32)],
                            grid, mode="rounds")
