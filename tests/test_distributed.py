"""Multi-device tests (8 forced host devices via subprocess): gradient
compression collectives, sharded train step numerics vs single-device,
checkpoint resharding across mesh shapes, and the HLO analysis tooling."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = [pytest.mark.slow, pytest.mark.sharded_subprocess]

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run8(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_int8_ef_allreduce_matches_psum():
    out = _run8("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro.compat import shard_map
        from repro.train import compression as C
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("dp",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 4096)) * 2.0
        e = jnp.zeros_like(g)
        fn = jax.jit(shard_map(
            lambda g, e: C.ef_allreduce_mean(g, e, "dp"),
            mesh=mesh, in_specs=(P("dp"), P("dp")),
            out_specs=(P("dp"), P("dp")), check_vma=False))
        mean, err = fn(g, e)
        true = jnp.mean(g, axis=0)
        rel = float(jnp.max(jnp.abs(mean[0] - true))
                    / jnp.max(jnp.abs(true)))
        assert rel < 0.03, rel                  # int8 single shot
        # All shards agree exactly (it IS an all-reduce).
        m = np.asarray(mean)
        assert np.all(m == m[0:1]), "shards disagree"
        # Error feedback: residual bounded by the quantization step.
        q_step = float(jnp.max(jnp.abs(g + 0))) / 127.0
        assert float(jnp.max(jnp.abs(err))) <= q_step + 1e-6
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    out = _run8("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs.base import get_config, reduced_config
        from repro.models.transformer import Model
        from repro.train.optimizer import get_optimizer
        from repro.train.trainer import make_train_step, batch_pspecs
        cfg = reduced_config(get_config("smollm_135m"), vocab=512)
        devs = np.array(jax.devices())
        mesh8 = Mesh(devs.reshape(4, 2), ("data", "model"))
        mesh1 = Mesh(devs[:1].reshape(1, 1), ("data", "model"))
        key = jax.random.PRNGKey(0)
        batch = {"tokens": jax.random.randint(key, (8, 32), 0, 512),
                 "labels": jax.random.randint(key, (8, 32), 0, 512)}
        losses = []
        for mesh in (mesh1, mesh8):
            model = Model(cfg, mesh, compute_dtype=jnp.float32)
            with jax.default_device(jax.devices()[0]):
                params = model.init(0)
            opt = get_optimizer("adamw", lr=1e-3)
            state = opt.init(params)
            step = jax.jit(make_train_step(model, opt, accum_steps=2))
            for _ in range(3):
                params, state, m = step(params, state, batch,
                                        jnp.float32(1e-3))
            losses.append(float(m["loss"]))
        assert abs(losses[0] - losses[1]) < 1e-3, losses
        print("OK", losses)
    """)
    assert "OK" in out


def test_checkpoint_elastic_reshard_across_meshes():
    out = _run8("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.train.checkpoint import Checkpointer
        devs = np.array(jax.devices())
        meshA = Mesh(devs.reshape(8, 1), ("data", "model"))
        meshB = Mesh(devs.reshape(2, 4), ("data", "model"))
        w = jnp.arange(64.0).reshape(8, 8)
        wA = jax.device_put(w, NamedSharding(meshA, P("data", "model")))
        d = tempfile.mkdtemp()
        ck = Checkpointer(d)
        ck.save(3, {"w": wA})
        out, _ = ck.restore(3, {"w": jnp.zeros((8, 8))}, mesh=meshB,
                            specs={"w": P("data", "model")})
        assert out["w"].sharding.mesh.shape == meshB.shape
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))
        print("OK")
    """)
    assert "OK" in out


def test_collective_parser_on_sharded_module():
    out = _run8("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import collective_bytes
        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        def f(xs):
            def body(c, x):
                s = jax.lax.with_sharding_constraint(
                    x.sum(0), NamedSharding(mesh, P()))
                return c + jnp.sum(s) + jnp.sum(x @ x.T), None
            return jax.lax.scan(body, 0.0, xs)[0]
        xs = jax.ShapeDtypeStruct((13, 1024, 64), jnp.float32)
        comp = jax.jit(f, in_shardings=(
            NamedSharding(mesh, P(None, "data", None)),)).lower(xs).compile()
        cb = collective_bytes(comp.as_text())
        # all-gather of f32[64,1024] inside a 13-trip loop.
        assert cb["all-gather"] == 64 * 1024 * 4 * 13, cb
        assert cb["_counts"]["all-gather"] == 13
        print("OK")
    """)
    assert "OK" in out


def test_moe_ep_sharded_matches_replicated():
    out = _run8("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh
        from repro.configs.base import get_config, reduced_config
        from repro.models import mlp as F
        from repro.models.common import AxisSizes, KeyGen
        import repro.models.mlp as mlp_mod
        mlp_mod.CAPACITY_FACTOR = 64.0    # avoid drop divergence
        cfg = reduced_config(get_config("granite_moe_3b"), d_ff=64)
        devs = np.array(jax.devices())
        p = F.init_moe(KeyGen(jax.random.PRNGKey(0)), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
        outs = []
        for shape, axes in (((1, 1), ("data", "model")),
                            ((2, 4), ("data", "model"))):
            n = shape[0] * shape[1]
            mesh = Mesh(devs[:n].reshape(shape), axes)
            ax = AxisSizes.from_mesh(mesh)
            outs.append(np.asarray(
                jax.jit(lambda p, x: F.moe_mlp(p, x, cfg, ax, mesh))(p, x)))
        np.testing.assert_allclose(outs[0], outs[1], atol=2e-5, rtol=2e-4)
        print("OK")
    """)
    assert "OK" in out
