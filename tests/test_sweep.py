"""Vectorized sweep engine vs the discrete-event engine (§6 methodology).

The acceptance bar for ``repro.sim.sweep``: one ``run_sweep`` call over
20+ (system, parameter) points, with the vectorized DCS/EC2 fast path
agreeing with per-point event-engine runs on every point — integer
metrics (peak nodes, completed jobs, adjust events) exactly, node-hours
to float64 round-off (< 1e-9 relative; the two paths sum the same
piecewise-constant integral in different association orders).
"""

import pytest

from repro.sim import traces
from repro.sim.engine import run_sim
from repro.sim.sweep import SweepPoint, _build, paper_grid, run_sweep

# Small trace grid: the first two simulated days of the moment-matched
# NASA-iPSC + WorldCup pair, including jobs that straddle the horizon.
T = 2 * 24 * 3600.0


@pytest.fixture(scope="module")
def workload():
    jobs = [j for j in traces.nasa_ipsc(seed=3) if j.submit < T]
    ws = [(t, d) for t, d in traces.worldcup98(seed=3, peak_vms=64)
          if t < T]
    return jobs, ws


@pytest.fixture(scope="module")
def grid():
    dcs = [SweepPoint("dcs", prc_pbj=p, prc_ws=w)
           for p, w in ((128, 64), (96, 32), (64, 64), (128, 128),
                        (32, 16), (64, 0), (48, 96), (200, 100))]
    ec2 = [SweepPoint("ec2", lease_seconds=s)
           for s in (450.0, 900.0, 1800.0, 2700.0, 3600.0, 5400.0,
                     7200.0, 10800.0, 14400.0, 28800.0)]
    phoenix = [SweepPoint("fb", capacity=160),
               SweepPoint("flb_nub", lb_pbj=13, lb_ws=12)]
    return dcs + ec2 + phoenix          # 20 vectorized + 2 event points


def test_vectorized_matches_event_engine_exactly(workload, grid):
    jobs, ws = workload
    assert len(grid) >= 20              # one call sweeps the whole grid
    vec = run_sweep(grid, jobs, ws, T, vectorize=True)
    ref = run_sweep(grid, jobs, ws, T, vectorize=False)
    assert [r["system"] for r in vec] == [p.name() for p in grid]
    for point, v, r in zip(grid, vec, ref):
        expected_engine = ("vectorized" if point.system in ("dcs", "ec2")
                           else "event")
        assert v["engine"] == expected_engine, point
        assert r["engine"] == "event"
        # Exact integer agreement.
        assert v["peak_nodes"] == r["peak_nodes"], point
        assert v["adjust_events"] == r["adjust_events"], point
        assert v["pbj_adjust_events"] == r["pbj_adjust_events"], point
        assert v["kills"] == r["kills"], point
        if "completed_jobs" in v and "completed_jobs" in r:
            assert v["completed_jobs"] == r["completed_jobs"], point
            assert v["avg_turnaround"] == pytest.approx(
                r["avg_turnaround"], rel=1e-9)
        # Node-hours to float64 round-off.
        assert v["node_hours"] == pytest.approx(r["node_hours"], rel=1e-9,
                                                abs=1e-9), point


def test_vectorized_ec2_against_direct_run_sim(workload):
    """Belt and braces: the fast path also matches a hand-driven
    ``run_sim`` (not just ``run_sweep``'s own fallback)."""
    jobs, ws = workload
    from repro.sim.engine import build_ec2_rightscale, clone_jobs
    point = SweepPoint("ec2", lease_seconds=1800.0)
    row = run_sweep([point], jobs, ws, T)[0]
    r = run_sim(build_ec2_rightscale(1800.0), clone_jobs(jobs), ws, T)
    assert row["peak_nodes"] == r.peak_nodes
    assert row["completed_jobs"] == r.completed_jobs
    assert row["node_hours"] == pytest.approx(r.node_hours, rel=1e-9)
    assert row["avg_turnaround"] == pytest.approx(r.avg_turnaround, rel=1e-9)
    # EC2 never queues: turnaround == execution (§6.6.1).
    assert row["avg_turnaround"] == row["avg_execution"]


def test_paper_grid_shape_and_fallback_routing(workload):
    jobs, ws = workload
    pts = paper_grid(prc_pbj=64, prc_ws=64,
                     capacity_fracs=(0.6, 1.0), B_values=(13, 25),
                     lease_minutes=(30, 60), fig18_B=25)
    assert len(pts) == 1 + 2 + 2 + 2 * 2
    rows = run_sweep(pts, jobs, ws, T)
    by_kind = {r["system_kind"]: r["engine"] for r in rows}
    assert by_kind["dcs"] == "vectorized"
    assert by_kind["ec2"] == "vectorized"
    assert by_kind["fb"] == "event"
    assert by_kind["flb_nub"] == "event"
    # Every builder constructs a ProvisioningSystem with the right lease.
    for p in pts:
        assert _build(p).lease_seconds == p.lease_seconds
