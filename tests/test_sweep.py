"""Vectorized sweep engine vs the discrete-event engine (§6 methodology).

The acceptance bar for ``repro.sim.sweep``: one ``run_sweep`` call over
20+ (system, parameter) points, with the vectorized DCS/EC2 fast path
agreeing with per-point event-engine runs on every point — integer
metrics (peak nodes, completed jobs, adjust events) exactly, node-hours
to float64 round-off (< 1e-9 relative; the two paths sum the same
piecewise-constant integral in different association orders).
"""

import pytest

pytestmark = pytest.mark.tier1

from repro.sim import traces
from repro.sim.engine import run_sim
from repro.sim.sweep import (SweepPoint, _build, paper_grid, run_sweep,
                             run_sweep_workloads)

# Small trace grid: the first two simulated days of the moment-matched
# NASA-iPSC + WorldCup pair, including jobs that straddle the horizon.
T = 2 * 24 * 3600.0


@pytest.fixture(scope="module")
def workload():
    jobs = [j for j in traces.nasa_ipsc(seed=3) if j.submit < T]
    ws = [(t, d) for t, d in traces.worldcup98(seed=3, peak_vms=64)
          if t < T]
    return jobs, ws


@pytest.fixture(scope="module")
def grid():
    dcs = [SweepPoint("dcs", prc_pbj=p, prc_ws=w)
           for p, w in ((128, 64), (96, 32), (64, 64), (128, 128),
                        (32, 16), (64, 0), (48, 96), (200, 100))]
    ec2 = [SweepPoint("ec2", lease_seconds=s)
           for s in (450.0, 900.0, 1800.0, 2700.0, 3600.0, 5400.0,
                     7200.0, 10800.0, 14400.0, 28800.0)]
    phoenix = [SweepPoint("fb", capacity=160),
               SweepPoint("flb_nub", lb_pbj=13, lb_ws=12)]
    return dcs + ec2 + phoenix          # 20 vectorized + 2 event points


def test_vectorized_matches_event_engine_exactly(workload, grid):
    jobs, ws = workload
    assert len(grid) >= 20              # one call sweeps the whole grid
    vec = run_sweep(grid, jobs, ws, T, vectorize=True)
    ref = run_sweep(grid, jobs, ws, T, vectorize=False)
    assert [r["system"] for r in vec] == [p.name() for p in grid]
    for point, v, r in zip(grid, vec, ref):
        assert r["engine"] == "event"
        if point.system in ("dcs", "ec2"):
            assert v["engine"] == "vectorized", point
            # Exact integer agreement.
            assert v["peak_nodes"] == r["peak_nodes"], point
            assert v["adjust_events"] == r["adjust_events"], point
            assert v["pbj_adjust_events"] == r["pbj_adjust_events"], point
            assert v["kills"] == r["kills"], point
            if "completed_jobs" in v and "completed_jobs" in r:
                assert v["completed_jobs"] == r["completed_jobs"], point
                assert v["avg_turnaround"] == pytest.approx(
                    r["avg_turnaround"], rel=1e-9)
            # Node-hours to float64 round-off.
            assert v["node_hours"] == pytest.approx(r["node_hours"],
                                                    rel=1e-9,
                                                    abs=1e-9), point
        else:
            # The stateful policies ride the event-round engine in auto
            # mode (the default scan-family fast path): completed jobs
            # are exact by construction, node-hours and peak within its
            # 5 % contract.
            assert v["engine"] == "rounds", point
            assert v["completed_jobs"] == r["completed_jobs"], point
            assert v["node_hours"] == pytest.approx(r["node_hours"],
                                                    rel=0.05), point
            assert v["peak_nodes"] == pytest.approx(r["peak_nodes"],
                                                    rel=0.05), point
            assert v["window_overflow"] == 0 and v["truncated"] == 0


def test_vectorized_ec2_against_direct_run_sim(workload):
    """Belt and braces: the fast path also matches a hand-driven
    ``run_sim`` (not just ``run_sweep``'s own fallback)."""
    jobs, ws = workload
    from repro.sim.engine import build_ec2_rightscale, clone_jobs
    point = SweepPoint("ec2", lease_seconds=1800.0)
    row = run_sweep([point], jobs, ws, T)[0]
    r = run_sim(build_ec2_rightscale(1800.0), clone_jobs(jobs), ws, T)
    assert row["peak_nodes"] == r.peak_nodes
    assert row["completed_jobs"] == r.completed_jobs
    assert row["node_hours"] == pytest.approx(r.node_hours, rel=1e-9)
    assert row["avg_turnaround"] == pytest.approx(r.avg_turnaround, rel=1e-9)
    # EC2 never queues: turnaround == execution (§6.6.1).
    assert row["avg_turnaround"] == row["avg_execution"]


def test_paper_grid_shape_and_fallback_routing(workload):
    jobs, ws = workload
    pts = paper_grid(prc_pbj=64, prc_ws=64,
                     capacity_fracs=(0.6, 1.0), B_values=(13, 25),
                     lease_minutes=(30, 60), fig18_B=25)
    assert len(pts) == 1 + 2 + 2 + 2 * 2
    rows = run_sweep(pts, jobs, ws, T)
    by_kind = {r["system_kind"]: r["engine"] for r in rows}
    assert by_kind["dcs"] == "vectorized"
    assert by_kind["ec2"] == "vectorized"
    # The event-round engine is the default scan-family mode for the
    # stateful policies since this PR.
    assert by_kind["fb"] == "rounds"
    assert by_kind["flb_nub"] == "rounds"
    # Every builder constructs a ProvisioningSystem with the right lease.
    for p in pts:
        assert _build(p).lease_seconds == p.lease_seconds


# ----------------------------------------------------- mode="scan" fast path

def test_sweep_point_rejects_unknown_system():
    with pytest.raises(ValueError, match="unknown system"):
        SweepPoint("ec3")
    with pytest.raises(ValueError, match="lease_seconds"):
        SweepPoint("fb", capacity=10, lease_seconds=0.0)
    with pytest.raises(ValueError, match="unknown mode"):
        run_sweep([SweepPoint("dcs", prc_pbj=1)], [], [(0.0, 0)], 10.0,
                  mode="warp")
    # The scan kill encoding always restarts from scratch — the beyond-
    # paper checkpoint-preempt mode must be rejected, not silently run.
    from repro.core.pbj_manager import PBJPolicyParams
    ckpt = SweepPoint("fb", capacity=8,
                      params=PBJPolicyParams(checkpoint_preempt=True))
    with pytest.raises(ValueError, match="checkpoint_preempt"):
        run_sweep([ckpt], [], [(0.0, 0)], 7200.0, mode="scan")


@pytest.fixture(scope="module")
def full_workload():
    return traces.nasa_ipsc(seed=0), traces.worldcup98(seed=0, peak_vms=128)


@pytest.fixture(scope="module")
def scan_grid():
    """Fig. 13 capacities + Fig. 14 pool sizes + Fig. 18 leases — the
    coordinated-policy points of the paper grids."""
    return (
        [SweepPoint("fb", capacity=c) for c in (128, 154, 192, 256)]
        + [SweepPoint("flb_nub", lb_pbj=B - 12, lb_ws=12)
           for B in (13, 25, 51, 154)]
        + [SweepPoint("flb_nub", lb_pbj=13, lb_ws=12, lease_seconds=L,
                      label=f"FLB-NUB(L={L:g}s)")
           for L in (900.0, 3600.0, 14400.0)])


def test_scan_mode_fidelity_contract(full_workload, scan_grid):
    """The documented tolerances of the batched lax.scan path vs the
    event engine on two-week paper workloads: completed jobs within 2 %,
    node-hours and peak within 15 %, kill counts the same order."""
    jobs, ws = full_workload
    T_full = traces.TWO_WEEKS
    scan_rows = run_sweep(scan_grid, jobs, ws, T_full, mode="scan")
    event_rows = run_sweep(scan_grid, jobs, ws, T_full, mode="event")
    for p, s, e in zip(scan_grid, scan_rows, event_rows):
        assert s["engine"] == "scan" and e["engine"] == "event"
        assert s["window_overflow"] == 0, p
        assert abs(s["completed_jobs"] - e["completed_jobs"]) \
            <= max(2, 0.02 * e["completed_jobs"]), p
        assert s["node_hours"] == pytest.approx(e["node_hours"], rel=0.15), p
        assert s["peak_nodes"] == pytest.approx(e["peak_nodes"], rel=0.15), p


def test_scan_mode_preserves_sweep_orderings(full_workload, scan_grid):
    """J1/J2 acceptance: the scan path ranks parameter-sweep points the
    same way the event engine does (Fig. 13 capacity → cost, Fig. 14
    B → cost and turnaround, Fig. 18 L → adjust events)."""
    jobs, ws = full_workload
    T_full = traces.TWO_WEEKS
    scan_rows = run_sweep(scan_grid, jobs, ws, T_full, mode="scan")
    event_rows = run_sweep(scan_grid, jobs, ws, T_full, mode="event")

    def order(rows, idx, metric):
        vals = [rows[i][metric] for i in idx]
        return sorted(range(len(vals)), key=vals.__getitem__)

    fb_idx, b_idx, l_idx = range(0, 4), range(4, 8), range(8, 11)
    # Fig. 13: node-hours grow with capacity C.
    assert order(scan_rows, fb_idx, "node_hours") \
        == order(event_rows, fb_idx, "node_hours") == [0, 1, 2, 3]
    # J1 (Fig. 14): consumption grows with B, turnaround falls with B.
    assert order(scan_rows, b_idx, "node_hours") \
        == order(event_rows, b_idx, "node_hours") == [0, 1, 2, 3]
    assert scan_rows[4]["avg_turnaround"] > scan_rows[7]["avg_turnaround"]
    assert event_rows[4]["avg_turnaround"] > event_rows[7]["avg_turnaround"]
    # Fig. 18: PBJ adjust events fall as the lease unit grows.
    assert order(scan_rows, l_idx, "pbj_adjust_events") \
        == order(event_rows, l_idx, "pbj_adjust_events") == [2, 1, 0]


def test_scan_mode_batches_the_trace_axis(workload):
    """run_sweep_workloads: one scan call serves several workloads, and
    per-workload rows reflect their own trace."""
    jobs, ws = workload
    jobs2 = [j for j in traces.sdsc_blue(seed=3) if j.submit < T]
    ws2 = [(t, d) for t, d in traces.worldcup98(seed=4, peak_vms=64)
           if t < T]
    pts = [SweepPoint("fb", capacity=160),
           SweepPoint("flb_nub", lb_pbj=13, lb_ws=12),
           SweepPoint("ec2", lease_seconds=3600.0)]
    rows = run_sweep_workloads(pts, [(jobs, ws), (jobs2, ws2)], T,
                               mode="scan")
    assert len(rows) == 2 and all(len(r) == len(pts) for r in rows)
    for w, (wl_jobs, _) in enumerate([(jobs, ws), (jobs2, ws2)]):
        assert rows[w][0]["engine"] == "scan"
        assert rows[w][1]["engine"] == "scan"
        assert rows[w][2]["engine"] == "vectorized"
        ref = run_sweep(pts, *([(jobs, ws), (jobs2, ws2)][w]), T,
                        mode="event")
        for i in (0, 1):
            assert abs(rows[w][i]["completed_jobs"]
                       - ref[i]["completed_jobs"]) \
                <= max(5, 0.05 * ref[i]["completed_jobs"])
    # The two workloads genuinely differ, and so must their rows.
    assert rows[0][1]["node_hours"] != rows[1][1]["node_hours"]
